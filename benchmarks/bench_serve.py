"""Continuous-batching LLM serving gate (ROADMAP item 2).

The acceptance benchmark for the Session-backed serving subsystem
(:class:`repro.serve.session_engine.SessionServeEngine`): ``N_USERS``
(>= 100) simulated closed-loop users — each submits a request, waits for
its completion, then submits the next — split across 1 *heavy* + 3
*light* tenants on one emulated accelerator.  Every tenant is a QoS
client; KV pages live in runtime-managed page-group buffers
(:class:`repro.core.kv_manager.KVManager`).  Four runs, four claims:

* **mix** (the headline): aggregate modeled token throughput
  (``tokens_per_s_model``) and the light tenants' p95 modeled decode
  latency, both from the deterministic QoS replay — exact across runs
  and machines;
* **solo**: the light users alone; the gate bounds
  ``decode_p95_ratio_vs_solo`` — how much the heavy tenant may stretch
  light-tenant decode latency;
* **pressure**: the same mix under a device arena smaller than the KV
  pool — cold page groups must spill to host through the runtime's
  eviction/coherence path (``spill_bytes > 0``) with **bit-identical**
  tokens (memory pressure changes *where* KV lives, never *what* is
  generated);
* **legacy**: the same workload through the hand-managed
  :class:`repro.serve.engine.ServeEngine` — every request's token
  stream must match bitwise (the runtime manages the memory, the math
  is untouched).

Emits ``BENCH_serve.json`` for the CI perf-regression gate; the record
carries ``gate_tolerances`` and ``gate_directions`` (throughput and
spill gate lower bounds).

Run:  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from .common import emit

N_USERS = 104
REQS_PER_USER = 2
N_LIGHTS = 3
MAX_BATCH = 8
PAGE_SIZE = 8
NUM_PAGES = 128
MAX_PAGES_PER_SEQ = 4
PAGES_PER_GROUP = 16
ALLOCATOR = "nextfit"  # cycles page grabs across all groups → cold groups
PROMPT_LEN = (2, 9)  # [lo, hi)
MAX_NEW = (2, 7)
HEAVY_WEIGHT = 1.0
HEAVY_WINDOW = 4
LIGHT_WEIGHT = 4.0
LIGHT_WINDOW = 4
HEAVY_QUOTA_PAGES = 96  # generous: accounting exercised, no deferrals
LIGHT_SLO_LATENCY_S = 60.0  # loose objective — never violated
HEAVY_SLO_LATENCY_S = 10e-6  # below the launch floor — always violated
SLO_TARGET = 0.99
# Pressure arena: smaller than the 512 KiB KV pool (16 group buffers x
# 32 KiB) but larger than any one substep's referenced working set.
PRESSURE_ARENA_BYTES = 384 << 10
BIG_ARENA_BYTES = 64 << 20


def _tenant_of(u: int) -> str:
    return "heavy" if u % 2 == 0 else f"light{(u // 2) % N_LIGHTS}"


def make_workload(n_users: int, reqs_per_user: int, vocab: int, seed=0):
    """Per-user request lists [(prompt, max_new), ...] — deterministic."""
    rng = np.random.default_rng(seed)
    users = []
    for _ in range(n_users):
        reqs = []
        for _ in range(reqs_per_user):
            plen = int(rng.integers(*PROMPT_LEN))
            prompt = [int(t) for t in rng.integers(1, vocab, plen)]
            reqs.append((prompt, int(rng.integers(*MAX_NEW))))
        users.append(reqs)
    return users


def drive(submit, step, users) -> dict:
    """Closed-loop drive: each user keeps exactly one request in flight;
    the next is submitted the step after the previous completes.
    Returns ``{(user, req_index): generated_tokens}``."""
    nxt = [0] * len(users)
    cur: list = [None] * len(users)
    out: dict = {}

    def pump(u: int) -> None:
        if nxt[u] < len(users[u]):
            prompt, max_new = users[u][nxt[u]]
            cur[u] = (nxt[u], submit(u, prompt, max_new))
            nxt[u] += 1
        else:
            cur[u] = None

    for u in range(len(users)):
        pump(u)
    while any(c is not None for c in cur):
        step()
        for u in range(len(users)):
            if cur[u] is not None and cur[u][1].done:
                i, req = cur[u]
                out[(u, i)] = list(req.generated)
                pump(u)
    return out


def _session_case(cfg, params, users, *, include_heavy: bool,
                  arena_bytes: int) -> dict:
    from repro.serve.session_engine import SessionServeEngine

    eng = SessionServeEngine(
        cfg, params, max_batch=MAX_BATCH, page_size=PAGE_SIZE,
        num_pages=NUM_PAGES, max_pages_per_seq=MAX_PAGES_PER_SEQ,
        pages_per_group=PAGES_PER_GROUP, allocator=ALLOCATOR,
        arena_bytes=arena_bytes,
    )
    for i in range(N_LIGHTS):
        eng.tenant(f"light{i}", weight=LIGHT_WEIGHT, window=LIGHT_WINDOW,
                   slo_latency_s=LIGHT_SLO_LATENCY_S, slo_target=SLO_TARGET)
    if include_heavy:
        eng.tenant("heavy", weight=HEAVY_WEIGHT, window=HEAVY_WINDOW,
                   quota_pages=HEAVY_QUOTA_PAGES,
                   slo_latency_s=HEAVY_SLO_LATENCY_S, slo_target=SLO_TARGET)

    active = [u for u in range(len(users))
              if include_heavy or _tenant_of(u) != "heavy"]
    sub_users = [users[u] for u in active]

    def submit(j, prompt, max_new):
        return eng.submit(prompt, max_new, tenant=_tenant_of(active[j]))

    t0 = time.perf_counter()
    out = drive(submit, eng.step, sub_users)
    wall = time.perf_counter() - t0
    # remap back to global user ids for cross-run comparison
    out = {(active[j], i): toks for (j, i), toks in out.items()}

    qrep = eng.qos_report()
    total_new = sum(len(t) for t in out.values())
    pct = qrep["latency_percentiles"]
    light_p95 = max(pct[f"light{i}"]["p95"] for i in range(N_LIGHTS))
    metrics = eng.session.metrics
    res = {
        "wall_s": wall,
        "makespan_model": qrep["makespan_model"],
        "total_new_tokens": total_new,
        "tokens_per_s_model": total_new / qrep["makespan_model"],
        "tokens_per_s_wall": total_new / wall,
        "light_decode_p95_model_s": light_p95,
        "latency_percentiles": pct,
        "slo": qrep["slo"],
        "fairness": qrep["fairness"],
        "spill_bytes": eng.kv.spill_bytes(),
        "kv_pages_resident": eng.kv.used_pages,
        "tokens_counter": int(
            metrics.counter("serve_tokens_generated").value),
        "requests_completed": int(
            metrics.counter("serve_requests_completed").value),
        "metrics_text": eng.session.metrics_text(),
        "_out": out,
    }
    eng.close()
    return res


def _legacy_case(cfg, params, users) -> dict:
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(cfg, params, max_batch=MAX_BATCH, page_size=PAGE_SIZE,
                      num_pages=NUM_PAGES,
                      max_pages_per_seq=MAX_PAGES_PER_SEQ,
                      allocator=ALLOCATOR)
    t0 = time.perf_counter()
    out = drive(lambda u, p, m: eng.submit(p, m), eng.step, users)
    wall = time.perf_counter() - t0
    total_new = sum(len(t) for t in out.values())
    return {"wall_s": wall, "total_new_tokens": total_new,
            "tokens_per_s_wall": total_new / wall, "_out": out}


def run_serve(*, n_users: int, reqs_per_user: int, json_path,
              smoke: bool) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_config("llama3_8b").smoke(),
                              name="serve-bench", dtype="float32")
    params = build_model(cfg).init(jax.random.key(1))
    users = make_workload(n_users, reqs_per_user, cfg.vocab)

    mix = _session_case(cfg, params, users, include_heavy=True,
                        arena_bytes=BIG_ARENA_BYTES)
    solo = _session_case(cfg, params, users, include_heavy=False,
                         arena_bytes=BIG_ARENA_BYTES)
    pressure = _session_case(cfg, params, users, include_heavy=True,
                             arena_bytes=PRESSURE_ARENA_BYTES)
    legacy = _legacy_case(cfg, params, users)

    ratio = (mix["light_decode_p95_model_s"]
             / max(solo["light_decode_p95_model_s"], 1e-12))
    identical_legacy = mix["_out"] == legacy["_out"]
    identical_pressure = mix["_out"] == pressure["_out"]
    light_keys = {k for k in mix["_out"] if _tenant_of(k[0]) != "heavy"}
    identical_solo = all(mix["_out"][k] == solo["_out"][k]
                         for k in light_keys)

    emit("serve_mix", mix["wall_s"] * 1e6,
         f"tok_per_s_model={mix['tokens_per_s_model']:.1f};"
         f"tok_per_s_wall={mix['tokens_per_s_wall']:.1f};"
         f"light_p95_ms={mix['light_decode_p95_model_s'] * 1e3:.3f};"
         f"x_solo={ratio:.2f}")
    emit("serve_solo", solo["wall_s"] * 1e6,
         f"light_p95_ms={solo['light_decode_p95_model_s'] * 1e3:.3f}")
    emit("serve_pressure", pressure["wall_s"] * 1e6,
         f"spill_bytes={pressure['spill_bytes']};"
         f"identical={identical_pressure}")
    emit("serve_legacy", legacy["wall_s"] * 1e6,
         f"tok_per_s_wall={legacy['tokens_per_s_wall']:.1f};"
         f"identical={identical_legacy}")

    strip = ("_out", "metrics_text", "latency_percentiles")
    rec = {
        "bench": "serve",
        "params": {
            "n_users": n_users, "reqs_per_user": reqs_per_user,
            "n_lights": N_LIGHTS, "max_batch": MAX_BATCH,
            "page_size": PAGE_SIZE, "num_pages": NUM_PAGES,
            "max_pages_per_seq": MAX_PAGES_PER_SEQ,
            "pages_per_group": PAGES_PER_GROUP, "allocator": ALLOCATOR,
            "heavy_weight": HEAVY_WEIGHT, "light_weight": LIGHT_WEIGHT,
            "heavy_quota_pages": HEAVY_QUOTA_PAGES,
            "pressure_arena_bytes": PRESSURE_ARENA_BYTES,
        },
        "mix": {k: v for k, v in mix.items() if k not in strip},
        "solo": {k: v for k, v in solo.items() if k not in strip},
        "pressure": {k: v for k, v in pressure.items() if k not in strip},
        "legacy": {k: v for k, v in legacy.items() if k != "_out"},
        "decode_p95_ratio_vs_solo": ratio,
        "bit_identical_vs_legacy": bool(identical_legacy),
        "bit_identical_under_pressure": bool(identical_pressure),
        "slo": mix["slo"],
        # Regression-gated metrics — all modeled / exact-count, so they
        # are byte-identical across runs and machines.
        "gate": {
            "tokens_per_s_model": mix["tokens_per_s_model"],
            "light_decode_p95_model_s": mix["light_decode_p95_model_s"],
            "decode_p95_ratio_vs_solo": ratio,
            "mix_makespan_model": mix["makespan_model"],
            "pressure_spill_bytes": pressure["spill_bytes"],
        },
        "gate_tolerances": {"decode_p95_ratio_vs_solo": 0.25,
                            "pressure_spill_bytes": 0.9},
        # Throughput must not drop; pressure must keep spilling (the
        # generous tolerance only guards the eviction path staying live).
        "gate_directions": {"tokens_per_s_model": "min",
                            "pressure_spill_bytes": "min"},
    }

    if smoke:
        assert n_users >= 100, f"gate requires >=100 users, got {n_users}"
        n_reqs = n_users * reqs_per_user
        assert len(mix["_out"]) == n_reqs, (len(mix["_out"]), n_reqs)
        assert identical_legacy, (
            "session engine token streams differ from legacy ServeEngine"
        )
        assert identical_solo, (
            "light requests' tokens changed between mix and solo runs"
        )
        # Pressure: the eviction path must carry KV to host and back
        # without changing a single token.
        assert pressure["spill_bytes"] > 0, (
            f"no KV spill under a {PRESSURE_ARENA_BYTES}-byte arena"
        )
        assert identical_pressure, (
            "token streams changed under memory pressure"
        )
        assert mix["spill_bytes"] == 0, (
            "unexpected spill with an ample arena"
        )
        # Serving telemetry (PR-8 metrics): counters must agree with the
        # driver's own tally and be exported in Prometheus text.
        assert mix["tokens_counter"] == mix["total_new_tokens"]
        assert mix["requests_completed"] == n_reqs
        for name in ("serve_tokens_generated", "serve_requests_completed",
                     "serve_kv_pages_resident", "serve_kv_spill_bytes"):
            assert name in mix["metrics_text"], f"{name} not exported"
        # All pages back in the pool: only the pinned scratch page stays.
        assert mix["kv_pages_resident"] == 1, mix["kv_pages_resident"]
        # Per-tenant SLO burn rates from the deterministic replay: the
        # lights' loose objective holds; the heavy tenant's
        # sub-launch-floor objective is violated by every task.
        for i in range(N_LIGHTS):
            s = mix["slo"][f"light{i}"]
            assert s["violations"] == 0 and not s["breached"], (i, s)
        hs = mix["slo"]["heavy"]
        assert hs["violations"] == hs["tasks"] > 0 and hs["breached"], hs
        print(f"serve smoke: OK ({n_reqs} reqs from {n_users} users, "
              f"{mix['total_new_tokens']} tokens, "
              f"{mix['tokens_per_s_model']:.1f} modeled tok/s, light p95 "
              f"{ratio:.2f}x solo, pressure spilled "
              f"{pressure['spill_bytes']} B bit-identically)", flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def run(n_users: int = N_USERS, reqs_per_user: int = REQS_PER_USER,
        json_path=None) -> None:
    run_serve(n_users=n_users, reqs_per_user=reqs_per_user,
              json_path=json_path, smoke=False)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI run with bit-identity + spill + telemetry "
                         "asserts")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--users", type=int, default=None)
    ap.add_argument("--reqs-per-user", type=int, default=None)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_serve.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    n_users = args.users or N_USERS
    reqs = args.reqs_per_user or (1 if args.smoke else REQS_PER_USER)
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "serve", metrics_dir=args.metrics_dir):
        run_serve(n_users=n_users, reqs_per_user=reqs,
                  json_path=args.json or None, smoke=args.smoke)


if __name__ == "__main__":
    main()
