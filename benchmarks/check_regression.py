"""CI perf-regression gate over BENCH_*.json artifacts (ISSUE 2/5).

Each smoke benchmark emits a machine-readable record whose ``gate`` dict
holds *modeled*, machine-independent metrics (makespan under the
bandwidth model + static cost priors, exact ledger copy counts,
QoS-replay latencies).  This tool compares freshly produced records
against the committed baselines of the same name under
``benchmarks/baselines/`` and fails (exit 1) if any gated metric
regressed beyond its tolerance.

Tolerances: ``--tolerance`` (default 10%) applies to every metric; a
baseline file may override per metric via a top-level
``"gate_tolerances": {"metric": 0.25}`` dict — benchmarks embed these in
the records they emit, so committing a record as the baseline carries
its tolerances along.

Directions: gates default to upper bounds (lower is better).  A baseline
``"gate_directions": {"metric": "min"}`` flips a metric to a lower bound
(higher is better — e.g. the process backend's measured
``wall_speedup_vs_serial``).  A produced record may list metrics it
could not measure this run under ``"gate_skipped"`` (e.g. wall gates on
a runner with too few cores); those report SKIP instead of failing.

Reporting: a per-metric baseline-vs-current table with percent deltas is
always printed; ``--report PATH`` appends the same table as GitHub
markdown (CI points it at ``$GITHUB_STEP_SUMMARY``), and ``--json PATH``
writes the full machine-readable comparison.

Improvements are reported; to ratchet a baseline down, re-run the bench
locally and commit the new JSON.

Usage:
  python -m benchmarks.check_regression BENCH_graph.json [...] \\
      [--baselines benchmarks/baselines] [--tolerance 0.10] \\
      [--report summary.md] [--json regressions.json]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

DEFAULT_BASELINES = Path(__file__).resolve().parent / "baselines"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def check_file(produced: Path, baselines: Path, tolerance: float) -> dict:
    """Compare one produced record against its committed baseline.
    Returns ``{"name", "rows": [...], "failures": [...]}`` where each
    row is one gated metric's comparison."""
    out = {"name": produced.name, "rows": [], "failures": []}
    base_path = baselines / produced.name
    if not produced.exists():
        out["failures"].append(f"{produced.name}: produced record missing")
        return out
    if not base_path.exists():
        out["failures"].append(
            f"{produced.name}: no committed baseline at {base_path}"
        )
        return out
    rec = json.loads(produced.read_text())
    base = json.loads(base_path.read_text())
    gate, gate_base = rec.get("gate", {}), base.get("gate", {})
    if not gate or not gate_base:
        out["failures"].append(
            f"{produced.name}: missing 'gate' dict in record or baseline"
        )
        return out
    # Per-metric overrides live in the BASELINE (the committed contract).
    tols = dict(base.get("gate_tolerances", {}))
    # Direction per metric: "max" (default) gates an upper bound — lower
    # is better, FAIL above ref*(1+tol); "min" gates a lower bound —
    # higher is better (e.g. wall_speedup_vs_serial), FAIL below
    # ref*(1-tol).
    directions = dict(base.get("gate_directions", {}))
    # A produced record may declare baseline metrics it could not
    # measure this run (e.g. wall gates on a runner with too few cores)
    # — reported as SKIP, not as a vanished metric.
    skipped = set(rec.get("gate_skipped", []))
    for key, ref in sorted(gate_base.items()):
        if key not in gate:
            if key in skipped:
                out["rows"].append({
                    "metric": key, "baseline": ref, "current": None,
                    "delta_pct": None,
                    "tolerance": tols.get(key, tolerance),
                    "status": "SKIP",
                })
                continue
            out["failures"].append(
                f"{produced.name}: gated metric {key!r} vanished"
            )
            out["rows"].append({
                "metric": key, "baseline": ref, "current": None,
                "delta_pct": None, "tolerance": tols.get(key, tolerance),
                "status": "MISSING",
            })
            continue
        val = gate[key]
        tol = float(tols.get(key, tolerance))
        direction = directions.get(key, "max")
        if direction == "min":
            limit = ref * (1.0 - tol)
            failed = val < limit
            over = f"<{tol * 100:.0f}% under"
        else:
            limit = ref * (1.0 + tol)
            failed = val > limit
            over = f">{tol * 100:.0f}% over"
        delta = (val - ref) / ref * 100 if ref else 0.0
        status = "FAIL" if failed else "ok"
        out["rows"].append({
            "metric": key, "baseline": ref, "current": val,
            "delta_pct": delta, "tolerance": tol, "status": status,
        })
        if failed:
            out["failures"].append(
                f"{produced.name}: {key} regressed {delta:+.1f}% "
                f"({over} baseline {_fmt(ref)})"
            )
    return out


def print_table(results: List[dict]) -> None:
    print(f"{'bench':<28} {'metric':<24} {'baseline':>12} {'current':>12} "
          f"{'delta':>8} {'tol':>6} status")
    for res in results:
        for row in res["rows"]:
            delta = ("" if row["delta_pct"] is None
                     else f"{row['delta_pct']:+.1f}%")
            cur = "" if row["current"] is None else _fmt(row["current"])
            print(f"{res['name']:<28} {row['metric']:<24} "
                  f"{_fmt(row['baseline']):>12} {cur:>12} {delta:>8} "
                  f"{row['tolerance'] * 100:>5.0f}% {row['status']}")


def markdown_report(results: List[dict]) -> str:
    lines = ["## Perf-regression gate", "",
             "| bench | metric | baseline | current | delta | tol | status |",
             "|---|---|---:|---:|---:|---:|---|"]
    for res in results:
        if not res["rows"]:
            lines.append(f"| {res['name']} | — | | | | | "
                         f"{'FAIL' if res['failures'] else 'ok'} |")
        for row in res["rows"]:
            delta = ("" if row["delta_pct"] is None
                     else f"{row['delta_pct']:+.1f}%")
            cur = "" if row["current"] is None else _fmt(row["current"])
            mark = {"ok": "✅", "FAIL": "❌", "MISSING": "❌",
                    "SKIP": "⏭️"}.get(row["status"], row["status"])
            lines.append(
                f"| {res['name']} | `{row['metric']}` | "
                f"{_fmt(row['baseline'])} | {cur} | {delta} | "
                f"{row['tolerance'] * 100:.0f}% | {mark} |"
            )
    failures = [f for res in results for f in res["failures"]]
    lines.append("")
    if failures:
        lines.append(f"**{len(failures)} regression(s):**")
        lines += [f"- {f}" for f in failures]
    else:
        lines.append("**All gated metrics within tolerance.**")
    lines.append("")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("produced", nargs="+", help="freshly emitted BENCH_*.json")
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES))
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="default allowed relative regression "
                         "(0.10 = 10%%); baselines may override per "
                         "metric via 'gate_tolerances'")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="append a GitHub-markdown comparison table to "
                         "PATH (use $GITHUB_STEP_SUMMARY in CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable comparison to PATH")
    args = ap.parse_args(argv)
    baselines = Path(args.baselines)
    results = [check_file(Path(p), baselines, args.tolerance)
               for p in args.produced]
    failures = [f for res in results for f in res["failures"]]

    print_table(results)
    if args.report:
        with open(args.report, "a") as fh:
            fh.write(markdown_report(results))
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"results": results, "failures": failures,
             "default_tolerance": args.tolerance}, indent=1))
    for f in failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    if not failures:
        print("perf-regression gate: OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
