"""CI perf-regression gate over BENCH_*.json artifacts (ISSUE 2).

Each smoke benchmark emits a machine-readable record whose ``gate`` dict
holds *modeled*, machine-independent metrics (makespan under the
bandwidth model + static cost priors, exact ledger copy counts).  This
tool compares a freshly produced record against the committed baseline
of the same name under ``benchmarks/baselines/`` and fails (exit 1) if
any gated metric regressed more than ``--tolerance`` (default 10%).

Improvements are reported; to ratchet the baseline down, re-run the
bench locally and commit the new JSON.

Usage:
  python -m benchmarks.check_regression BENCH_graph.json BENCH_pressure.json \\
      [--baselines benchmarks/baselines] [--tolerance 0.10]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINES = Path(__file__).resolve().parent / "baselines"


def check_file(produced: Path, baselines: Path, tolerance: float) -> list:
    """Returns a list of failure strings (empty = pass)."""
    base_path = baselines / produced.name
    if not base_path.exists():
        return [f"{produced.name}: no committed baseline at {base_path}"]
    rec = json.loads(produced.read_text())
    base = json.loads(base_path.read_text())
    gate, gate_base = rec.get("gate", {}), base.get("gate", {})
    if not gate or not gate_base:
        return [f"{produced.name}: missing 'gate' dict in record or baseline"]
    failures = []
    for key, ref in sorted(gate_base.items()):
        if key not in gate:
            failures.append(f"{produced.name}: gated metric {key!r} vanished")
            continue
        val = gate[key]
        limit = ref * (1.0 + tolerance)
        delta = (val - ref) / ref * 100 if ref else 0.0
        status = "FAIL" if val > limit else "ok"
        print(f"[{status}] {produced.name}:{key} = {val:.6g} "
              f"(baseline {ref:.6g}, {delta:+.1f}%, limit {limit:.6g})")
        if val > limit:
            failures.append(
                f"{produced.name}: {key} regressed {delta:+.1f}% "
                f"(>{tolerance * 100:.0f}% over baseline {ref:.6g})"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("produced", nargs="+", help="freshly emitted BENCH_*.json")
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES))
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (0.10 = 10%%)")
    args = ap.parse_args()
    baselines = Path(args.baselines)
    failures = []
    for p in args.produced:
        failures += check_file(Path(p), baselines, args.tolerance)
    for f in failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    if not failures:
        print("perf-regression gate: OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
