"""Paper Table 2: RC / PD / SAR on GPU-only and 3CPU-1GPU configs,
reference vs RIMMS.  Round-robin scheduling reproduces the paper's
batches-of-four task placement on the 3CPU-1GPU setup.

SAR runs at 1/8 way-count (64-way + 32-way) to keep CI-time sane — the
per-task structure (and therefore the copy-elimination ratios) is
identical; way-count scales both policies equally."""

from __future__ import annotations

import functools

from .common import emit, run_app

CONFIGS = (
    ("gpu_only", dict(n_cpu=0, accelerators=("gpu0",))),
    ("3cpu_1gpu", dict(n_cpu=3, accelerators=("gpu0",))),
)


def run(repeats: int = 3) -> None:
    from repro.apps.radar import build_pd, build_rc, build_sar

    apps = (
        ("rc", build_rc, {}),
        ("pd", functools.partial(build_pd, ways=128, n=128), {}),
        ("sar", functools.partial(build_sar, scale=8), {}),
    )
    for app_name, builder, kw in apps:
        for cfg_name, cfg in CONFIGS:
            res = {}
            for policy in ("reference", "rimms"):
                res[policy] = run_app(
                    builder, policy=policy, repeats=repeats,
                    n_cpu=cfg["n_cpu"],  # 0 ⇒ no CPU PE ⇒ GPU-only
                    accelerators=cfg["accelerators"],
                    builder_kwargs=kw,
                )
            ref, rim = res["reference"], res["rimms"]
            spd = ref["wall_s"] / max(rim["wall_s"], 1e-12)
            emit(
                f"table2_{app_name}_{cfg_name}", rim["wall_s"] * 1e6,
                f"ref_us={ref['wall_s']*1e6:.1f};spdup={spd:.2f}x;"
                f"copies {ref['copies']:.0f}->{rim['copies']:.0f};"
                f"modeled_spdup={ref['modeled_s']/max(rim['modeled_s'],1e-12):.2f}x",
            )


if __name__ == "__main__":
    run()
