"""Paper Fig 10 + Table 3: marking-system comparison on the PD app.

Fig 10 — allocation overhead of the PD Computation region's 8 data
points × 128 parallel buffers under (a) bitset (block 4096), (b)
next-fit, (c) next-fit + fragment (1 alloc + O(n) fragment per point).

Table 3 — Overall vs Computation-only speedup convergence with repeat
count: allocation happens once, computation repeats N times; the
allocation scheme's overhead should wash out with repeats (fastest with
NF+fragment)."""

from __future__ import annotations

import time

import numpy as np

from .common import emit, run_app

WAYS, N = 128, 128
POINTS = 8  # data points in the PD computation region (Fig 9 edges)


def _alloc_overhead(kind: str, use_fragment: bool, iters: int = 3) -> float:
    from repro.core.hete import HeteContext, MemorySpace
    from repro.core.locations import Location

    ts = []
    for _ in range(iters):
        ctx = HeteContext()
        loc = Location("device", "acc0")
        ctx.register_space(MemorySpace(
            loc, capacity=64 << 20, allocator=kind, block_size=4096,
            ingest=lambda a: a, egress=lambda a: np.asarray(a),
        ))
        t0 = time.perf_counter()
        parents = []
        for _ in range(POINTS):
            if use_fragment:
                hd = ctx.malloc((WAYS * N,), np.complex64, spaces=[loc])
                hd.fragment(N)
                parents.append(hd)
            else:
                parents.extend(
                    ctx.malloc((N,), np.complex64, spaces=[loc])
                    for _ in range(WAYS)
                )
        for hd in parents:
            ctx.free(hd)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(repeat_counts=(1, 10, 50)) -> None:
    # ---- Fig 10: allocation overhead per scheme -------------------------
    t_bitset = _alloc_overhead("bitset", use_fragment=False)
    t_nf = _alloc_overhead("nextfit", use_fragment=False)
    t_nf_frag = _alloc_overhead("nextfit", use_fragment=True)
    emit("fig10_alloc_bitset", t_bitset * 1e6, f"{POINTS}x{WAYS} allocs")
    emit("fig10_alloc_nf", t_nf * 1e6,
         f"speedup_vs_bitset={t_bitset/max(t_nf,1e-12):.2f}x (paper: 2.55x)")
    emit("fig10_alloc_nf_fragment", t_nf_frag * 1e6,
         f"speedup_vs_nf={t_nf/max(t_nf_frag,1e-12):.2f}x (paper: 18.53x)")

    # ---- Table 3: overall vs computation-only across repeats -------------
    from repro.apps.radar import build_pd

    comp = {}
    for policy in ("reference", "rimms"):
        comp[policy] = run_app(
            lambda ctx: build_pd(ctx, ways=32, n=128, use_fragment=True),
            policy=policy, repeats=3, n_cpu=0, accelerators=("gpu0",),
        )
    comp_spd = comp["reference"]["wall_s"] / max(comp["rimms"]["wall_s"], 1e-12)
    emit("table3_computation_only", comp["rimms"]["wall_s"] * 1e6,
         f"spdup={comp_spd:.2f}x")
    for reps in repeat_counts:
        for scheme, kind, frag in (("bitset", "bitset", False),
                                   ("nf", "nextfit", False),
                                   ("nf_fragment", "nextfit", True)):
            alloc_s = _alloc_overhead(kind, frag, iters=1)
            total_rimms = alloc_s + reps * comp["rimms"]["wall_s"]
            total_ref = alloc_s + reps * comp["reference"]["wall_s"]
            emit(
                f"table3_overall_{scheme}_r{reps}", total_rimms * 1e6,
                f"spdup={total_ref/max(total_rimms,1e-12):.2f}x;"
                f"comp_only={comp_spd:.2f}x",
            )


if __name__ == "__main__":
    run()
