"""Interconnect topology: peer-mesh vs host-bridged platforms (ISSUE 3).

The same radar fork-join task graph (shared FFT source → parallel
fft/zip branches → pairwise zip joins, all radar ops) runs on two
modeled platforms built from the same PEs:

* ``nvlink_mesh``     — fast direct peer links between the accelerators;
* ``host_bridged_fpga`` — no peer links at all: every device↔device
  transfer routes through the host over slow UDMA links, which also
  serialize under contention.

Outputs must be **bit-identical** (the topology changes modeled cost and
routing accounting, never data), while the peer mesh must beat the
host-bridged platform by ≥1.3× modeled makespan — the join reductions'
device↔device traffic sits on the critical path, so routing quality is
exactly what the gap measures.

A second scenario demonstrates **spill-to-peer**: a pulse-Doppler
working set 2× one accelerator's arena, every task pinned to that
accelerator, with an idle roomy peer one fast link away.  Eviction
write-back chooses the peer over the host (cheaper link), the ledger's
``spills_to_peer`` counter proves it, and outputs stay bit-identical to
an unconstrained run.

All gated metrics are *modeled* (deterministic: static round-robin
placement + the executor's deterministic topology replay).

Run:  PYTHONPATH=src python -m benchmarks.bench_topology [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

WAYS = 8
N = 1 << 14
DEPTH = 2
MESH = "nvlink_mesh"
BRIDGED = "host_bridged_fpga"


def _soc(topology, *, arena_bytes=64 << 20, accelerators=("gpu0", "gpu1")):
    from repro.apps.radar import register_kernels
    from repro.core.runtime import Runtime, make_emulated_soc

    pes, ctx = make_emulated_soc(
        n_cpu=0, accelerators=accelerators, arena_bytes=arena_bytes,
        topology=topology,
    )
    rt = Runtime(pes, ctx, policy="rimms", scheduler="round_robin")
    register_kernels(rt)
    return rt, ctx


def _run_forkjoin(topology, mode, *, ways, n, depth):
    from repro.apps.synthetic import build_fork_join
    from repro.core.hete import hete_sync

    rt, ctx = _soc(topology)
    bufs, tasks = build_fork_join(ctx, ways=ways, n=n, depth=depth, seed=1)
    wall = (rt.run if mode == "serial" else rt.run_graph)(tasks)
    snap = ctx.ledger.snapshot()
    out = hete_sync(bufs["out"], context=ctx)
    rt.close()
    return {
        "wall_s": wall,
        "makespan_model": rt.last_makespan_model,
        "copies": snap["total_copies"],
        "bytes": snap["total_bytes"],
        "per_link": snap["per_link"],
        "_out": out,
    }


def _run_spill(topology, *, ways, n, constrained: bool):
    """Pulse-Doppler chain pinned to gpu0; gpu1 is an idle peer arena one
    fast link away.  Constrained: gpu0's arena is half the working set,
    so eviction must spill — to the peer when the link beats host."""
    from repro.apps.radar import _parallel_fzf
    from repro.core.hete import hete_sync

    working_set = 6 * ways * n * 8  # six complex64 parents
    arena = {"gpu0": (working_set // 2 if constrained else 64 << 20),
             "gpu1": 64 << 20}
    rt, ctx = _soc(topology, arena_bytes=arena)
    points, tasks = _parallel_fzf(ctx, ways, n, use_fragment=True, seed=0)
    for t in tasks:
        t.pin = "gpu0"
    wall = rt._run_impl(tasks)  # serial: deterministic victim order
    snap = ctx.ledger.snapshot()
    out = np.stack([
        hete_sync(points["out"][1][i], context=ctx) for i in range(ways)
    ])
    rt.close()
    return {
        "wall_s": wall,
        "makespan_model": rt.last_makespan_model,
        "copies": snap["total_copies"],
        "evictions": snap["total_evictions"],
        "spills_to_peer": snap["spills_to_peer"],
        "peer_writeback_MiB": snap["peer_writeback_bytes"] / 2 ** 20,
        "writeback_bytes": snap["writeback_bytes"],
        "_out": out,
    }


def run_topology(*, ways, n, depth, json_path, smoke) -> dict:
    cases = {}
    for topo in (MESH, BRIDGED):
        for mode in ("serial", "graph"):
            cases[(topo, mode)] = _run_forkjoin(
                topo, mode, ways=ways, n=n, depth=depth)

    mesh_g, bridged_g = cases[(MESH, "graph")], cases[(BRIDGED, "graph")]
    speedup = bridged_g["makespan_model"] / mesh_g["makespan_model"]
    identical = all(
        np.array_equal(mesh_g["_out"], c["_out"]) for c in cases.values()
    )

    spill = _run_spill(MESH, ways=ways, n=n, constrained=True)
    roomy = _run_spill(MESH, ways=ways, n=n, constrained=False)
    spill_identical = bool(np.array_equal(spill["_out"], roomy["_out"]))

    for (topo, mode), c in cases.items():
        emit(
            f"topology_{topo}_{mode}", c["wall_s"] * 1e6,
            f"model_ms={c['makespan_model'] * 1e3:.3f};"
            f"copies={c['copies']};bytes_MiB={c['bytes'] / 2 ** 20:.2f}",
        )
    emit(
        "topology_spill_to_peer", spill["wall_s"] * 1e6,
        f"model_ms={spill['makespan_model'] * 1e3:.3f};"
        f"evictions={spill['evictions']};"
        f"spills_to_peer={spill['spills_to_peer']};"
        f"peer_writeback_MiB={spill['peer_writeback_MiB']:.2f}",
    )
    busiest = sorted(
        bridged_g["per_link"].items(),
        key=lambda kv: -kv[1]["modeled_s"],
    )[:4]
    for link, row in busiest:
        emit(
            f"topology_link[{link}]", row["modeled_s"] * 1e6,
            f"copies={row['copies']};bytes_MiB={row['bytes'] / 2 ** 20:.2f}",
        )

    rec = {
        "bench": "topology",
        "params": {"ways": ways, "n": n, "depth": depth,
                   "mesh": MESH, "bridged": BRIDGED},
        "mesh_graph": {k: v for k, v in mesh_g.items()
                       if k not in ("_out", "per_link")},
        "bridged_graph": {k: v for k, v in bridged_g.items()
                          if k not in ("_out", "per_link")},
        "mesh_serial": {k: v for k, v in cases[(MESH, "serial")].items()
                        if k not in ("_out", "per_link")},
        "bridged_serial": {
            k: v for k, v in cases[(BRIDGED, "serial")].items()
            if k not in ("_out", "per_link")
        },
        "model_speedup_mesh_over_bridged": speedup,
        "bit_identical": bool(identical),
        "spill_to_peer": {k: v for k, v in spill.items() if k != "_out"},
        "spill_bit_identical": spill_identical,
        # Regression-gated metrics: all modeled + deterministic (static
        # placement, deterministic topology replay, serial spill case).
        "gate": {
            "makespan_model_mesh": mesh_g["makespan_model"],
            "makespan_model_bridged": bridged_g["makespan_model"],
            "mesh_over_bridged": mesh_g["makespan_model"]
            / bridged_g["makespan_model"],
            "copies_mesh": mesh_g["copies"],
            "spill_makespan_model": spill["makespan_model"],
        },
    }

    if smoke:
        assert identical, "outputs differ across topologies/modes"
        assert speedup >= 1.3, (
            f"peer mesh only {speedup:.2f}x over host-bridged "
            f"(acceptance: >=1.3x modeled makespan)"
        )
        assert spill["evictions"] > 0, "no eviction at 2x capacity?"
        assert spill["spills_to_peer"] > 0, (
            "no spill-to-peer despite a cheaper idle peer arena"
        )
        assert spill_identical, "spill-to-peer changed results"
        print(f"topology smoke: OK (mesh {speedup:.2f}x over bridged, "
              f"{spill['spills_to_peer']} spills to peer)", flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def run(ways: int = WAYS, n: int = N, depth: int = DEPTH) -> None:
    run_topology(ways=ways, n=n, depth=depth, json_path=None, smoke=False)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with bit-identity + speedup + "
                         "spill-to-peer asserts")
    ap.add_argument("--json", default="BENCH_topology.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--ways", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--depth", type=int, default=DEPTH)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_*.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    ways = args.ways or (4 if args.smoke else WAYS)
    n = args.n or (1 << 13 if args.smoke else N)
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "topology", metrics_dir=args.metrics_dir):
        run_topology(ways=ways, n=n, depth=args.depth,
                     json_path=args.json or None, smoke=args.smoke)


if __name__ == "__main__":
    main()
