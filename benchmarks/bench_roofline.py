"""Roofline terms per (arch × shape) from the dry-run artifacts
(EXPERIMENTS.md §Roofline) — emitted as CSV rows."""

from __future__ import annotations

from .common import emit


def run() -> None:
    from repro.launch.roofline import full_table

    rows = full_table()
    for r in rows:
        emit(
            f"roofline_{r['arch']}_{r['shape']}",
            r["bound_s"] * 1e6,
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f};GiB/dev={r['mem_per_device_GiB']:.2f};"
            f"multi={'y' if r['multi_ok'] else 'n'}",
        )
    if not rows:
        emit("roofline_missing", 0.0, "run repro.launch.dryrun --sweep first")


if __name__ == "__main__":
    run()
