"""Paper Fig 8: 3ZIP across runtimes on GPU-only — CEDR-style reference,
RIMMS, and a hand-fused jit chain as the native-CUDA analogue.

Sizes 2^7 .. 2^17.  The CUDA version in the paper keeps intermediates on
device — our fused jit does the same (one dispatch, zero intermediate
transfers), so "RIMMS tracks CUDA" maps to RIMMS wall/modeled time
approaching the fused-jit floor."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, run_app

SIZES = tuple(2 ** k for k in (7, 9, 11, 13, 15, 17))


@jax.jit
def _fused_3zip(a, b, c, d):
    return (a * b) * (c * d)


def run(repeats: int = 5) -> None:
    from repro.apps.radar import build_3zip

    for n in SIZES:
        res = {}
        for policy in ("reference", "rimms"):
            res[policy] = run_app(
                lambda ctx, n=n: build_3zip(ctx, n, pins=("gpu0",) * 3),
                policy=policy, repeats=repeats,
            )
        # native fused analogue
        rng = np.random.default_rng(0)
        arrs = [jnp.asarray((rng.normal(size=n) + 1j * rng.normal(size=n))
                            .astype(np.complex64)) for _ in range(4)]
        _fused_3zip(*arrs).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            _fused_3zip(*arrs).block_until_ready()
        fused = (time.perf_counter() - t0) / repeats
        ref, rim = res["reference"], res["rimms"]
        emit(
            f"fig8_3zip_n{n}", rim["wall_s"] * 1e6,
            f"ref_us={ref['wall_s']*1e6:.1f};fused_us={fused*1e6:.1f};"
            f"spdup_vs_ref={ref['wall_s']/max(rim['wall_s'],1e-12):.2f}x;"
            f"copies {ref['copies']:.0f}->{rim['copies']:.0f};"
            f"modeled_spdup={ref['modeled_s']/max(rim['modeled_s'],1e-12):.2f}x",
        )


if __name__ == "__main__":
    run()
