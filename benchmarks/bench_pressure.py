"""Capacity pressure: radar pipeline with a working set 2× arena capacity.

The ISSUE-2 acceptance benchmark.  A Pulse-Doppler-style pipeline
(``ways`` parallel FFT/FFT→ZIP→IFFT instances over fragmented parents)
allocates six parent buffers; the device arena is sized at HALF their
total footprint, so the runtime must continuously evict + spill-to-host
to make progress.  The run must complete **bit-identical** to an
unconstrained run — in serial mode and in graph mode (prefetch +
queued-reader protection) — while the ledger reports the spill traffic.

Emits `BENCH_pressure.json` (machine-readable, consumed by the CI
perf-regression gate — see benchmarks/check_regression.py).  The gated
metrics are *modeled* (bandwidth model + static cost priors over exact
byte counts), hence deterministic across machines.

Run:  PYTHONPATH=src python -m benchmarks.bench_pressure [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

WAYS = 8
N = 1 << 14


def _build(arena_bytes: int, *, ways: int, n: int, seed: int = 0):
    from repro.apps.radar import _parallel_fzf, register_kernels
    from repro.core.runtime import Runtime, make_emulated_soc

    pes, ctx = make_emulated_soc(
        n_cpu=0, accelerators=("gpu0",), arena_bytes=arena_bytes,
    )
    rt = Runtime(pes, ctx, policy="rimms", scheduler="round_robin")
    register_kernels(rt)
    points, tasks = _parallel_fzf(ctx, ways, n, use_fragment=True, seed=seed)
    return rt, ctx, points, tasks


def _outputs(points, ctx, ways: int) -> np.ndarray:
    from repro.core.hete import hete_sync

    return np.stack([
        hete_sync(points["out"][1][i], context=ctx) for i in range(ways)
    ])


def _run_case(mode: str, arena_bytes: int, *, ways: int, n: int) -> dict:
    rt, ctx, points, tasks = _build(arena_bytes, ways=ways, n=n)
    run = rt.run if mode == "serial" else rt.run_graph
    wall = run(tasks)
    snap = ctx.ledger.snapshot()
    out = _outputs(points, ctx, ways)
    rt.close()
    return {
        "wall_s": wall,
        "makespan_model": rt.last_makespan_model,
        "copies": snap["total_copies"],
        "bytes": snap["total_bytes"],
        "evictions": snap["total_evictions"],
        "writeback_bytes": snap["writeback_bytes"],
        "spill_stall_s": snap["spill_stall_s"],
        "spill_stall_model_s": rt.timeline.total_spill_s,
        "prefetch_deferrals": snap["prefetch_deferrals"],
        "_out": out,
    }


def run_pressure(*, ways: int, n: int, json_path: str | None,
                 smoke: bool) -> dict:
    parent_bytes = ways * n * 8  # complex64 parents
    working_set = 6 * parent_bytes  # a, b, fa, fb, z, out
    arena_bytes = working_set // 2  # the 2×-capacity acceptance point

    roomy = _run_case("serial", 64 << 20, ways=ways, n=n)
    tight_serial = _run_case("serial", arena_bytes, ways=ways, n=n)
    tight_graph = _run_case("graph", arena_bytes, ways=ways, n=n)

    identical_serial = bool(np.array_equal(roomy["_out"], tight_serial["_out"]))
    identical_graph = bool(np.array_equal(roomy["_out"], tight_graph["_out"]))
    rec = {
        "bench": "pressure",
        "params": {
            "ways": ways, "n": n, "working_set_bytes": working_set,
            "arena_bytes": arena_bytes, "pressure_ratio": 2.0,
        },
        "unconstrained": {k: v for k, v in roomy.items() if k != "_out"},
        "constrained_serial": {
            k: v for k, v in tight_serial.items() if k != "_out"
        },
        "constrained_graph": {
            k: v for k, v in tight_graph.items() if k != "_out"
        },
        "bit_identical_serial": identical_serial,
        "bit_identical_graph": identical_graph,
        # Regression-gated metrics: deterministic (modeled seconds over
        # exact byte counts; serial victim order is deterministic).
        "gate": {
            "makespan_model": tight_serial["makespan_model"],
            "copies": tight_serial["copies"],
            "evictions": tight_serial["evictions"],
        },
    }

    for name, case in (("unconstrained", roomy),
                       ("constrained_serial", tight_serial),
                       ("constrained_graph", tight_graph)):
        emit(
            f"pressure_{name}", case["wall_s"] * 1e6,
            f"model_ms={case['makespan_model'] * 1e3:.3f};"
            f"copies={case['copies']};evictions={case['evictions']};"
            f"writeback_MiB={case['writeback_bytes'] / 2 ** 20:.2f};"
            f"stall_ms={case['spill_stall_s'] * 1e3:.3f}",
        )

    if smoke:
        assert identical_serial, "serial outputs differ under pressure"
        assert identical_graph, "graph outputs differ under pressure"
        assert tight_serial["evictions"] > 0, "no eviction at 2x capacity?"
        assert tight_graph["evictions"] > 0, "no eviction in graph mode?"
        assert tight_serial["writeback_bytes"] > 0, "no dirty write-back?"
        print("pressure smoke: OK", flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with bit-identity + spill asserts")
    ap.add_argument("--json", default="BENCH_pressure.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--ways", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_*.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    ways = args.ways or (4 if args.smoke else WAYS)
    n = args.n or (1 << 12 if args.smoke else N)
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "pressure", metrics_dir=args.metrics_dir):
        run_pressure(ways=ways, n=n, json_path=args.json or None,
                     smoke=args.smoke)


if __name__ == "__main__":
    main()
