"""Paper Fig 7: hete_Malloc / hete_Free overhead vs block size.

Sweeps bitset block sizes 8 B .. 64 KiB and float problem sizes
32..8192, measuring per-call allocation and deallocation time on a
64 MiB arena, against python/numpy allocation as the "C/C++ default"
stand-in."""

from __future__ import annotations

import time

import numpy as np

from .common import emit

BLOCK_SIZES = (8, 64, 512, 4096, 65536)
PROBLEM_SIZES = (32, 512, 8192)  # float32 elements


def run(iters: int = 200) -> None:
    from repro.core.allocator import BitsetAllocator

    for prob in PROBLEM_SIZES:
        nbytes = prob * 4
        # baseline: raw numpy allocation (malloc analogue)
        t0 = time.perf_counter()
        for _ in range(iters):
            a = np.empty(prob, np.float32)
            del a
        base_us = (time.perf_counter() - t0) / iters * 1e6
        emit(f"fig7_malloc_default_n{prob}", base_us, "numpy empty/free")
        for bs in BLOCK_SIZES:
            arena = BitsetAllocator(64 << 20, bs)
            # steady-state: arena half full of persistent allocations
            persist = []
            try:
                for _ in range(64):
                    persist.append(arena.alloc(max(nbytes, bs)))
            except Exception:
                pass
            t0 = time.perf_counter()
            exts = [arena.alloc(nbytes) for _ in range(iters)]
            alloc_us = (time.perf_counter() - t0) / iters * 1e6
            t0 = time.perf_counter()
            for e in exts:
                arena.free(e)
            free_us = (time.perf_counter() - t0) / iters * 1e6
            emit(
                f"fig7_hete_malloc_n{prob}_bs{bs}", alloc_us,
                f"free_us={free_us:.3f};metadata_B={arena.metadata_bytes()}",
            )


if __name__ == "__main__":
    run()
