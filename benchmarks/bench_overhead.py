"""Paper §5.2.2: per-call last-resource-flag check overhead.

The paper measures 1.16 CPU cycles (1–2 cycles) per input on the
ZCU102's 1.2 GHz cores.  Our check is a Python-level dict/flag compare;
we report ns/call and the cycle-equivalent at 1.2 GHz, plus the check
cost relative to the transfer it avoids."""

from __future__ import annotations

import time

import numpy as np

from .common import emit


def run(n_calls: int = 1_000_000) -> None:
    from repro.core.hete import HeteContext
    from repro.core.locations import HOST

    ctx = HeteContext()
    hd = ctx.malloc((1024,), np.float32)
    t0 = time.perf_counter()
    for _ in range(n_calls):
        ctx.ensure(hd, HOST)  # flag hit: no copy
    dt = time.perf_counter() - t0
    ns = dt / n_calls * 1e9
    cycles_1p2ghz = ns * 1.2
    emit(
        "sec522_flag_check", ns / 1e3,
        f"ns_per_call={ns:.1f};cycles@1.2GHz={cycles_1p2ghz:.1f};"
        f"checks={ctx.ledger.flag_checks}",
    )


if __name__ == "__main__":
    run()
