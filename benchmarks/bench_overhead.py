"""Paper §5.2.2: per-call last-resource-flag check overhead — and the
tracing subsystem's cost on and off that hot path (ISSUE 6).

The paper measures 1.16 CPU cycles (1–2 cycles) per input on the
ZCU102's 1.2 GHz cores.  Our check is a Python-level dict/flag compare;
we report ns/call and the cycle-equivalent at 1.2 GHz.

Three tracer configurations are interleaved (round-robin repeats, so
machine drift hits all three equally) over the same flag-hit loop:

* ``baseline``  — no tracer attached (the pre-tracing hot path);
* ``traced``    — a ``TraceCollector`` attached and enabled.  The
  flag-hit fast path carries **zero** tracer instrumentation by design,
  so this must match baseline;
* ``paused``    — tracer attached but ``enabled=False`` (the no-op
  guard every slow-path hook takes first).

``--smoke`` gates both ratios at ≤ 1.30× baseline — i.e. the
tracing-disabled hot path stays statistically indistinguishable from a
build without tracing, which is the repo's analogue of the paper's
1–2-cycles-per-call claim.  The raw event-record cost (``instant()``
ns/event, enabled vs paused) is reported alongside.

A fourth configuration (ISSUE 8) runs the same flag-hit loop on a live
session while the background **telemetry sampler** ticks every 1 ms:
the sampler reads occupancy/arena/link/tenant gauges from its own
thread and must leave the hot path alone — gated at the same ≤ 1.30×
its own sampler-off baseline under ``--smoke``.

Run:  PYTHONPATH=src python -m benchmarks.bench_overhead [--smoke]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit

REPEATS = 5
SMOKE_RATIO = 1.30


def _flag_loop_ns(ctx, hd, n_calls: int) -> float:
    """ns/call over n_calls flag-hit ensure() calls."""
    from repro.core.locations import HOST

    t0 = time.perf_counter()
    for _ in range(n_calls):
        ctx.ensure(hd, HOST)  # flag hit: no copy
    return (time.perf_counter() - t0) / n_calls * 1e9


def _median(xs) -> float:
    xs = sorted(xs)
    return xs[len(xs) // 2]


def _bench_flag_check(n_calls: int) -> dict:
    """Interleaved flag-check medians for the three tracer configs."""
    from repro.core.hete import HeteContext
    from repro.core.trace import TraceCollector

    ctx = HeteContext()
    hd = ctx.malloc((1024,), np.float32)
    tc = TraceCollector()
    samples = {"baseline": [], "traced": [], "paused": []}
    _flag_loop_ns(ctx, hd, n_calls)  # warmup
    for _ in range(REPEATS):
        ctx.set_tracer(None)
        samples["baseline"].append(_flag_loop_ns(ctx, hd, n_calls))
        ctx.set_tracer(tc)
        tc.resume()
        samples["traced"].append(_flag_loop_ns(ctx, hd, n_calls))
        tc.pause()
        samples["paused"].append(_flag_loop_ns(ctx, hd, n_calls))
    ctx.set_tracer(None)
    out = {k: _median(v) for k, v in samples.items()}
    out["flag_checks"] = ctx.ledger.flag_checks
    return out


def _bench_flag_check_sampled(n_calls: int) -> dict:
    """Flag-check medians on a live session, sampler off vs running
    (1 ms period).  The sampler reads from its own thread; the flag-hit
    path carries zero sampler instrumentation, so on ≈ off."""
    from repro.core.api import Session

    session = Session.emulated(n_cpu=1, accelerators=("gpu0",))
    ctx = session.context
    hd = ctx.malloc((1024,), np.float32)
    off, on = [], []
    _flag_loop_ns(ctx, hd, n_calls)  # warmup
    for _ in range(REPEATS):
        off.append(_flag_loop_ns(ctx, hd, n_calls))
        sampler = session.start_sampler(period=1e-3)
        on.append(_flag_loop_ns(ctx, hd, n_calls))
        sampler.stop()
        session.sampler = None  # a stopped sampler stays stopped
    n_samples = sampler.ticks
    session.close()
    session.runtime.close()
    return {"off": _median(off), "on": _median(on),
            "last_run_samples": n_samples}


def _bench_instant(n_events: int) -> dict:
    """Raw event-record cost: instant() ns/event, enabled vs paused."""
    from repro.core.trace import TraceCollector

    enabled, paused = [], []
    for _ in range(REPEATS):
        tc = TraceCollector(capacity_per_thread=n_events + 1)  # no drops
        t0 = time.perf_counter()
        for _ in range(n_events):
            tc.instant("e", "bench", "t")
        enabled.append((time.perf_counter() - t0) / n_events * 1e9)
        tc.pause()
        t0 = time.perf_counter()
        for _ in range(n_events):
            tc.instant("e", "bench", "t")
        paused.append((time.perf_counter() - t0) / n_events * 1e9)
    return {"enabled": _median(enabled), "paused": _median(paused)}


def run(n_calls: int = 1_000_000, *, smoke: bool = False) -> dict:
    flag = _bench_flag_check(n_calls)
    inst = _bench_instant(min(n_calls, 50_000))
    samp = _bench_flag_check_sampled(min(n_calls, 100_000))
    ns = flag["baseline"]
    cycles_1p2ghz = ns * 1.2
    ratio_traced = flag["traced"] / ns
    ratio_paused = flag["paused"] / ns
    ratio_sampled = samp["on"] / samp["off"]
    emit(
        "sec522_flag_check", ns / 1e3,
        f"ns_per_call={ns:.1f};cycles@1.2GHz={cycles_1p2ghz:.1f};"
        f"checks={flag['flag_checks']}",
    )
    emit(
        "trace_flag_check_traced", flag["traced"] / 1e3,
        f"ns_per_call={flag['traced']:.1f};x_baseline={ratio_traced:.3f}",
    )
    emit(
        "trace_flag_check_paused", flag["paused"] / 1e3,
        f"ns_per_call={flag['paused']:.1f};x_baseline={ratio_paused:.3f}",
    )
    emit(
        "trace_instant_enabled", inst["enabled"] / 1e3,
        f"ns_per_event={inst['enabled']:.1f}",
    )
    emit(
        "trace_instant_paused", inst["paused"] / 1e3,
        f"ns_per_event={inst['paused']:.1f}",
    )
    emit(
        "sampler_flag_check", samp["on"] / 1e3,
        f"ns_per_call={samp['on']:.1f};x_off={ratio_sampled:.3f};"
        f"samples={samp['last_run_samples']}",
    )
    if smoke:
        assert ratio_traced <= SMOKE_RATIO, (
            f"tracing-enabled flag check {ratio_traced:.2f}x baseline "
            f"(gate: <={SMOKE_RATIO}x — the flag-hit fast path must carry "
            f"no tracer instrumentation)"
        )
        assert ratio_paused <= SMOKE_RATIO, (
            f"tracing-paused flag check {ratio_paused:.2f}x baseline "
            f"(gate: <={SMOKE_RATIO}x)"
        )
        assert ratio_sampled <= SMOKE_RATIO, (
            f"sampler-enabled flag check {ratio_sampled:.2f}x its "
            f"sampler-off baseline (gate: <={SMOKE_RATIO}x — the sampler "
            f"must stay off the hot path)"
        )
        print(f"overhead smoke: OK (traced {ratio_traced:.2f}x, paused "
              f"{ratio_paused:.2f}x baseline of {ns:.0f} ns/call, "
              f"sampled {ratio_sampled:.2f}x)",
              flush=True)
    return {"flag": flag, "instant": inst, "sampled": samp,
            "ratio_traced": ratio_traced, "ratio_paused": ratio_paused,
            "ratio_sampled": ratio_sampled}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run gating tracer overhead ratios")
    ap.add_argument("--n-calls", type=int, default=None)
    args = ap.parse_args()
    n_calls = args.n_calls or (100_000 if args.smoke else 1_000_000)
    print("name,us_per_call,derived")
    run(n_calls, smoke=args.smoke)


if __name__ == "__main__":
    main()
