"""Streaming session throughput: N concurrent clients vs batch (ISSUE 4).

The acceptance benchmark for the session API redesign.  ``CLIENTS``
submitter threads each stream ``CHAINS`` radar 2FZF chains
(fft, fft → zip → ifft) against ONE :class:`repro.core.api.Session`;
every client pins its chains to one accelerator (clients round-robin
over the PEs), blocks only on its own ``BufferFuture.result()`` calls,
and the persistent WorkerPool consumes the interleaved stream with no
global barrier.  Three claims are checked:

* **bit-identical**: the streamed outputs equal, bitwise, a batch
  ``run_graph`` of the same chains on a fresh runtime — and the per-pair
  copy counts match exactly (the rimms policy does the same data
  movement whether tasks arrive as a stream or as a list);
* **throughput**: the stream's deterministic replayed modeled makespan
  (chains spread over all accelerators, transfers overlapping compute)
  beats the serial-batch baseline — modeled throughput ratio ≥ 1 is the
  acceptance floor, ~#accelerators× is the expectation;
* **determinism**: gated metrics are modeled (static pinned placement +
  the (ready-time, index)-ordered replay), so they are exact across
  machines and submission interleavings — per-PE workloads are fixed
  multisets of identical chains regardless of thread timing.

Emits ``BENCH_stream.json`` for the CI perf-regression gate.

Run:  PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import threading
from pathlib import Path

import numpy as np

from .common import emit

CLIENTS = 8
CHAINS = 8
N = 1 << 14
ACCELERATORS = ("gpu0", "gpu1")


def _chain_seed(client: int, chain: int) -> int:
    return 1000 + client * 97 + chain


def _stream_case(*, clients: int, chains: int, n: int, accelerators,
                 scheduler: str = "round_robin", pin: bool = True) -> dict:
    """N client threads stream pinned 2FZF chains against one session;
    returns outputs (client-major), ledger snapshot, replayed modeled
    makespan, and wall seconds."""
    from repro.apps.radar import make_session, submit_2fzf

    session = make_session(
        policy="rimms", scheduler=scheduler, n_cpu=0,
        accelerators=accelerators,
    )
    outs: dict = {}
    errors: list = []

    def client(c: int) -> None:
        try:
            pe = accelerators[c % len(accelerators)] if pin else None
            mine = []
            for k in range(chains):
                bufs = submit_2fzf(
                    session, n, pins=(pe,) * 4,
                    seed=_chain_seed(c, k), tag=f"_c{c}k{k}",
                )
                mine.append(bufs["out"])
            # block only on this client's own results (out of order is
            # fine — other clients' chains keep streaming meanwhile)
            outs[c] = [f.result(timeout=300) for f in mine]
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    session.ledger.reset()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    session.barrier()
    rep = session.report()
    snap = session.ledger.snapshot()
    out = np.stack([np.stack(outs[c]) for c in range(clients)])
    session.close()
    session.runtime.close()
    return {
        "wall_s": rep["wall_s"],
        "makespan_model": rep["makespan_model"],
        "copies": snap["total_copies"],
        "bytes": snap["total_bytes"],
        "by_pair": snap["by_pair"],
        "n_tasks": rep["n_tasks"],
        "_out": out,
    }


def _batch_case(mode: str, *, clients: int, chains: int, n: int,
                accelerators) -> dict:
    """The same chains as one batch task list (pins mirror the stream's
    per-client pinning) through serial run() or batch run_graph()."""
    from repro.apps.radar import build_2fzf, make_runtime
    from repro.core.hete import hete_sync

    rt, ctx = make_runtime(policy="rimms", scheduler="round_robin",
                           n_cpu=0, accelerators=accelerators)
    all_bufs, tasks = [], []
    for c in range(clients):
        pe = accelerators[c % len(accelerators)]
        row = []
        for k in range(chains):
            bufs, chain_tasks = build_2fzf(
                ctx, n, pins=(pe,) * 4, seed=_chain_seed(c, k))
            tasks += chain_tasks
            row.append(bufs)
        all_bufs.append(row)
    ctx.ledger.reset()
    wall = (rt.run if mode == "serial" else rt.run_graph)(tasks)
    out = np.stack([
        np.stack([hete_sync(bufs["out"], context=ctx) for bufs in row])
        for row in all_bufs
    ])
    # snapshot AFTER syncing outputs: the stream's result() syncs land
    # inside its measured window, so count the batch ones symmetrically
    snap = ctx.ledger.snapshot()
    makespan = rt.last_makespan_model
    rt.close()
    return {
        "wall_s": wall,
        "makespan_model": makespan,
        "copies": snap["total_copies"],
        "bytes": snap["total_bytes"],
        "by_pair": snap["by_pair"],
        "_out": out,
    }


def run_stream(*, clients: int, chains: int, n: int, json_path, smoke) -> dict:
    accs = ACCELERATORS
    stream = _stream_case(clients=clients, chains=chains, n=n,
                          accelerators=accs)
    batch = _batch_case("graph", clients=clients, chains=chains, n=n,
                        accelerators=accs)
    serial = _batch_case("serial", clients=clients, chains=chains, n=n,
                         accelerators=accs)

    identical = bool(np.array_equal(stream["_out"], batch["_out"]))
    copies_match = stream["by_pair"] == batch["by_pair"]
    throughput_x = serial["makespan_model"] / max(stream["makespan_model"],
                                                 1e-12)

    emit(
        "stream_session", stream["wall_s"] * 1e6,
        f"model_ms={stream['makespan_model'] * 1e3:.3f};"
        f"clients={clients};chains={chains};copies={stream['copies']};"
        f"throughput_vs_serial={throughput_x:.2f}x",
    )
    emit(
        "stream_batch_graph", batch["wall_s"] * 1e6,
        f"model_ms={batch['makespan_model'] * 1e3:.3f};"
        f"copies={batch['copies']}",
    )
    emit(
        "stream_serial_baseline", serial["wall_s"] * 1e6,
        f"model_ms={serial['makespan_model'] * 1e3:.3f};"
        f"copies={serial['copies']}",
    )

    rec = {
        "bench": "stream",
        "params": {"clients": clients, "chains": chains, "n": n,
                   "accelerators": list(accs)},
        "stream": {k: v for k, v in stream.items()
                   if k not in ("_out", "by_pair")},
        "batch_graph": {k: v for k, v in batch.items()
                        if k not in ("_out", "by_pair")},
        "serial": {k: v for k, v in serial.items()
                   if k not in ("_out", "by_pair")},
        "bit_identical": identical,
        "copies_match": bool(copies_match),
        "throughput_vs_serial": throughput_x,
        # Regression-gated metrics: modeled + deterministic (pinned
        # placement; replay orders by (ready time, index); per-PE work
        # is a fixed multiset of identical chains).
        "gate": {
            "makespan_model": stream["makespan_model"],
            "copies": stream["copies"],
        },
    }

    if smoke:
        assert identical, "streamed outputs differ from batch run_graph"
        assert copies_match, (
            f"stream copy counts differ from batch run_graph: "
            f"{stream['by_pair']} vs {batch['by_pair']}"
        )
        assert throughput_x >= 1.0, (
            f"stream modeled throughput only {throughput_x:.2f}x the "
            f"serial-batch baseline (acceptance: >=1x)"
        )
        print(f"stream smoke: OK ({clients} clients, "
              f"{throughput_x:.2f}x serial throughput, "
              f"copies match batch)", flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def run(clients: int = CLIENTS, chains: int = CHAINS, n: int = N) -> None:
    run_stream(clients=clients, chains=chains, n=n, json_path=None,
               smoke=False)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with bit-identity + copy-count + "
                         "throughput asserts")
    ap.add_argument("--json", default="BENCH_stream.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--chains", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    args = ap.parse_args()
    clients = args.clients or (4 if args.smoke else CLIENTS)
    chains = args.chains or (6 if args.smoke else CHAINS)
    n = args.n or (1 << 13 if args.smoke else N)
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "stream"):
        run_stream(clients=clients, chains=chains, n=n,
                   json_path=args.json or None, smoke=args.smoke)


if __name__ == "__main__":
    main()
