"""Streaming session throughput: N concurrent clients vs batch (ISSUE 4).

The acceptance benchmark for the session API redesign.  ``CLIENTS``
submitter threads each stream ``CHAINS`` radar 2FZF chains
(fft, fft → zip → ifft) against ONE :class:`repro.core.api.Session`;
every client pins its chains to one accelerator (clients round-robin
over the PEs), blocks only on its own ``BufferFuture.result()`` calls,
and the persistent WorkerPool consumes the interleaved stream with no
global barrier.  Three claims are checked:

* **bit-identical**: the streamed outputs equal, bitwise, a batch
  ``run_graph`` of the same chains on a fresh runtime — and the per-pair
  copy counts match exactly (the rimms policy does the same data
  movement whether tasks arrive as a stream or as a list);
* **throughput**: the stream's deterministic replayed modeled makespan
  (chains spread over all accelerators, transfers overlapping compute)
  beats the serial-batch baseline — modeled throughput ratio ≥ 1 is the
  acceptance floor, ~#accelerators× is the expectation;
* **determinism**: gated metrics are modeled (static pinned placement +
  the (ready-time, index)-ordered replay), so they are exact across
  machines and submission interleavings — per-PE workloads are fixed
  multisets of identical chains regardless of thread timing.

Emits ``BENCH_stream.json`` for the CI perf-regression gate.

With ``--backend process`` (ISSUE 7) the stream case executes kernels in
subprocess PE workers against shared-memory host arenas; the record then
adds **measured wall-clock** speedups — ``wall_speedup_vs_serial``
(gated ≥ baseline on runners with ≥ 4 cores, skipped below) and
``wall_speedup_vs_thread`` (reported) — plus a bitwise identity check
against the thread-backend stream.  Modeled gates are identical across
backends by construction (static priors + deterministic replay).

Run:  PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time
from pathlib import Path

import numpy as np

from .common import emit

CLIENTS = 8
CHAINS = 8
N = 1 << 14
N_PROCESS = 1 << 15  # compute-dominant sizes for wall-clock comparisons
ACCELERATORS = ("gpu0", "gpu1")

# Wall-clock gates need real cores: on fewer the process backend cannot
# be expected to beat in-process serial, so the gate is marked skipped.
MIN_CORES_FOR_WALL_GATE = 4


def _chain_seed(client: int, chain: int) -> int:
    return 1000 + client * 97 + chain


def _stream_case(*, clients: int, chains: int, n: int, accelerators,
                 scheduler: str = "round_robin", pin: bool = True,
                 backend=None, warm: bool = False) -> dict:
    """N client threads stream pinned 2FZF chains against one session;
    returns outputs (client-major), ledger snapshot, replayed modeled
    makespan, and wall seconds."""
    from repro.apps.radar import make_session, submit_2fzf

    session = make_session(
        policy="rimms", scheduler=scheduler, n_cpu=0,
        accelerators=accelerators, backend=backend,
    )
    if warm:
        # One pinned chain per accelerator: spawns process workers,
        # pays jit compiles at shape n, and first-touch staging — the
        # measured window below is then steady-state.  (Thread-backend
        # default runs stay warmup-free so their modeled record matches
        # the committed BENCH_stream.json baseline exactly.)
        warm_futs = [
            submit_2fzf(session, n, pins=(pe,) * 4, seed=7,
                        tag=f"_warm{i}")["out"]
            for i, pe in enumerate(accelerators)
        ]
        for f in warm_futs:
            f.result(timeout=600)
    outs: dict = {}
    errors: list = []

    def client(c: int) -> None:
        try:
            pe = accelerators[c % len(accelerators)] if pin else None
            mine = []
            for k in range(chains):
                bufs = submit_2fzf(
                    session, n, pins=(pe,) * 4,
                    seed=_chain_seed(c, k), tag=f"_c{c}k{k}",
                )
                mine.append(bufs["out"])
            # block only on this client's own results (out of order is
            # fine — other clients' chains keep streaming meanwhile)
            outs[c] = [f.result(timeout=300) for f in mine]
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    session.ledger.reset()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    session.barrier()
    wall_meas = time.perf_counter() - t0
    rep = session.report()
    snap = session.ledger.snapshot()
    out = np.stack([np.stack(outs[c]) for c in range(clients)])
    session.close()
    divergence = session.runtime.divergence.table()
    session.runtime.close()
    return {
        "divergence": divergence,
        "wall_s": rep["wall_s"],
        # submit→drain window only (excludes session startup + warmup;
        # rep["wall_s"] counts from executor construction)
        "wall_meas_s": wall_meas,
        "makespan_model": rep["makespan_model"],
        "copies": snap["total_copies"],
        "bytes": snap["total_bytes"],
        "by_pair": snap["by_pair"],
        "n_tasks": rep["n_tasks"],
        "_out": out,
    }


def _batch_case(mode: str, *, clients: int, chains: int, n: int,
                accelerators, backend=None, warm: bool = False) -> dict:
    """The same chains as one batch task list (pins mirror the stream's
    per-client pinning) through serial run() or batch run_graph()."""
    from repro.apps.radar import build_2fzf, make_runtime
    from repro.core.hete import hete_sync

    rt, ctx = make_runtime(policy="rimms", scheduler="round_robin",
                           n_cpu=0, accelerators=accelerators,
                           backend=backend)
    # internal calls → private impls (the run/run_graph deprecation
    # warning is for user code migrating to Session)
    impl = rt._run_impl if mode == "serial" else rt._run_graph_impl
    if warm:
        # jit compiles + first-touch on throwaway buffers, so the
        # measured run below is steady-state wall (its per-buffer copy
        # counts are untouched: the warm chains are separate mallocs)
        warm_tasks = []
        for i, pe in enumerate(accelerators):
            _, wt = build_2fzf(ctx, n, pins=(pe,) * 4, seed=7)
            warm_tasks += wt
        impl(warm_tasks)
    all_bufs, tasks = [], []
    for c in range(clients):
        pe = accelerators[c % len(accelerators)]
        row = []
        for k in range(chains):
            bufs, chain_tasks = build_2fzf(
                ctx, n, pins=(pe,) * 4, seed=_chain_seed(c, k))
            tasks += chain_tasks
            row.append(bufs)
        all_bufs.append(row)
    ctx.ledger.reset()
    wall = impl(tasks)
    out = np.stack([
        np.stack([hete_sync(bufs["out"], context=ctx) for bufs in row])
        for row in all_bufs
    ])
    # snapshot AFTER syncing outputs: the stream's result() syncs land
    # inside its measured window, so count the batch ones symmetrically
    snap = ctx.ledger.snapshot()
    makespan = rt.last_makespan_model
    rt.close()
    return {
        "wall_s": wall,
        "makespan_model": makespan,
        "copies": snap["total_copies"],
        "bytes": snap["total_bytes"],
        "by_pair": snap["by_pair"],
        "_out": out,
    }


def run_stream(*, clients: int, chains: int, n: int, json_path, smoke,
               backend: str = "thread") -> dict:
    from repro.core.runtime import resolve_backend

    backend = resolve_backend(backend)
    proc = backend == "process"
    accs = ACCELERATORS
    stream = _stream_case(clients=clients, chains=chains, n=n,
                          accelerators=accs, backend=backend, warm=proc)
    # batch + serial baselines always run in-process (thread backend):
    # serial wall is THE wall-clock reference the process backend must
    # beat, and batch-graph outputs double as the cross-backend
    # bit-identity reference.
    batch = _batch_case("graph", clients=clients, chains=chains, n=n,
                        accelerators=accs)
    serial = _batch_case("serial", clients=clients, chains=chains, n=n,
                         accelerators=accs, warm=proc)
    stream_thread = None
    if proc:
        stream_thread = _stream_case(clients=clients, chains=chains, n=n,
                                     accelerators=accs, backend="thread",
                                     warm=True)

    identical = bool(np.array_equal(stream["_out"], batch["_out"]))
    copies_match = stream["by_pair"] == batch["by_pair"]
    throughput_x = serial["makespan_model"] / max(stream["makespan_model"],
                                                 1e-12)

    emit(
        "stream_session", stream["wall_s"] * 1e6,
        f"model_ms={stream['makespan_model'] * 1e3:.3f};"
        f"clients={clients};chains={chains};copies={stream['copies']};"
        f"throughput_vs_serial={throughput_x:.2f}x",
    )
    emit(
        "stream_batch_graph", batch["wall_s"] * 1e6,
        f"model_ms={batch['makespan_model'] * 1e3:.3f};"
        f"copies={batch['copies']}",
    )
    emit(
        "stream_serial_baseline", serial["wall_s"] * 1e6,
        f"model_ms={serial['makespan_model'] * 1e3:.3f};"
        f"copies={serial['copies']}",
    )

    rec = {
        "bench": "stream",
        "backend": backend,
        "params": {"clients": clients, "chains": chains, "n": n,
                   "accelerators": list(accs)},
        "stream": {k: v for k, v in stream.items()
                   if k not in ("_out", "by_pair", "divergence")},
        # Wall/modeled calibration table from the stream case (ISSUE 8):
        # one cell per (span kind, op, PE kind, shape bucket).
        "divergence": stream["divergence"],
        "batch_graph": {k: v for k, v in batch.items()
                        if k not in ("_out", "by_pair")},
        "serial": {k: v for k, v in serial.items()
                   if k not in ("_out", "by_pair")},
        "bit_identical": identical,
        "copies_match": bool(copies_match),
        "throughput_vs_serial": throughput_x,
        # Regression-gated metrics: modeled + deterministic (pinned
        # placement; replay orders by (ready time, index); per-PE work
        # is a fixed multiset of identical chains).
        "gate": {
            "makespan_model": stream["makespan_model"],
            "copies": stream["copies"],
        },
    }

    if proc:
        wall_vs_serial = serial["wall_s"] / max(stream["wall_meas_s"], 1e-12)
        wall_vs_thread = (stream_thread["wall_meas_s"]
                          / max(stream["wall_meas_s"], 1e-12))
        identical_thread = bool(np.array_equal(stream["_out"],
                                               stream_thread["_out"]))
        rec["wall_speedup_vs_serial"] = wall_vs_serial
        rec["wall_speedup_vs_thread"] = wall_vs_thread
        rec["bit_identical_vs_thread"] = identical_thread
        # The wall gate is real measured time, gated as higher-is-better
        # (direction "min": FAIL below baseline*(1-tol)) — but only on
        # runners with enough cores to make the comparison meaningful.
        rec["gate_directions"] = {"wall_speedup_vs_serial": "min"}
        rec["gate_tolerances"] = {"wall_speedup_vs_serial": 0.0}
        if (os.cpu_count() or 1) >= MIN_CORES_FOR_WALL_GATE:
            rec["gate"]["wall_speedup_vs_serial"] = wall_vs_serial
        else:
            rec["gate_skipped"] = ["wall_speedup_vs_serial"]
        emit(
            "stream_process_wall", stream["wall_meas_s"] * 1e6,
            f"vs_serial={wall_vs_serial:.2f}x;vs_thread={wall_vs_thread:.2f}x;"
            f"cores={os.cpu_count()};bit_identical_vs_thread="
            f"{identical_thread}",
        )

    if smoke:
        import math

        compute_ratios = [
            c["ema_ratio"] for c in stream["divergence"].values()
            if c["kind"] == "compute" and c["count"] > 0
        ]
        assert any(r is not None and r > 0 and math.isfinite(r)
                   for r in compute_ratios), (
            f"divergence table has no (op, PE kind) compute cell with a "
            f"finite positive wall/modeled ratio: {stream['divergence']}"
        )
        assert identical, "streamed outputs differ from batch run_graph"
        assert copies_match, (
            f"stream copy counts differ from batch run_graph: "
            f"{stream['by_pair']} vs {batch['by_pair']}"
        )
        assert throughput_x >= 1.0, (
            f"stream modeled throughput only {throughput_x:.2f}x the "
            f"serial-batch baseline (acceptance: >=1x)"
        )
        if proc:
            assert rec["bit_identical_vs_thread"], (
                "process-backend stream outputs differ bitwise from the "
                "thread-backend stream"
            )
            assert stream["by_pair"] == stream_thread["by_pair"], (
                f"process copy counts differ from thread: "
                f"{stream['by_pair']} vs {stream_thread['by_pair']}"
            )
        print(f"stream smoke: OK ({clients} clients, backend={backend}, "
              f"{throughput_x:.2f}x serial throughput, "
              f"copies match batch)", flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def run(clients: int = CLIENTS, chains: int = CHAINS, n: int = N,
        backend: str = "thread") -> None:
    run_stream(clients=clients, chains=chains, n=n, json_path=None,
               smoke=False, backend=backend)


def main() -> None:
    from repro.core.runtime import BACKENDS, resolve_backend

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with bit-identity + copy-count + "
                         "throughput asserts")
    ap.add_argument("--json", default="BENCH_stream.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--backend", default="thread", choices=BACKENDS,
                    help="kernel-execution backend for the stream case "
                         "(process adds wall-clock speedup metrics vs the "
                         "in-process serial + thread baselines)")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--chains", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_*.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    backend = resolve_backend(args.backend)
    clients = args.clients or (4 if args.smoke else CLIENTS)
    chains = args.chains or (6 if args.smoke else CHAINS)
    # process smoke uses compute-dominant sizes: at tiny n the pipe
    # round-trip dominates and wall comparisons measure only overhead
    n = args.n or ((N_PROCESS if backend == "process" else 1 << 13)
                   if args.smoke else N)
    print("name,us_per_call,derived")
    from .common import tracing

    trace_name = "stream" if backend == "thread" else f"stream_{backend}"
    with tracing(args.trace_dir, trace_name, metrics_dir=args.metrics_dir):
        run_stream(clients=clients, chains=chains, n=n,
                   json_path=args.json or None, smoke=args.smoke,
                   backend=backend)


if __name__ == "__main__":
    main()
