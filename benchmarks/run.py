"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

``--json-dir DIR`` additionally emits ``BENCH_*.json`` records (full
depth) for the json-capable benches — the nightly CI workflow uploads
them and feeds them to ``check_regression.py --report`` so modeled-
metric drift is visible between PRs, not only at gate-failure time.
"""

import argparse
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: 2fft,2fzf,alloc,overhead,3zip,apps,"
                         "marking,roofline,graph,pressure,topology,stream,"
                         "multitenant,serve,calibrate")
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write BENCH_*.json records for json-capable "
                         "benches into DIR")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto TRACE_*.json per "
                         "benchmark into DIR (ISSUE 6)")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write METRICS_*.json (wall/modeled divergence "
                         "tables) per benchmark into DIR (ISSUE 8; "
                         "requires --trace-dir)")
    args = ap.parse_args()
    from . import (bench_2fft, bench_2fzf, bench_3zip, bench_alloc,
                   bench_apps, bench_calibrate, bench_graph,
                   bench_marking, bench_multitenant, bench_overhead,
                   bench_pressure, bench_roofline, bench_serve,
                   bench_stream, bench_topology)

    def graph(jp):
        bench_graph.run()
        if jp:  # the graph record is the (deterministic) smoke gate's
            bench_graph.smoke(json_path=jp)

    benches = {
        "alloc": lambda jp: bench_alloc.run(),
        "overhead": lambda jp: bench_overhead.run(n_calls=200_000),
        "2fft": lambda jp: bench_2fft.run(),
        "2fzf": lambda jp: bench_2fzf.run(),
        "3zip": lambda jp: bench_3zip.run(),
        "apps": lambda jp: bench_apps.run(),
        "marking": lambda jp: bench_marking.run(),
        "roofline": lambda jp: bench_roofline.run(),
        "graph": graph,
        "pressure": lambda jp: bench_pressure.run_pressure(
            ways=8, n=1 << 14, json_path=jp, smoke=False),
        "topology": lambda jp: bench_topology.run_topology(
            ways=bench_topology.WAYS, n=bench_topology.N,
            depth=bench_topology.DEPTH, json_path=jp, smoke=False),
        "stream": lambda jp: bench_stream.run_stream(
            clients=bench_stream.CLIENTS, chains=bench_stream.CHAINS,
            n=bench_stream.N, json_path=jp, smoke=False),
        "multitenant": lambda jp: bench_multitenant.run_multitenant(
            n=bench_multitenant.N,
            light_chains=bench_multitenant.LIGHT_CHAINS,
            heavy_chains=bench_multitenant.HEAVY_CHAINS,
            json_path=jp, smoke=False),
        "serve": lambda jp: bench_serve.run_serve(
            n_users=bench_serve.N_USERS,
            reqs_per_user=bench_serve.REQS_PER_USER,
            json_path=jp, smoke=False),
        "calibrate": lambda jp: bench_calibrate.run_calibrate(
            json_path=jp, smoke=False),
    }
    json_names = {
        "graph": "BENCH_graph.json",
        "pressure": "BENCH_pressure.json",
        "topology": "BENCH_topology.json",
        "stream": "BENCH_stream.json",
        "multitenant": "BENCH_multitenant.json",
        "serve": "BENCH_serve.json",
        "calibrate": "BENCH_calibrate.json",
    }
    only = set(args.only.split(",")) if args.only else None
    json_dir = Path(args.json_dir) if args.json_dir else None
    if json_dir:
        json_dir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    from .common import tracing

    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        jp = (str(json_dir / json_names[name])
              if json_dir and name in json_names else None)
        with tracing(args.trace_dir, name, metrics_dir=args.metrics_dir):
            fn(jp)


if __name__ == "__main__":
    main()
