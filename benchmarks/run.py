"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: 2fft,2fzf,alloc,overhead,3zip,apps,"
                         "marking,roofline,graph,pressure,topology,stream")
    args = ap.parse_args()
    from . import (bench_2fft, bench_2fzf, bench_3zip, bench_alloc,
                   bench_apps, bench_graph, bench_marking, bench_overhead,
                   bench_pressure, bench_roofline, bench_stream,
                   bench_topology)
    benches = {
        "alloc": bench_alloc.run,
        "overhead": lambda: bench_overhead.run(n_calls=200_000),
        "2fft": bench_2fft.run,
        "2fzf": bench_2fzf.run,
        "3zip": bench_3zip.run,
        "apps": bench_apps.run,
        "marking": bench_marking.run,
        "roofline": bench_roofline.run,
        "graph": bench_graph.run,
        "pressure": lambda: bench_pressure.run_pressure(
            ways=8, n=1 << 14, json_path=None, smoke=False),
        "topology": bench_topology.run,
        "stream": bench_stream.run,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn()


if __name__ == "__main__":
    main()
