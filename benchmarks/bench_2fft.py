"""Paper Fig 5 (ZCU102) / Fig 6 (Jetson): 2FFT reference vs RIMMS.

Scenarios: CPU-ACC (first FFT on CPU, second on accelerator) and
ACC-ACC (both on the same accelerator).  The paper's structural claim —
RIMMS eliminates 1 copy in CPU-ACC and 3 copies in ACC-ACC — is asserted
exactly from the transfer ledger; wall / modeled times are reported per
size 64..2048.
"""

from __future__ import annotations

import functools

from .common import emit, run_app

SIZES = (64, 128, 256, 512, 1024, 2048)


def run(repeats: int = 5) -> None:
    from repro.apps.radar import build_2fft

    for scen, pins in (("cpu_acc", ("cpu0", "gpu0")),
                       ("acc_acc", ("gpu0", "gpu0"))):
        for n in SIZES:
            res = {}
            for policy in ("reference", "rimms"):
                builder = functools.partial(build_2fft, n=n, pins=pins)
                res[policy] = run_app(
                    lambda ctx, n=n: build_2fft(ctx, n, pins=pins),
                    policy=policy, repeats=repeats,
                )
            ref, rim = res["reference"], res["rimms"]
            eliminated = ref["copies"] - rim["copies"]
            expect = 1 if scen == "cpu_acc" else 3
            ok = "OK" if abs(eliminated - expect) < 1e-9 else "MISMATCH"
            emit(
                f"fig5_2fft_{scen}_n{n}",
                rim["wall_s"] * 1e6,
                f"ref_us={ref['wall_s']*1e6:.1f};copies {ref['copies']:.0f}->"
                f"{rim['copies']:.0f} (-{eliminated:.0f} expect {expect} {ok});"
                f"modeled_spdup={ref['modeled_s']/max(rim['modeled_s'],1e-12):.2f}x",
            )


if __name__ == "__main__":
    run()
