"""Measured calibration + Pallas autotuning acceptance bench (ISSUE 10).

Two claims are checked, one per half of the tentpole:

* **Calibrated placement** (part A, fully deterministic): on an
  emulated platform whose *measured* throughputs invert the
  ``CostModel`` priors (the priors claim the GPU is the fastest kind;
  the synthetic "truth" calibration says the GPU is slow and the
  fixed-function accelerators fast), a static HEFT plan built from the
  calibrated model must cost no more than the prior-built plan when
  both are priced under the truth model.  Nothing executes — both
  plans come from :func:`repro.core.calibrate.heft_plan` and are priced
  by :func:`~repro.core.calibrate.simulate_plan`, so the gated ratio
  ``calibrated_vs_prior_makespan`` is exact across machines.

* **Autotuned variants** (part B, measured): a live
  :func:`repro.core.autotune.autotune` pass over the Pallas launch
  parameters must find at least one non-default variant winning with a
  measured speedup ≥ 1.0 over the baked-in default
  (``nondefault_winners`` / ``winner_speedup``, both gated as lower
  bounds), and dispatching the winning op through a calibrated session
  must (a) select the winner (``Runtime.variant_log``) and (b) produce
  output bit-identical to the default variant.

Emits ``BENCH_calibrate.json`` for the CI perf-regression gate.

Run:  PYTHONPATH=src python -m benchmarks.bench_calibrate [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

#: part A workload: unpinned 2FZF chains at these sizes (complex64 n)
PLAN_SIZES = (1 << 12, 1 << 14, 1 << 16)
PLAN_CHAINS = 6
#: truth throughputs (bytes/s) for the synthetic calibration table —
#: deliberately inverting the CostModel priors (gpu 1.6e10 → slow,
#: acc 8e9 → fastest)
TRUE_THROUGHPUT = {"cpu": 1.0e9, "acc": 1.6e10, "gpu": 0.8e9}
#: buckets the truth table covers (must span every task's in_bytes)
TRUTH_LADDER = tuple(1 << p for p in range(12, 23))

AUTOTUNE_LADDER = (64 << 10, 1 << 20)
AUTOTUNE_LADDER_SMOKE = (64 << 10,)


def _truth_table():
    """Synthetic measured truth: linear-in-bytes timings from
    TRUE_THROUGHPUT, one cell per (op, kind, bucket)."""
    from repro.core.calibrate import CalibrationTable
    from repro.core.graph import CostModel

    table = CalibrationTable()
    table.meta["synthetic"] = "bench_calibrate part A truth model"
    for op in ("fft", "ifft", "zip"):
        w = CostModel.OP_WEIGHT.get(op, 2.0)
        for kind, thr in TRUE_THROUGHPUT.items():
            for nb in TRUTH_LADDER:
                s = CostModel.LAUNCH_LATENCY_S + nb * w / thr
                table.record(op, "default", kind, nb, s)
    return table


def run_plan_gate() -> dict:
    """Part A: prior-HEFT vs calibrated-HEFT, both priced under truth."""
    from repro.apps.radar import build_2fzf, make_runtime
    from repro.core.calibrate import heft_plan, simulate_plan
    from repro.core.graph import CostModel

    rt, ctx = make_runtime(
        policy="rimms", scheduler="heft", n_cpu=1,
        accelerators=("gpu0", "fft_acc0", "zip_acc0"),
    )
    try:
        tasks = []
        for i in range(PLAN_CHAINS):
            n = PLAN_SIZES[i % len(PLAN_SIZES)]
            _, chain = build_2fzf(ctx, n, pins=(None,) * 4, seed=100 + i)
            tasks += chain

        truth = _truth_table()
        prior_cm = CostModel()                  # BASE_THROUGHPUT priors
        calib_cm = CostModel(calibration=truth)  # measured truth attached

        prior_plan = heft_plan(rt, tasks, cost_model=prior_cm)
        calib_plan = heft_plan(rt, tasks, cost_model=calib_cm)
        # price BOTH plans under the truth model — plan quality, not
        # model optimism, is what's compared
        prior_cost = simulate_plan(rt, tasks, prior_plan, cost_model=calib_cm)
        calib_cost = simulate_plan(rt, tasks, calib_plan, cost_model=calib_cm)
    finally:
        rt.close()
    ratio = calib_cost / max(prior_cost, 1e-12)

    def _spread(plan):
        names = sorted(set(plan))
        return {pe: plan.count(pe) for pe in names}

    emit(
        "calibrate_plan_gate", calib_cost * 1e6,
        f"prior_ms={prior_cost * 1e3:.3f};calib_ms={calib_cost * 1e3:.3f};"
        f"ratio={ratio:.3f};tasks={len(tasks)}",
    )
    return {
        "n_tasks": len(tasks),
        "prior_plan_makespan_s": prior_cost,
        "calibrated_plan_makespan_s": calib_cost,
        "calibrated_vs_prior_makespan": ratio,
        "prior_plan_spread": _spread(prior_plan),
        "calibrated_plan_spread": _spread(calib_plan),
    }


def run_autotune_gate(*, smoke: bool) -> dict:
    """Part B: live autotune; ≥1 non-default winner with speedup ≥ 1,
    winner dispatch + bit-identity through a calibrated session."""
    from repro.core.api import OpRegistry, Session
    from repro.core.autotune import tunables, tuned_summary
    from repro.core.calibrate import DEFAULT_VARIANT

    ladder = AUTOTUNE_LADDER_SMOKE if smoke else AUTOTUNE_LADDER
    reg = OpRegistry()
    session = Session.emulated(n_cpu=1, accelerators=(), registry=reg)
    try:
        from repro.core.autotune import autotune

        table = autotune(session, nbytes=ladder, k=5, warmup=2, seed=0)
        tuned = tuned_summary(table)
        nondefault = {key: win for key, win in tuned.items()
                      if win["variant"] != DEFAULT_VARIANT}
        winner_speedup = max(
            (win["speedup"] for win in nondefault.values()), default=1.0)

        # dispatch check: run the best non-default winner through the
        # calibrated session; the runtime must select the winner variant
        # and its output must be bit-identical to the default's.
        dispatch = None
        single_out = {t.op: t for t in tunables() if t.op != "rg_lru"}
        candidates = [(key, win) for key, win in nondefault.items()
                      if key.split("/")[0] in single_out
                      and key.split("/")[1] == "cpu"]
        if candidates:
            from repro.core.telemetry import shape_bucket

            key, win = max(candidates, key=lambda kv: kv[1]["speedup"])
            op_name, _kind, bucket = key.split("/")
            tun = single_out[op_name]
            # regenerate the calibration inputs for the winning bucket
            nb, ins = ladder[0], None
            for n in ladder:
                rng = np.random.default_rng([0, int(n)])
                made = [np.asarray(a) for a in tun.make_inputs(rng, int(n))]
                if shape_bucket(sum(a.nbytes for a in made)) == bucket:
                    nb, ins = n, made
                    break
            assert ins is not None, (key, ladder)
            session.runtime.reset_stats()
            fut = session.submit(op_name, list(ins), name="dispatch_check")
            out = fut.result(timeout=300)
            session.barrier()
            log = [v for (o, _k, v) in session.runtime.variant_log
                   if o == op_name]
            ref = tun.fn(ins)[0]  # default launch params
            dispatch = {
                "op": op_name,
                "winner": win["variant"],
                "variant_log": log,
                "selected_winner": win["variant"] in log,
                "bit_identical": bool(
                    np.asarray(out).tobytes() == np.asarray(ref).tobytes()),
            }
    finally:
        session.close()

    emit(
        "calibrate_autotune", winner_speedup,
        f"nondefault_winners={len(nondefault)};"
        f"winners={sorted(w['variant'] for w in nondefault.values())};"
        f"ladder={list(ladder)}",
    )
    return {
        "ladder": list(ladder),
        "cells": len(table),
        "tuned_winners": tuned,
        "nondefault_winners": len(nondefault),
        "winner_speedup": winner_speedup,
        "dispatch": dispatch,
        "skipped_ops": table.meta.get("skipped_ops", []),
    }


def run_calibrate(*, json_path, smoke: bool) -> dict:
    plan = run_plan_gate()
    tune = run_autotune_gate(smoke=smoke)

    rec = {
        "bench": "calibrate",
        "plan": plan,
        "autotune": tune,
        # Gated metrics.  The plan ratio is fully deterministic (static
        # plans under synthetic truth).  The autotune gates are lower
        # bounds that hold by construction whenever autotuning works at
        # all: a non-default winner exists and its measured speedup is
        # >= 1 by the winner rule.
        "gate": {
            "calibrated_vs_prior_makespan":
                plan["calibrated_vs_prior_makespan"],
            "nondefault_winners": min(tune["nondefault_winners"], 1),
            "winner_speedup": min(tune["winner_speedup"], 1.0),
        },
        "gate_directions": {
            "nondefault_winners": "min",
            "winner_speedup": "min",
        },
        "gate_tolerances": {
            "calibrated_vs_prior_makespan": 0.0,
            "nondefault_winners": 0.0,
            "winner_speedup": 0.0,
        },
    }

    if smoke:
        assert plan["calibrated_vs_prior_makespan"] <= 1.0, (
            f"calibrated HEFT plan costs MORE than the prior plan under "
            f"the measured truth model: {plan}"
        )
        assert tune["nondefault_winners"] >= 1, (
            f"autotuning found no non-default variant winner: "
            f"{tune['tuned_winners']}"
        )
        assert tune["winner_speedup"] >= 1.0, tune
        if tune["dispatch"] is not None:
            assert tune["dispatch"]["selected_winner"], tune["dispatch"]
            assert tune["dispatch"]["bit_identical"], tune["dispatch"]
        print(
            f"calibrate smoke: OK (plan ratio "
            f"{plan['calibrated_vs_prior_makespan']:.3f}, "
            f"{tune['nondefault_winners']} non-default winner(s), "
            f"best speedup {tune['winner_speedup']:.2f}x)", flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def run(smoke: bool = False) -> None:
    run_calibrate(json_path=None, smoke=smoke)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with plan-ratio + winner asserts")
    ap.add_argument("--json", default="BENCH_calibrate.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_*.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "calibrate", metrics_dir=args.metrics_dir):
        run_calibrate(json_path=args.json or None, smoke=args.smoke)


if __name__ == "__main__":
    main()
