"""Serial vs async task-graph executor: makespan on fork-join DAGs.

For 1–4 emulated accelerators, runs the same fork-join workload
(shared source → parallel fft/zip branches → pairwise zip reduction)
through serial :meth:`Runtime.run` and the graph executor
:meth:`Runtime.run_graph`, and reports:

* measured wall seconds (honest but pessimistic on this box — every
  emulated PE shares one physical CPU, so threading adds overhead
  without adding FLOPs),
* **modeled makespan** — the schedule simulation under the platform
  :class:`BandwidthModel` + static compute estimates, identical cost
  basis for both modes, so the ratio isolates what the DAG scheduler
  buys: transfer/compute overlap and multi-PE concurrency,
* ledger copy counts (must match between modes under ``rimms`` with
  static scheduling — asserted in ``--smoke``).

Run:  PYTHONPATH=src python -m benchmarks.bench_graph [--smoke]
"""

from __future__ import annotations

import argparse

import numpy as np

from .common import emit

WAYS = 8
N = 1 << 15
DEPTH = 2


def _build(scheduler: str, accelerators, *, policy: str = "rimms",
           ways: int = WAYS, n: int = N, depth: int = DEPTH,
           backend=None):
    from repro.apps.radar import make_runtime
    from repro.apps.synthetic import build_fork_join

    rt, ctx = make_runtime(policy=policy, n_cpu=0,
                           accelerators=accelerators, scheduler=scheduler,
                           backend=backend)
    bufs, tasks = build_fork_join(ctx, ways=ways, n=n, depth=depth)
    return rt, ctx, bufs, tasks


def _measure(rt, ctx, tasks, mode: str, repeats: int):
    # internal calls → private impls (run/run_graph deprecation warnings
    # are for user code migrating to Session)
    run = rt._run_impl if mode == "serial" else rt._run_graph_impl
    run(tasks)  # warmup: jit compile, worker spawn, first-touch transfers
    ctx.ledger.reset()
    wall = model = float("inf")
    for _ in range(repeats):
        wall = min(wall, run(tasks))
        model = min(model, rt.last_makespan_model)
    copies = ctx.ledger.total_copies / repeats
    return wall, model, copies


def run(repeats: int = 3, ways: int = WAYS, n: int = N, depth: int = DEPTH) -> None:
    for n_acc in (1, 2, 3, 4):
        accs = tuple(f"gpu{i}" for i in range(n_acc))
        results = {}
        for mode, sched in (("serial", "round_robin"),
                            ("graph", "round_robin"),
                            ("graph", "heft")):
            rt, ctx, _, tasks = _build(sched, accs, ways=ways, n=n, depth=depth)
            results[(mode, sched)] = _measure(rt, ctx, tasks, mode, repeats)
        sw, sm, sc = results[("serial", "round_robin")]
        for mode, sched in (("graph", "round_robin"), ("graph", "heft")):
            gw, gm, gc = results[(mode, sched)]
            emit(
                f"graph_forkjoin_acc{n_acc}_{sched}", gw * 1e6,
                f"serial_wall_us={sw * 1e6:.1f};model_ms={gm * 1e3:.3f};"
                f"serial_model_ms={sm * 1e3:.3f};"
                f"model_speedup={sm / max(gm, 1e-12):.2f}x;"
                f"copies {sc:.0f}->{gc:.0f}",
            )


def smoke(json_path: str | None = None, backend: str = "thread") -> None:
    """CI gate: graph mode must (1) match serial outputs bitwise and
    copy-counts exactly under rimms/round_robin, and (2) beat the serial
    modeled makespan on a 2-accelerator fork-join workload.  With
    ``backend="process"`` the graph case runs on subprocess PE workers
    (ISSUE 7): the serial case stays in-process, making (1) a
    cross-backend bit-identity check, and the record additionally gates
    measured ``wall_speedup_vs_serial`` on runners with ≥ 4 cores."""
    import json
    import os
    from pathlib import Path

    from repro.core.hete import hete_sync

    proc = backend == "process"
    accs = ("gpu0", "gpu1")
    # process smoke uses compute-dominant sizes (pipe round-trips
    # dominate tiny problems) and one extra repeat for a stabler min
    ways, n, depth, repeats = (4, 1 << 15, 2, 3) if proc \
        else (4, 1 << 13, 2, 2)

    rt_s, ctx_s, bufs_s, tasks_s = _build("round_robin", accs,
                                          ways=ways, n=n, depth=depth)
    rt_g, ctx_g, bufs_g, tasks_g = _build("round_robin", accs,
                                          ways=ways, n=n, depth=depth,
                                          backend=backend)
    sw, sm, sc = _measure(rt_s, ctx_s, tasks_s, "serial", repeats)
    gw, gm, gc = _measure(rt_g, ctx_g, tasks_g, "graph", repeats)

    out_s = hete_sync(bufs_s["out"], context=ctx_s)
    out_g = hete_sync(bufs_g["out"], context=ctx_g)
    assert np.array_equal(out_s, out_g), "graph outputs differ from serial"
    assert ctx_s.ledger.snapshot()["by_pair"] == ctx_g.ledger.snapshot()["by_pair"], (
        "graph copy counts differ from serial under rimms/round_robin"
    )
    assert gm < sm, (
        f"graph modeled makespan {gm * 1e3:.3f} ms not below serial "
        f"{sm * 1e3:.3f} ms on a 2-accelerator fork-join"
    )
    rt_g.close()
    rt_s.close()
    emit("graph_smoke", gw * 1e6,
         f"backend={backend};model_speedup={sm / gm:.2f}x;"
         f"copies={gc:.0f};OK")
    if json_path:
        # Gated metrics are modeled (deterministic across machines):
        # static placement → exact copy counts and makespan arithmetic.
        rec = {
            "bench": "graph",
            "backend": backend,
            "params": {"ways": ways, "n": n, "depth": depth,
                       "accelerators": list(accs)},
            "serial": {"makespan_model": sm, "copies": sc, "wall_s": sw},
            "graph": {"makespan_model": gm, "copies": gc, "wall_s": gw},
            "model_speedup": sm / gm,
            "gate": {"makespan_model": gm, "copies": gc},
        }
        if proc:
            wall_vs_serial = sw / max(gw, 1e-12)
            rec["wall_speedup_vs_serial"] = wall_vs_serial
            rec["gate_directions"] = {"wall_speedup_vs_serial": "min"}
            rec["gate_tolerances"] = {"wall_speedup_vs_serial": 0.0}
            if (os.cpu_count() or 1) >= 4:
                rec["gate"]["wall_speedup_vs_serial"] = wall_vs_serial
            else:
                rec["gate_skipped"] = ["wall_speedup_vs_serial"]
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    print(f"graph smoke: OK (backend={backend})", flush=True)


def main() -> None:
    from repro.core.runtime import BACKENDS, resolve_backend

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with equivalence + speedup asserts")
    ap.add_argument("--json", default="BENCH_graph.json",
                    help="machine-readable smoke output path ('' to skip)")
    ap.add_argument("--backend", default="thread", choices=BACKENDS,
                    help="kernel-execution backend for the graph case")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_*.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    backend = resolve_backend(args.backend)
    print("name,us_per_call,derived")
    from .common import tracing

    trace_name = "graph" if backend == "thread" else f"graph_{backend}"
    with tracing(args.trace_dir, trace_name, metrics_dir=args.metrics_dir):
        if args.smoke:
            smoke(args.json or None, backend=backend)
        else:
            run()


if __name__ == "__main__":
    main()
