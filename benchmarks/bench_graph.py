"""Serial vs async task-graph executor: makespan on fork-join DAGs.

For 1–4 emulated accelerators, runs the same fork-join workload
(shared source → parallel fft/zip branches → pairwise zip reduction)
through serial :meth:`Runtime.run` and the graph executor
:meth:`Runtime.run_graph`, and reports:

* measured wall seconds (honest but pessimistic on this box — every
  emulated PE shares one physical CPU, so threading adds overhead
  without adding FLOPs),
* **modeled makespan** — the schedule simulation under the platform
  :class:`BandwidthModel` + static compute estimates, identical cost
  basis for both modes, so the ratio isolates what the DAG scheduler
  buys: transfer/compute overlap and multi-PE concurrency,
* ledger copy counts (must match between modes under ``rimms`` with
  static scheduling — asserted in ``--smoke``).

Run:  PYTHONPATH=src python -m benchmarks.bench_graph [--smoke]
"""

from __future__ import annotations

import argparse

import numpy as np

from .common import emit

WAYS = 8
N = 1 << 15
DEPTH = 2


def _build(scheduler: str, accelerators, *, policy: str = "rimms",
           ways: int = WAYS, n: int = N, depth: int = DEPTH):
    from repro.apps.radar import make_runtime
    from repro.apps.synthetic import build_fork_join

    rt, ctx = make_runtime(policy=policy, n_cpu=0,
                           accelerators=accelerators, scheduler=scheduler)
    bufs, tasks = build_fork_join(ctx, ways=ways, n=n, depth=depth)
    return rt, ctx, bufs, tasks


def _measure(rt, ctx, tasks, mode: str, repeats: int):
    run = rt.run if mode == "serial" else rt.run_graph
    run(tasks)  # warmup: jit compile + first-touch transfers
    ctx.ledger.reset()
    wall = model = float("inf")
    for _ in range(repeats):
        wall = min(wall, run(tasks))
        model = min(model, rt.last_makespan_model)
    copies = ctx.ledger.total_copies / repeats
    return wall, model, copies


def run(repeats: int = 3, ways: int = WAYS, n: int = N, depth: int = DEPTH) -> None:
    for n_acc in (1, 2, 3, 4):
        accs = tuple(f"gpu{i}" for i in range(n_acc))
        results = {}
        for mode, sched in (("serial", "round_robin"),
                            ("graph", "round_robin"),
                            ("graph", "heft")):
            rt, ctx, _, tasks = _build(sched, accs, ways=ways, n=n, depth=depth)
            results[(mode, sched)] = _measure(rt, ctx, tasks, mode, repeats)
        sw, sm, sc = results[("serial", "round_robin")]
        for mode, sched in (("graph", "round_robin"), ("graph", "heft")):
            gw, gm, gc = results[(mode, sched)]
            emit(
                f"graph_forkjoin_acc{n_acc}_{sched}", gw * 1e6,
                f"serial_wall_us={sw * 1e6:.1f};model_ms={gm * 1e3:.3f};"
                f"serial_model_ms={sm * 1e3:.3f};"
                f"model_speedup={sm / max(gm, 1e-12):.2f}x;"
                f"copies {sc:.0f}->{gc:.0f}",
            )


def smoke(json_path: str | None = None) -> None:
    """CI gate: graph mode must (1) match serial outputs bitwise and
    copy-counts exactly under rimms/round_robin, and (2) beat the serial
    modeled makespan on a 2-accelerator fork-join workload."""
    import json
    from pathlib import Path

    from repro.core.hete import hete_sync

    accs = ("gpu0", "gpu1")
    ways, n, depth, repeats = 4, 1 << 13, 2, 2

    rt_s, ctx_s, bufs_s, tasks_s = _build("round_robin", accs,
                                          ways=ways, n=n, depth=depth)
    rt_g, ctx_g, bufs_g, tasks_g = _build("round_robin", accs,
                                          ways=ways, n=n, depth=depth)
    sw, sm, sc = _measure(rt_s, ctx_s, tasks_s, "serial", repeats)
    gw, gm, gc = _measure(rt_g, ctx_g, tasks_g, "graph", repeats)

    out_s = hete_sync(bufs_s["out"], context=ctx_s)
    out_g = hete_sync(bufs_g["out"], context=ctx_g)
    assert np.array_equal(out_s, out_g), "graph outputs differ from serial"
    assert ctx_s.ledger.snapshot()["by_pair"] == ctx_g.ledger.snapshot()["by_pair"], (
        "graph copy counts differ from serial under rimms/round_robin"
    )
    assert gm < sm, (
        f"graph modeled makespan {gm * 1e3:.3f} ms not below serial "
        f"{sm * 1e3:.3f} ms on a 2-accelerator fork-join"
    )
    emit("graph_smoke", gw * 1e6,
         f"model_speedup={sm / gm:.2f}x;copies={gc:.0f};OK")
    if json_path:
        # Gated metrics are modeled (deterministic across machines):
        # static placement → exact copy counts and makespan arithmetic.
        rec = {
            "bench": "graph",
            "params": {"ways": ways, "n": n, "depth": depth,
                       "accelerators": list(accs)},
            "serial": {"makespan_model": sm, "copies": sc},
            "graph": {"makespan_model": gm, "copies": gc},
            "model_speedup": sm / gm,
            "gate": {"makespan_model": gm, "copies": gc},
        }
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    print("graph smoke: OK", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with equivalence + speedup asserts")
    ap.add_argument("--json", default="BENCH_graph.json",
                    help="machine-readable smoke output path ('' to skip)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "graph"):
        if args.smoke:
            smoke(args.json or None)
        else:
            run()


if __name__ == "__main__":
    main()
