"""Multi-tenant QoS: 1 heavy + 3 light clients on one session (ISSUE 5).

The acceptance benchmark for the QoS subsystem.  Three *light* clients
each stream ``K`` radar 2FZF chains in a closed loop (submit a chain,
wait for its result, submit the next) while one *heavy* client floods
``H`` chains open-loop against the SAME session on 2 emulated
accelerators.  The heavy client runs under a small backpressure window
and a low DRR weight; the lights keep default weight with a
one-chain-in-flight window.  Three claims are checked:

* **bounded interference**: the light clients' p95 per-chain *modeled*
  latency in the mix stays ≤ 2× their solo run (the same three lights
  without the heavy tenant).  Latencies come from the deterministic
  QoS replay (:func:`repro.core.qos.fair_replay` via
  ``Session.qos_report``), which re-enacts windows + weighted DRR
  admission in virtual time — so the metric depends only on each
  client's own submission order, never on thread interleaving, and is
  byte-identical across runs and machines;
* **bit-identical per chain**: every light chain's output in the mix
  equals, bitwise, the same chain in the solo run (same seeds — QoS
  changes *when* work runs, never *what* it computes);
* **fairness**: ``ledger.fairness_report()`` over the three equal-weight
  light clients reports a Jain's index ≥ 0.8 (they demand equal work,
  so equal service ⇒ index ≈ 1.0).

An *unbounded* variant (heavy client with an effectively infinite
window and full weight — FCFS admission, the pre-QoS behaviour) is also
run for the report, to show the interference QoS removes.

Emits ``BENCH_multitenant.json`` for the CI perf-regression gate; the
record carries per-metric ``gate_tolerances`` the gate honours.

Run:  PYTHONPATH=src python -m benchmarks.bench_multitenant [--smoke]
"""

from __future__ import annotations

import argparse
import json
import threading
from pathlib import Path

import numpy as np

from .common import emit

ACCELERATORS = ("gpu0", "gpu1")
N_LIGHTS = 3
LIGHT_CHAINS = 8
HEAVY_CHAINS = 64
N = 1 << 13
LIGHT_WINDOW = 4  # one chain in flight: the closed-loop pacing
HEAVY_WINDOW = 4
HEAVY_WEIGHT = 0.25
GLOBAL_WINDOW = 12  # the shared admission budget the DRR weights split
# Latency SLOs (ISSUE 8): the lights declare a loose objective no
# modeled latency can violate; the heavy tenant declares one below the
# 20us modeled launch floor, so every task violates it — the benchmark
# deterministically exercises both the clean and the breached paths of
# the burn-rate monitor.
LIGHT_SLO_LATENCY_S = 60.0
HEAVY_SLO_LATENCY_S = 10e-6
SLO_TARGET = 0.99


def _chain_seed(client: int, chain: int) -> int:
    return 5000 + client * 131 + chain


def _light_pin(c: int, k: int, accs) -> str:
    # lights 0/1 each own one accelerator; light 2 alternates per chain
    return accs[k % len(accs)] if c == 2 else accs[c % len(accs)]


def _tenant_case(*, n: int, light_chains: int, heavy_chains: int,
                 heavy_window: int, heavy_weight: float, accs,
                 include_heavy: bool, global_window=GLOBAL_WINDOW) -> dict:
    """Run the client mix against one session; returns per-chain light
    outputs/latencies (from the deterministic QoS replay), fairness, and
    ledger evidence."""
    from repro.apps.radar import make_session, submit_2fzf

    session = make_session(policy="rimms", scheduler="round_robin",
                           n_cpu=0, accelerators=accs,
                           global_window=global_window)
    light_names = [f"light{c}" for c in range(N_LIGHTS)]
    for name in light_names:
        session.client(name, weight=1.0, window=LIGHT_WINDOW,
                       slo_latency_s=LIGHT_SLO_LATENCY_S,
                       slo_target=SLO_TARGET)
    if include_heavy:
        session.client("heavy", weight=heavy_weight, window=heavy_window,
                       slo_latency_s=HEAVY_SLO_LATENCY_S,
                       slo_target=SLO_TARGET)

    outs: dict = {}
    nodes: dict = {}
    errors: list = []

    def light(c: int) -> None:
        # closed loop: one chain in flight, next submitted after result()
        try:
            rows, ids = [], []
            for k in range(light_chains):
                pe = _light_pin(c, k, accs)
                bufs = submit_2fzf(session, n, pins=(pe,) * 4,
                                   seed=_chain_seed(c, k), tag=f"_l{c}k{k}")
                rows.append(bufs["out"].result(timeout=300))
                ids.append((bufs["fa"].node, bufs["out"].node))
            outs[c] = rows
            nodes[c] = ids
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    def heavy() -> None:
        # open loop: submit everything ASAP; backpressure paces it
        try:
            for k in range(heavy_chains):
                pe = accs[k % len(accs)]
                submit_2fzf(session, n, pins=(pe,) * 4,
                            seed=_chain_seed(9, k), tag=f"_h{k}")
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    session.ledger.reset()
    threads = [threading.Thread(target=light, args=(c,), name=f"light{c}")
               for c in range(N_LIGHTS)]
    if include_heavy:
        threads.append(threading.Thread(target=heavy, name="heavy"))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    session.barrier()
    rep = session.report()
    qrep = session.qos_report()
    finish, release = qrep["finish_model"], qrep["release_model"]
    lats = {
        c: [finish[out_i] - release[fa_i] for fa_i, out_i in nodes[c]]
        for c in range(N_LIGHTS)
    }
    fairness = session.ledger.fairness_report(clients=light_names)
    snap = session.ledger.snapshot()
    session.close()
    divergence = session.runtime.divergence.table()
    session.runtime.close()
    return {
        "slo": qrep["slo"],
        "divergence": divergence,
        "wall_s": rep["wall_s"],
        "makespan_model": qrep["makespan_model"],
        "n_tasks": rep["n_tasks"],
        "n_completed": rep["n_completed"],
        "copies": snap["total_copies"],
        "jain_lights": fairness["jain_index"],
        "stall_s": {name: snap["client_tasks"].get(name, 0) and
                    fairness["clients"][name]["stall_s"]
                    for name in fairness["clients"]},
        # per-client per-*task* modeled-latency percentiles from the
        # session's histogram registry (ISSUE 6) — a different quantity
        # from the per-*chain* p95 the interference gate uses
        "latency_percentiles": qrep["latency_percentiles"],
        "_out": outs,
        "_lat": lats,
    }


def _p95(lats: dict) -> float:
    flat = [v for row in lats.values() for v in row]
    return float(np.percentile(np.asarray(flat, dtype=np.float64), 95))


def run_multitenant(*, n: int, light_chains: int, heavy_chains: int,
                    json_path, smoke: bool) -> dict:
    accs = ACCELERATORS
    kw = dict(n=n, light_chains=light_chains, heavy_chains=heavy_chains,
              accs=accs)
    solo = _tenant_case(heavy_window=HEAVY_WINDOW,
                        heavy_weight=HEAVY_WEIGHT, include_heavy=False, **kw)
    mix = _tenant_case(heavy_window=HEAVY_WINDOW,
                       heavy_weight=HEAVY_WEIGHT, include_heavy=True, **kw)
    # pre-QoS behaviour: FCFS admission, nothing bounds the heavy tenant
    unbounded = _tenant_case(heavy_window=4 * heavy_chains,
                             heavy_weight=1.0, include_heavy=True,
                             global_window=None, **kw)

    p95_solo, p95_mix = _p95(solo["_lat"]), _p95(mix["_lat"])
    p95_unbounded = _p95(unbounded["_lat"])
    ratio = p95_mix / max(p95_solo, 1e-12)
    ratio_unbounded = p95_unbounded / max(p95_solo, 1e-12)
    identical = all(
        np.array_equal(mix["_out"][c][k], solo["_out"][c][k])
        for c in range(N_LIGHTS) for k in range(light_chains)
    )

    emit(
        "multitenant_mix", mix["wall_s"] * 1e6,
        f"light_p95_ms={p95_mix * 1e3:.3f};x_solo={ratio:.2f};"
        f"jain={mix['jain_lights']:.3f};copies={mix['copies']}",
    )
    emit(
        "multitenant_solo", solo["wall_s"] * 1e6,
        f"light_p95_ms={p95_solo * 1e3:.3f}",
    )
    emit(
        "multitenant_unbounded", unbounded["wall_s"] * 1e6,
        f"light_p95_ms={p95_unbounded * 1e3:.3f};"
        f"x_solo={ratio_unbounded:.2f}",
    )

    strip = ("_out", "_lat", "divergence")
    rec = {
        "bench": "multitenant",
        "params": {
            "n": n, "light_chains": light_chains,
            "heavy_chains": heavy_chains, "n_lights": N_LIGHTS,
            "light_window": LIGHT_WINDOW, "heavy_window": HEAVY_WINDOW,
            "heavy_weight": HEAVY_WEIGHT, "global_window": GLOBAL_WINDOW,
            "accelerators": list(accs),
        },
        "mix": {k: v for k, v in mix.items() if k not in strip},
        "solo": {k: v for k, v in solo.items() if k not in strip},
        "unbounded": {k: v for k, v in unbounded.items() if k not in strip},
        "light_p95_model_s": {"solo": p95_solo, "mix": p95_mix,
                              "unbounded": p95_unbounded},
        "light_p95_over_solo": ratio,
        "light_p95_over_solo_unbounded": ratio_unbounded,
        "bit_identical": bool(identical),
        # Wall/modeled calibration table + per-tenant SLO burn rates
        # from the mix case (ISSUE 8).
        "divergence": mix["divergence"],
        "slo": mix["slo"],
        # Regression-gated metrics: all from the deterministic QoS
        # replay (virtual admission + modeled execution), so they are
        # exact across runs and machines.
        "gate": {
            "light_p95_model_s": p95_mix,
            "light_p95_over_solo": ratio,
            "mix_makespan_model": mix["makespan_model"],
            "copies": mix["copies"],
        },
        # Per-metric gate tolerances (ISSUE 5 satellite): the ratio gets
        # headroom; everything else uses the gate default.
        "gate_tolerances": {"light_p95_over_solo": 0.25},
    }

    if smoke:
        # SLO burn rates (ISSUE 8): the lights' loose objective is never
        # violated; the heavy tenant's sub-launch-floor objective is
        # violated by every task — both deterministic, from the replay.
        slo = mix["slo"]
        for c in range(N_LIGHTS):
            s = slo[f"light{c}"]
            assert s["violations"] == 0 and not s["breached"], (c, s)
        hs = slo["heavy"]
        assert hs["violations"] == hs["tasks"] > 0, hs
        assert hs["breached"] and hs["burn_rate"] > 1.0, hs
        # Per-client histogram percentiles (ISSUE 6): every tenant must
        # report ordered, positive per-task modeled latency quantiles,
        # with one sample per task it completed.
        pct = mix["latency_percentiles"]
        expect = {f"light{c}" for c in range(N_LIGHTS)} | {"heavy"}
        assert expect <= set(pct), (
            f"missing per-client percentiles: {expect - set(pct)}"
        )
        for name in sorted(expect):
            s = pct[name]
            assert 0.0 < s["p50"] <= s["p95"] <= s["p99"], (name, s)
        n_light_tasks = sum(pct[f"light{c}"]["count"]
                            for c in range(N_LIGHTS))
        assert n_light_tasks + pct["heavy"]["count"] == mix["n_tasks"], (
            "histogram sample counts don't cover the task population"
        )
        assert identical, "light chains differ between mix and solo runs"
        assert mix["n_completed"] == mix["n_tasks"], (
            f"heavy tenant starved: {mix['n_completed']}/{mix['n_tasks']}"
        )
        assert ratio <= 2.0, (
            f"light-client p95 modeled latency {ratio:.2f}x solo "
            f"(acceptance: <=2x; unbounded FCFS gives "
            f"{ratio_unbounded:.2f}x)"
        )
        assert mix["jain_lights"] >= 0.8, (
            f"Jain's index over equal-weight light clients only "
            f"{mix['jain_lights']:.3f} (acceptance: >=0.8)"
        )
        print(f"multitenant smoke: OK (light p95 {ratio:.2f}x solo vs "
              f"{ratio_unbounded:.2f}x unbounded, jain "
              f"{mix['jain_lights']:.3f}, bit-identical per chain)",
              flush=True)

    if json_path:
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"wrote {json_path}", flush=True)
    return rec


def run(n: int = N, light_chains: int = LIGHT_CHAINS,
        heavy_chains: int = HEAVY_CHAINS, json_path=None) -> None:
    run_multitenant(n=n, light_chains=light_chains,
                    heavy_chains=heavy_chains, json_path=json_path,
                    smoke=False)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run with latency-bound + bit-identity "
                         "+ fairness asserts")
    ap.add_argument("--json", default="BENCH_multitenant.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--light-chains", type=int, default=None)
    ap.add_argument("--heavy-chains", type=int, default=None)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export + lint a Perfetto trace of the run")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write a METRICS_*.json divergence table "
                         "(requires --trace-dir)")
    args = ap.parse_args()
    n = args.n or (1 << 12 if args.smoke else N)
    light_chains = args.light_chains or (4 if args.smoke else LIGHT_CHAINS)
    heavy_chains = args.heavy_chains or (24 if args.smoke else HEAVY_CHAINS)
    print("name,us_per_call,derived")
    from .common import tracing

    with tracing(args.trace_dir, "multitenant", metrics_dir=args.metrics_dir):
        run_multitenant(n=n, light_chains=light_chains,
                        heavy_chains=heavy_chains,
                        json_path=args.json or None, smoke=args.smoke)


if __name__ == "__main__":
    main()
