"""Shared benchmark helpers: timing, CSV emit, app runners, tracing."""

from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import Callable, Dict, List

ROWS: List[str] = []


@contextlib.contextmanager
def tracing(trace_dir, bench_name: str, *, capacity: int = 1 << 18,
            lint: bool = True, metrics_dir=None):
    """Trace one benchmark run end to end (ISSUE 6).

    With a falsy ``trace_dir`` this is a no-op (yields ``None``) — the
    benchmark runs exactly as before, tracer-free.  Otherwise a fresh
    process-global :class:`~repro.core.trace.TraceCollector` is
    installed for the block (every ``HeteContext`` the bench creates
    attaches automatically), the trace is exported to
    ``<trace_dir>/TRACE_<bench_name>.json`` (Perfetto-loadable), and
    ``trace_lint`` validates it — a violation fails the benchmark.

    The wall/modeled divergence observed by every runtime the block
    creates is aggregated and embedded in the trace
    (``doc["rimms"]["divergence"]``, ISSUE 8); with ``metrics_dir``
    set, the table is additionally written to
    ``<metrics_dir>/METRICS_<bench_name>.json``.
    """
    if not trace_dir:
        yield None
        return
    from repro.core import telemetry
    from repro.core.trace import (TraceCollector, global_collector,
                                  install_global, trace_lint)

    prev = global_collector()
    tc = TraceCollector(capacity_per_thread=capacity)
    install_global(tc)
    serial = telemetry.divergence_serial()
    try:
        yield tc
    finally:
        install_global(prev)
    div = telemetry.aggregate_divergence(since=serial).table()
    tc.set_divergence(div)
    out = Path(trace_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"TRACE_{bench_name}.json"
    doc = tc.export(str(path))
    meta = doc["rimms"]
    print(f"trace: {path} ({meta['n_wall_events']} wall + "
          f"{meta['n_model_events']} modeled events, "
          f"{len(div)} divergence cells)", flush=True)
    if metrics_dir:
        mdir = Path(metrics_dir)
        mdir.mkdir(parents=True, exist_ok=True)
        mpath = mdir / f"METRICS_{bench_name}.json"
        mpath.write_text(json.dumps(
            {"bench": bench_name, "divergence": div}, indent=1))
        print(f"metrics: {mpath}", flush=True)
    if lint:
        violations = trace_lint(doc)
        if violations:
            msg = "\n".join(f"  - {v}" for v in violations)
            raise AssertionError(
                f"trace_lint failed for {path}:\n{msg}")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_it(fn: Callable, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall seconds of fn() over repeats."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def run_app(builder, *, policy: str, accelerators=("gpu0",), n_cpu: int = 1,
            scheduler: str = "round_robin", repeats: int = 5,
            allocator: str = "nextfit", backend=None,
            builder_kwargs=None) -> Dict:
    """Build + run one radar app; returns measured/modeled time + ledger.
    ``backend`` selects kernel execution (thread | process | auto,
    ISSUE 7); the serial dispatch goes through the private impl so the
    Runtime.run deprecation warning stays pointed at user code."""
    from repro.apps.radar import make_runtime

    rt, ctx = make_runtime(policy=policy, scheduler=scheduler, n_cpu=n_cpu,
                           accelerators=accelerators, allocator=allocator,
                           backend=backend)
    bufs, tasks = builder(ctx, **(builder_kwargs or {}))
    rt._run_impl(tasks)  # warmup (jit compile)
    ctx.ledger.reset()
    t0 = time.perf_counter()
    for _ in range(repeats):
        rt._run_impl(tasks)
    wall = (time.perf_counter() - t0) / repeats
    snap = ctx.ledger.snapshot()
    rt.close()
    return {
        "wall_s": wall,
        "copies": snap["total_copies"] / repeats,
        "bytes": snap["total_bytes"] / repeats,
        "modeled_s": snap["modeled_seconds"] / repeats,
        # per-(src,dst) transfer matrix (per *link* under a topology):
        # copies/bytes/modeled_s per directed pair (ISSUE 3)
        "per_link": snap["per_link"],
        "ledger": snap,
    }
