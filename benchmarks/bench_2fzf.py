"""Paper Table 1: 2FZF execution time, CPU-only vs ACC-only, sizes
32..2048, reference vs RIMMS.

Checks: (1) CPU-only parity — RIMMS adds no overhead when no
accelerator is used (paper: "confirms that the RIMMS protocols ... do
not introduce any overhead"); (2) ACC-only speedup from eliminated
copies."""

from __future__ import annotations

from .common import emit, run_app

SIZES = (32, 64, 128, 256, 512, 1024, 2048)


def run(repeats: int = 5) -> None:
    from repro.apps.radar import build_2fzf

    for n in SIZES:
        for exec_type, pins in (
            ("cpu_only", ("cpu0",) * 4),
            ("acc_only", ("gpu0",) * 4),
        ):
            res = {}
            for policy in ("reference", "rimms"):
                res[policy] = run_app(
                    lambda ctx, n=n: build_2fzf(ctx, n, pins=pins),
                    policy=policy, repeats=repeats,
                )
            ref, rim = res["reference"], res["rimms"]
            spd = ref["wall_s"] / max(rim["wall_s"], 1e-12)
            emit(
                f"table1_2fzf_{exec_type}_n{n}",
                rim["wall_s"] * 1e6,
                f"ref_us={ref['wall_s']*1e6:.1f};spdup={spd:.2f}x;"
                f"copies {ref['copies']:.0f}->{rim['copies']:.0f};"
                f"modeled_spdup={ref['modeled_s']/max(rim['modeled_s'],1e-12):.2f}x",
            )


if __name__ == "__main__":
    run()
