"""Fault-tolerant training loop.

Production behaviours, scaled down to run anywhere:

* **checkpoint/restart** — atomic checkpoints every ``ckpt_every`` steps
  including optimizer + data-pipeline state; startup auto-resumes from
  the newest complete checkpoint.
* **preemption safety** — SIGTERM/SIGINT set a flag; the loop finishes
  the in-flight step, checkpoints, and exits cleanly (TPU-pod preemption
  contract).
* **straggler detection** — per-step wall times in a ring buffer; steps
  slower than ``straggler_factor ×`` the running median fire a hook
  (at fleet scale: trigger hot-spare swap / re-shard; here: counted and
  logged — the *detection* is the runnable part on one host).
* **RIMMS batch tracking** — each host-produced batch is a ``HeteData``;
  the device ingest happens through the last-resource-flag protocol and
  lands in the transfer ledger, so the framework's own input path is
  evidence for the paper's claim (one copy per consumer set, no host
  bounces).
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.hete import HeteContext
from repro.core.locations import HOST, Location
from repro.data.pipeline import TokenPipeline
from repro.models.model_api import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.step import build_train_step

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    microbatches: int = 1
    remat: bool = True
    straggler_factor: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, batch_size: int, seq_len: int,
                 tcfg: TrainerConfig = TrainerConfig(),
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 hete: Optional[HeteContext] = None):
        self.cfg, self.tcfg = cfg, tcfg
        self.model = build_model(cfg)
        self.pipeline = TokenPipeline(cfg, batch_size, seq_len, seed=tcfg.seed)
        self.step_fn = jax.jit(build_train_step(
            self.model, opt_cfg, remat=tcfg.remat,
            microbatches=tcfg.microbatches,
        ), donate_argnums=(0, 1))
        self.hete = hete or HeteContext()
        self.device_loc = Location("device", "tpu0")
        if self.device_loc not in self.hete.spaces:
            from repro.core.hete import MemorySpace
            dev = jax.devices()[0]
            self.hete.register_space(MemorySpace(
                self.device_loc,
                ingest=lambda a: jax.device_put(a, dev),
                egress=lambda a: np.asarray(a),
            ))
        self.step = 0
        self.metrics_log: List[Dict] = []
        self.straggler_events = 0
        self._preempted = False
        self._step_times: List[float] = []

    # -- preemption ------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def request_preemption(self):  # tests / fault injection
        self._preempted = True

    # -- checkpointing -----------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        save_checkpoint(
            self.tcfg.ckpt_dir, self.step, self._state_tree(),
            extra={"pipeline": self.pipeline.state(), "step": self.step},
        )

    def maybe_restore(self) -> bool:
        if latest_step(self.tcfg.ckpt_dir) is None:
            return False
        if not hasattr(self, "params"):
            # structure-only stand-in (no allocation) for tree matching
            abs_params = jax.eval_shape(
                self.model.init, jax.random.key(self.tcfg.seed)
            )
            like = {"params": abs_params,
                    "opt": jax.eval_shape(adamw_init, abs_params)}
        else:
            like = self._state_tree()
        tree, step, extra = restore_checkpoint(self.tcfg.ckpt_dir, like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = extra["step"]
        self.pipeline.restore(extra["pipeline"])
        return True

    # -- batch staging through RIMMS ------------------------------------------
    def _stage_batch(self, np_batch: Dict[str, np.ndarray]) -> Dict:
        staged = {}
        for k, a in np_batch.items():
            hd = self.hete.malloc(a.shape, a.dtype)
            hd.copies[HOST][...] = a
            staged[k] = self.hete.ensure(hd, self.device_loc)
            self.hete.free(hd)
        return staged

    # -- main loop ---------------------------------------------------------------
    def init_state(self):
        self.params = self.model.init(jax.random.key(self.tcfg.seed))
        self.opt_state = adamw_init(self.params)

    def run(self) -> Dict[str, Any]:
        if not hasattr(self, "params"):
            if not self.maybe_restore():
                self.init_state()
        t_loop = time.time()
        while self.step < self.tcfg.steps and not self._preempted:
            batch = self._stage_batch(next(self.pipeline))
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            self._step_times.append(dt)
            if len(self._step_times) > 50:
                self._step_times.pop(0)
            med = statistics.median(self._step_times)
            if len(self._step_times) >= 5 and dt > self.tcfg.straggler_factor * med:
                self.straggler_events += 1
                self.on_straggler(self.step, dt, med)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == 1:
                self.metrics_log.append(
                    {"step": self.step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "sec_per_step": dt}
                )
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
        if self._preempted:
            self.save()
        return {
            "final_step": self.step,
            "preempted": self._preempted,
            "straggler_events": self.straggler_events,
            "wall_s": time.time() - t_loop,
            "metrics": self.metrics_log,
            "transfers": self.hete.ledger.snapshot(),
        }

    # hook — override / monkeypatch in deployments
    def on_straggler(self, step: int, dt: float, median: float) -> None:
        print(f"[straggler] step {step}: {dt:.3f}s vs median {median:.3f}s")
