"""Sharded, atomic, mesh-elastic checkpointing.

Layout (one directory per step):

    ckpt_dir/step_000123.tmp/   ← written first
        manifest.json            tree structure, shapes, dtypes, extra state
        arrays/<leafpath>.npy    one file per leaf (logical/global value)
    ckpt_dir/step_000123/        ← atomic rename on completion

* **Atomicity / fault tolerance**: a crash mid-write leaves only a
  ``.tmp`` dir, which restore ignores and the next save garbage-collects.
* **Elasticity**: leaves are stored as *global logical arrays*, so a
  checkpoint written on a 16×16 mesh restores onto any mesh — restore
  takes the target shardings and ``jax.device_put``s each leaf.  (At real
  pod scale you would write per-shard files + a resharding service; the
  format and API here are deliberately shard-layout-agnostic so that
  swap is invisible to callers.)
* **Retention**: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir, step: int, tree, extra: Optional[Dict] = None,
                    keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    leaves = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in leaves.items():
        arr = np.asarray(leaf)  # gathers logical value
        fname = key.replace("/", "__") + ".npy"
        np.save(tmp / "arrays" / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit

    # retention + stale tmp GC
    steps = sorted(
        p for p in ckpt_dir.iterdir() if p.name.startswith("step_")
    )
    for p in steps:
        if p.suffix == ".tmp" and p != tmp:
            shutil.rmtree(p, ignore_errors=True)
    done = [p for p in steps if p.suffix != ".tmp"]
    for p in done[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and p.suffix != ".tmp"
        and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, tree_like, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``; optional per-leaf
    shardings (pytree of NamedSharding) re-shard onto the current mesh —
    the elastic-scaling path."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    leaves = _leaf_paths(tree_like)
    sh_leaves = _leaf_paths(shardings) if shardings is not None else {}
    restored = {}
    for key in leaves:
        meta = manifest["leaves"][key]
        arr = np.load(d / "arrays" / meta["file"])
        if key in sh_leaves:
            arr = jax.device_put(arr, sh_leaves[key])
        restored[key] = arr

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    ordered = []
    for path, _ in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        ordered.append(restored[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), step, manifest["extra"]
