"""Train / serve step builders (pure functions suitable for pjit)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model_api import Model
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.schedule import cosine_schedule

__all__ = ["build_train_step", "build_serve_step", "build_prefill_step"]


def build_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig(),
                     *, remat: bool = True, probe: bool = False,
                     microbatches: int = 1):
    """fwd+bwd+AdamW.  ``microbatches > 1`` = gradient accumulation over a
    ``lax.scan``: the dominant activation-memory term (per-layer scan
    carries) shrinks by the microbatch factor while per-step collective
    and FLOP totals are unchanged (same tokens per step).  Probes compile
    with ``microbatches=1`` — identical per-step cost totals."""

    def loss_fn(p, b):
        return model.loss(p, b, probe=probe, remat=remat)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            k = microbatches
            mb = jax.tree.map(
                lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), batch
            )

            def body(acc, b):
                l, g = jax.value_and_grad(loss_fn)(params, b)
                acc = (
                    acc[0] + l,
                    jax.tree.map(lambda s, x: s + x.astype(jnp.float32),
                                 acc[1], g),
                )
                return acc, None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, gsum), _ = jax.lax.scan(body, (0.0, zeros), mb)
            loss = loss_sum / k
            grads = jax.tree.map(lambda g: g / k, gsum)

        # schedule is evaluated at the step being taken (1-based): warmup
        # must not zero out the very first update.
        lr_scale = cosine_schedule(opt_state["step"] + 1)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, opt_state, params, lr_scale
        )
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def build_serve_step(model: Model):
    """One decode step: greedy next token + updated caches."""

    def serve_step(params, caches, token, pos):
        logits, caches = model.decode_step(params, caches, token, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    return serve_step


def build_prefill_step(model: Model, max_len: int, *, probe: bool = False):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len, probe=probe)

    return prefill_step
