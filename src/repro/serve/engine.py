"""Batched serving engine on the RIMMS paged KV pool.

The production mapping of the paper (DESIGN.md §2): the KV cache is one
preallocated device pool; the RIMMS marking systems hand out page
extents; a sequence's pages are one ``fragment()``-style grab; block
tables are the resource pointers consumed by the paged-attention kernel
(ref path on CPU, Pallas kernel on TPU).

Continuous-batching-lite: up to ``max_batch`` slots decode in lock-step;
finished sequences free their pages back to the pool and new requests
are admitted into the freed slots.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.paged_kv import PagedKVPool, init_pool_arrays, write_token
from repro.kernels.paged_attention import ref as pa_ref
from repro.models import layers as L

__all__ = ["ServeEngine", "Request", "SUPPORTED_FAMILIES"]

#: full-attention dense decoder families the paged engines support.
SUPPORTED_FAMILIES = ("dense", "vlm")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 page_size: int = 16, num_pages: int = 512,
                 max_pages_per_seq: int = 32, allocator: str = "bitset",
                 eos_id: Optional[int] = None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"serve engine supports full-attention dense decoder "
                f"families {SUPPORTED_FAMILIES}, got {cfg.family!r}"
            )
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.max_batch = max_batch
        self.eos_id = eos_id
        # scratch=True reserves the sacrificial scratch page inside the
        # pool's own accounting: inactive slots' block tables point at
        # it, so their masked writes never corrupt a live sequence's
        # pages, and no tenant can free it or get billed for it.
        self.pool = PagedKVPool(num_pages=num_pages, page_size=page_size,
                                allocator=allocator, scratch=True)
        self.scratch_page = self.pool.scratch_page
        n_layers = cfg.n_layers
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        k0, v0 = init_pool_arrays(num_pages, page_size, kv, hd, L.cdtype(cfg))
        self.k_pools = jnp.broadcast_to(k0, (n_layers,) + k0.shape).copy()
        self.v_pools = jnp.broadcast_to(v0, (n_layers,) + v0.shape).copy()
        # slot state (host side — RIMMS metadata lives on host, §3.2.2)
        self.block_tables = np.full(
            (max_batch, max_pages_per_seq), self.scratch_page, np.int32
        )
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros((max_batch,), np.int32)
        self.slot_tok = np.zeros((max_batch,), np.int32)
        self._next_rid = 0
        self.waiting: List[Request] = []
        self._step_fn = jax.jit(functools.partial(_paged_decode_step, cfg))

    # -- request admission --------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> Request:
        if not prompt:
            raise ValueError("prompt must be non-empty")
        need = -(-(len(prompt) + max_new_tokens) // self.page_size)
        if need > self.max_pages:
            raise ValueError(
                f"request needs {need} pages "
                f"({len(prompt)} prompt + {max_new_tokens} new tokens) "
                f"but max_pages_per_seq is {self.max_pages}"
            )
        req = Request(self._next_rid, list(prompt), max_new_tokens)
        self._next_rid += 1
        self.waiting.append(req)
        return req

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            n_tokens = len(req.prompt) + req.max_new_tokens
            table = self.pool.alloc_sequence(req.rid, n_tokens)
            self.block_tables[slot, :] = self.scratch_page
            self.block_tables[slot, : len(table)] = table
            self.slot_req[slot] = req
            # prefill by teacher-forced decode over the prompt
            for i, tok in enumerate(req.prompt[:-1]):
                self._decode_one(slot, tok, i)
            self.slot_pos[slot] = len(req.prompt) - 1
            self.slot_tok[slot] = req.prompt[-1]

    def _decode_one(self, slot: int, token: int, pos: int) -> int:
        toks = self.slot_tok.copy()
        poss = self.slot_pos.copy()
        toks[slot], poss[slot] = token, pos
        nxt = self._step(toks, poss, active_mask=np.eye(1, self.max_batch,
                                                        slot, dtype=bool)[0])
        return int(nxt[slot])

    # -- decode ----------------------------------------------------------------
    def _step(self, tokens: np.ndarray, pos: np.ndarray, active_mask) -> np.ndarray:
        lengths = jnp.asarray(np.where(active_mask, pos + 1, 0), jnp.int32)
        nxt, self.k_pools, self.v_pools = self._step_fn(
            self.params, self.k_pools, self.v_pools,
            jnp.asarray(self.block_tables), jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32), lengths,
        )
        return np.asarray(nxt)

    def step(self) -> int:
        """One lock-step decode over all active slots; returns #active."""
        self._admit()
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return 0
        nxt = self._step(self.slot_tok, self.slot_pos, active)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.slot_pos[slot] += 1
            self.slot_tok[slot] = tok
            if len(req.generated) >= req.max_new_tokens or tok == self.eos_id:
                req.done = True
                self.pool.free_sequence(req.rid)
                self.slot_req[slot] = None
                # re-point the idle slot at the scratch page so its
                # masked writes can't land in pages the pool recycles.
                self.block_tables[slot, :] = self.scratch_page
        return int(active.sum())

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.waiting:
                break


def _paged_decode_step(cfg, params, k_pools, v_pools, block_tables,
                       tokens, pos, lengths):
    """One batched paged decode step for dense-family configs."""
    x = L.embed_tokens(cfg, params["embed"], tokens[:, None],
                       pos[:, None] if cfg.pos_embed == "learned" else None)
    stack = params["stacks"][0]
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    dims = L.attn_dims(cfg)
    new_k, new_v = [], []
    for li in range(n_layers):
        p = jax.tree.map(lambda a: a[li], stack)["b0"]
        h = L.norm_apply(cfg, p["norm1"], x)
        q, k, v = L._project_qkv(cfg, p["attn"], h, pos[:, None])
        kp = write_token(k_pools[li], block_tables, pos, k[:, 0])
        vp = write_token(v_pools[li], block_tables, pos, v[:, 0])
        new_k.append(kp)
        new_v.append(vp)
        attn = pa_ref.paged_attention(
            q[:, 0].reshape(q.shape[0], dims.n_q, dims.head_dim),
            kp, vp, block_tables, lengths,
        ).reshape(x.shape[0], 1, dims.n_q * dims.head_dim)
        x = x + attn @ p["attn"]["wo"].astype(x.dtype)
        h = L.norm_apply(cfg, p["norm2"], x)
        x = x + L.mlp_apply(cfg, p["mlp"], h)
    x = L.norm_apply(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    return nxt, jnp.stack(new_k), jnp.stack(new_v)
