"""Continuous-batching LLM serving on the RIMMS Session.

The legacy :class:`~repro.serve.engine.ServeEngine` manages its KV pool
by hand: two bare jax arrays, no quotas, no pressure handling, no
telemetry.  This engine runs the same continuous-batching decode loop
*through* the runtime instead (ROADMAP item 2, the "millions of users"
scenario):

* every tenant is a QoS client on a :class:`~repro.core.api.Session` —
  weighted DRR admission, bounded in-flight windows, per-tenant decode
  latency percentiles and SLO burn rates in ``qos_report()``;
* the KV cache is a :class:`~repro.core.kv_manager.KVManager`: page
  groups are Session buffers in the device arena, with per-tenant page
  quotas enforced by the tenant-aware paged pool;
* prefill and decode are distinct registered ops (``llm_prefill``
  throughput-bound, ``llm_decode`` latency-sensitive) with their own QoS
  weights/windows, so placement, staging, spans, and divergence
  telemetry all come from the runtime for free;
* each submission stages only the page groups its block tables
  reference: cold groups become LRU eviction victims under arena
  pressure, spill to host through the existing coherence path
  (dirty write-back), and re-stage transparently on the next decode
  step that touches them — there is no serving-specific copy code.

Token streams are bit-identical to the legacy engine on the same
submission order: the per-tenant masked sub-steps write the same values
into the same pages (KV entries are deterministic, idempotent functions
of ``(token, position, params)``, and every per-row output depends only
on that row's inputs plus its own gathered pages).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.api import OpRegistry, Session
from repro.core.kv_manager import KVManager
from repro.models import layers as L

from .engine import SUPPORTED_FAMILIES, Request, _paged_decode_step

__all__ = ["SessionServeEngine", "TenantRequest"]


@dataclasses.dataclass
class TenantRequest(Request):
    tenant: str = "default"


@functools.lru_cache(maxsize=None)
def _jit_grouped_step(cfg: ArchConfig, n_groups: int):
    """One batched decode step over a compacted pool of ``n_groups``
    page groups: concat → legacy step → split, jitted as one unit.
    Cached per (config, group count) so every engine instance — and
    every run in a benchmark — shares compilations."""

    def fn(params, k_groups, v_groups, block_tables, tokens, pos, lengths):
        k_pool = jnp.concatenate(k_groups, axis=1)
        v_pool = jnp.concatenate(v_groups, axis=1)
        nxt, k_pool, v_pool = _paged_decode_step(
            cfg, params, k_pool, v_pool, block_tables, tokens, pos, lengths
        )
        gp = k_groups[0].shape[1]
        cuts = [gp * i for i in range(1, n_groups)]
        return (nxt, tuple(jnp.split(k_pool, cuts, axis=1)),
                tuple(jnp.split(v_pool, cuts, axis=1)))

    return jax.jit(fn)


class SessionServeEngine:
    """Session-backed continuous-batching engine.

    Drop-in for :class:`~repro.serve.engine.ServeEngine` plus tenancy:
    ``submit(prompt, max_new_tokens, tenant=...)`` queues a request
    under a QoS client; ``step()`` admits waiting requests (prefill
    tasks under the shared throughput-bound ``prefill`` client) and runs
    one lock-step decode as per-tenant latency-sensitive sub-steps.

    With no ``session`` the engine owns a fresh emulated SoC whose
    single device arena (``arena_bytes``) backs the KV groups —
    shrinking it below the total KV footprint makes cold sequences spill
    to host through the runtime's eviction path.  ``prefetch`` is off on
    the owned session: the closed decode loop serializes on its own
    results, and unprefetched staging keeps the replayed modeled gates
    byte-deterministic.
    """

    def __init__(self, cfg: ArchConfig, params, *, session: Optional[Session] = None,
                 max_batch: int = 4, page_size: int = 16, num_pages: int = 512,
                 max_pages_per_seq: int = 32, pages_per_group: int = 8,
                 allocator: str = "bitset", eos_id: Optional[int] = None,
                 arena_bytes: int = 64 << 20, platform: Optional[str] = None,
                 kv_owner: str = "kv-cache",
                 decode_weight: float = 4.0, decode_window: int = 4,
                 prefill_weight: float = 1.0, prefill_window: int = 8):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"serve engine supports full-attention dense decoder "
                f"families {SUPPORTED_FAMILIES}, got {cfg.family!r}"
            )
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.max_batch = max_batch
        self.eos_id = eos_id
        self._decode_weight = decode_weight
        self._decode_window = decode_window

        self._registry = OpRegistry()
        self._register_kernels()
        if session is None:
            session = Session.emulated(
                platform, policy="rimms", scheduler="heft", n_cpu=0,
                accelerators=("gpu0",), registry=self._registry,
                prefetch=False, arena_bytes=arena_bytes,
            )
            self._owns_session = True
        else:
            # Rebind (not missing_only): the kernels close over *this*
            # engine's params — one serving engine per session at a time.
            self._registry.install(session.runtime,
                                   extend_supports=("cpu", "gpu"))
            self._owns_session = False
        self.session = session
        self.kv = KVManager(
            session, n_layers=cfg.n_layers, kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, num_pages=num_pages,
            page_size=page_size, pages_per_group=pages_per_group,
            dtype=L.cdtype(cfg), allocator=allocator, owner=kv_owner,
        )
        self._prefill_client = session.client(
            "prefill", weight=prefill_weight, window=prefill_window)
        self._tenants: Dict[str, object] = {}  # name -> SessionClient

        self.block_tables = np.full(
            (max_batch, max_pages_per_seq), self.kv.scratch_page, np.int32)
        self.slot_req: List[Optional[TenantRequest]] = [None] * max_batch
        self.slot_pos = np.zeros((max_batch,), np.int32)
        self.slot_tok = np.zeros((max_batch,), np.int32)
        self._next_rid = 0
        self.waiting: List[TenantRequest] = []

    # -- kernels -------------------------------------------------------------
    def _register_kernels(self) -> None:
        cfg = self.cfg

        def decode_kernel(ins, *, mask, n_groups):
            tokens, pos, tables = ins[0], ins[1], ins[2]
            k_groups = tuple(ins[3:3 + n_groups])
            v_groups = tuple(ins[3 + n_groups:3 + 2 * n_groups])
            lengths = jnp.where(
                jnp.asarray(mask, bool), jnp.asarray(pos) + 1, 0
            ).astype(jnp.int32)
            step = _jit_grouped_step(cfg, n_groups)
            nxt, k_groups, v_groups = step(
                self.params, k_groups, v_groups, tables,
                jnp.asarray(tokens, jnp.int32), jnp.asarray(pos, jnp.int32),
                lengths,
            )
            return (nxt, *k_groups, *v_groups)

        def prefill_kernel(ins, *, slot, prompt, base_toks, base_pos,
                           n_groups):
            tables = ins[0]
            k_groups = tuple(ins[1:1 + n_groups])
            v_groups = tuple(ins[1 + n_groups:1 + 2 * n_groups])
            toks = np.array(base_toks, np.int32)
            poss = np.array(base_pos, np.int32)
            onehot = np.eye(1, len(toks), slot, dtype=bool)[0]
            step = _jit_grouped_step(cfg, n_groups)
            # Teacher-forced prefill: one masked decode per prompt token,
            # reusing the decode step's compiled trace.  Each dispatch
            # gets fresh copies of toks/poss: jnp.asarray can alias the
            # numpy buffer zero-copy, and the async XLA execution must
            # not observe the next iteration's in-place mutation.
            for i, tok in enumerate(prompt):
                toks[slot], poss[slot] = tok, i
                lengths = jnp.asarray(
                    np.where(onehot, poss + 1, 0), jnp.int32)
                _, k_groups, v_groups = step(
                    self.params, k_groups, v_groups, tables,
                    jnp.asarray(toks.copy()), jnp.asarray(poss.copy()),
                    lengths,
                )
            return (*k_groups, *v_groups)

        from repro.core.api import op

        op("llm_decode", kinds=("cpu", "gpu"), registry=self._registry,
           replace=True)(decode_kernel)
        op("llm_prefill", kinds=("cpu", "gpu"), registry=self._registry,
           replace=True)(prefill_kernel)

    # -- tenants -------------------------------------------------------------
    def tenant(self, name: str, *, weight: Optional[float] = None,
               window: Optional[int] = None,
               quota_pages: Optional[int] = None,
               slo_latency_s: Optional[float] = None,
               slo_target: Optional[float] = None):
        """Register (or update) a tenant: a QoS client for its decode
        tasks plus an optional KV page quota."""
        cl = self.session.client(
            name,
            weight=self._decode_weight if weight is None else weight,
            window=self._decode_window if window is None else window,
            slo_latency_s=slo_latency_s, slo_target=slo_target,
        )
        if name not in self._tenants:
            self._tenants[name] = cl
        if quota_pages is not None:
            self.kv.set_quota(name, quota_pages)
        return cl

    # -- request admission ---------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               tenant: str = "default") -> TenantRequest:
        if not prompt:
            raise ValueError("prompt must be non-empty")
        need = -(-(len(prompt) + max_new_tokens) // self.page_size)
        if need > self.max_pages:
            raise ValueError(
                f"request needs {need} pages "
                f"({len(prompt)} prompt + {max_new_tokens} new tokens) "
                f"but max_pages_per_seq is {self.max_pages}"
            )
        if tenant not in self._tenants:
            self.tenant(tenant)
        req = TenantRequest(self._next_rid, list(prompt), max_new_tokens,
                            tenant=tenant)
        self._next_rid += 1
        self.waiting.append(req)
        return req

    def _admit(self) -> None:
        from repro.core.allocator import AllocError
        from repro.core.qos import QuotaExceeded

        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            req = None
            # FIFO with quota skip: a tenant over its KV quota defers
            # (stays queued) without blocking other tenants' admissions.
            for i, cand in enumerate(self.waiting):
                n_tokens = len(cand.prompt) + cand.max_new_tokens
                try:
                    table = self.kv.alloc(cand.rid, n_tokens,
                                          tenant=cand.tenant)
                except QuotaExceeded:
                    self.session.metrics.counter(
                        "serve_quota_deferrals").inc()
                    continue
                except AllocError:
                    # Shared pool exhausted: clean admission backpressure
                    # (head-of-line, order-preserving), not corruption.
                    self.session.metrics.counter(
                        "serve_pool_backpressure").inc()
                    return
                req = self.waiting.pop(i)
                break
            if req is None:
                return
            self.block_tables[slot, :] = self.kv.scratch_page
            self.block_tables[slot, : len(table)] = table
            self.slot_req[slot] = req
            if len(req.prompt) > 1:
                self._submit_prefill(slot, req)
            self.slot_pos[slot] = len(req.prompt) - 1
            self.slot_tok[slot] = req.prompt[-1]

    def _submit_prefill(self, slot: int, req: TenantRequest) -> None:
        groups = self.kv.referenced_groups(self.block_tables)
        tables = self.kv.compact_tables(self.block_tables, groups)
        bufs = self.kv.buffers(groups)
        tb = self.session.malloc(tables.shape, np.int32,
                                 client=self._prefill_client)
        tb.data[...] = tables
        self._prefill_client.submit(
            "llm_prefill", [tb, *bufs], out=list(bufs),
            name=f"prefill#{req.rid}",
            slot=slot, prompt=tuple(req.prompt[:-1]),
            base_toks=tuple(int(t) for t in self.slot_tok),
            base_pos=tuple(int(p) for p in self.slot_pos),
            n_groups=len(groups),
        )
        self.session.free(tb)  # deferred to the prefill's completion

    # -- decode --------------------------------------------------------------
    def _decode_substep(self, mask: np.ndarray, client) -> np.ndarray:
        groups = self.kv.referenced_groups(self.block_tables)
        tables = self.kv.compact_tables(self.block_tables, groups)
        bufs = self.kv.buffers(groups)
        sess = self.session
        tok = sess.malloc((self.max_batch,), np.int32, client=client)
        tok.data[...] = self.slot_tok
        pos = sess.malloc((self.max_batch,), np.int32, client=client)
        pos.data[...] = self.slot_pos
        tb = sess.malloc(tables.shape, np.int32, client=client)
        tb.data[...] = tables
        nxt = sess.malloc((self.max_batch,), np.int32, client=client)
        futs = client.submit(
            "llm_decode", [tok, pos, tb, *bufs], out=[nxt, *bufs],
            mask=tuple(bool(m) for m in mask), n_groups=len(groups),
        )
        for b in (tok, pos, tb):
            sess.free(b)
        out = futs[0].result()
        sess.free(nxt)
        return out

    def step(self) -> int:
        """One lock-step decode over all active slots — submitted as one
        latency-sensitive sub-step per tenant present; returns #active."""
        self._admit()
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            self.kv.publish_metrics()
            return 0
        n_active = int(active.sum())
        metrics = self.session.metrics
        for tname, client in self._tenants.items():
            slots = [s for s in range(self.max_batch)
                     if self.slot_req[s] is not None
                     and self.slot_req[s].tenant == tname]
            if not slots:
                continue
            mask = np.zeros((self.max_batch,), bool)
            mask[slots] = True
            nxt = self._decode_substep(mask, client)
            for slot in slots:
                req = self.slot_req[slot]
                tok = int(nxt[slot])
                req.generated.append(tok)
                metrics.counter("serve_tokens_generated").inc()
                self.slot_pos[slot] += 1
                self.slot_tok[slot] = tok
                if (len(req.generated) >= req.max_new_tokens
                        or tok == self.eos_id):
                    req.done = True
                    self.kv.free(req.rid)
                    self.slot_req[slot] = None
                    self.block_tables[slot, :] = self.kv.scratch_page
                    metrics.counter("serve_requests_completed").inc()
        self.kv.publish_metrics()
        return n_active

    def run(self, max_steps: int = 10000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.waiting:
                break

    # -- reporting / lifecycle ----------------------------------------------
    def qos_report(self):
        """The session's deterministic QoS replay — per-tenant decode
        latency percentiles, SLO burn rates, fairness, metrics."""
        self.session.barrier()
        return self.session.qos_report()

    def close(self) -> None:
        if self._owns_session and not self.session.closed:
            self.session.close()

    def __enter__(self) -> "SessionServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
