from .base import ARCH_IDS, SHAPES, ArchConfig, ShapeSpec, cells_for, get_config, list_archs

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeSpec", "cells_for", "get_config", "list_archs"]
