"""internvl2-26b: InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone-only per the assignment: the vision frontend is a stub —
input_specs() provides precomputed patch embeddings occupying the first
n_patches positions of the sequence.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, head_dim=128, rope_theta=1_000_000.0,
    n_patches=256,
)
