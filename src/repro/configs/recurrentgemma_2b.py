"""recurrentgemma-2b: RG-LRU + local attention 1:2 [arXiv:2402.19427].

26 layers = 8 × (rec, rec, local-attn) + (rec, rec). MQA (kv=1),
window 2048, GeGLU d_ff=7680.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256, rope_theta=10_000.0,
    act="geglu", block_pattern=("rec", "rec", "attn"), window=2048,
)
