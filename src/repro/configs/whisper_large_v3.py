"""whisper-large-v3: enc-dec, conv frontend stub [arXiv:2212.04356].

n_layers counts decoder layers (32) + 32 encoder layers, matching
whisper-large. The conv frontend is a stub: input_specs() provides
precomputed frame embeddings (B, 1500, d_model). decode_32k follows the
assigned shape (32k self-KV) even though upstream whisper caps decoder
context at 448 — learned positions are sized to the assigned shape.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    norm="layernorm", act="gelu", pos_embed="learned",
    n_enc_layers=32, enc_seq=1500,
)
