"""xlstm-350m: alternating mLSTM/sLSTM blocks [arXiv:2405.04517].

d_ff=0 per the assignment: blocks carry their own up/down projections
(mLSTM pf=2, sLSTM pf=4/3-style gated FFN). See models/recurrent.py for
the simplifications recorded in DESIGN.md (sigmoid gating for numeric
stability; chunkwise-parallel mLSTM).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=256,
    block_pattern=("mlstm", "slstm"),
)
