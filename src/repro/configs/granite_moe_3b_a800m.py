"""granite-moe-3b-a800m: MoE 40 experts top-8 [hf:ibm-granite].

Note: the assignment's inline comment says "32 experts" but the config
field says "MoE 40e top-8"; we take the config field (40) as
authoritative (matches ibm-granite/granite-3.0-3b-a800m-base).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64, rope_theta=10_000.0,
    n_experts=40, top_k=8,
)
