"""Architecture + shape configuration schema and registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs", "cells_for"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention / embeddings
    head_dim: int = 0  # 0 → d_model // n_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | geglu | gelu
    pos_embed: str = "rope"  # rope | learned
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # encoder-decoder (whisper): n_layers counts DECODER layers
    n_enc_layers: int = 0
    enc_seq: int = 0
    # vlm stub frontend
    n_patches: int = 0
    # hybrid / ssm block structure; () → all attention blocks
    block_pattern: Tuple[str, ...] = ()
    window: int = 0  # local-attention window (0 = full causal)
    conv_width: int = 4
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # chunk sizes for chunked attention / chunkwise recurrence
    q_chunk: int = 512
    rec_chunk: int = 256

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k decode is tractable (no full-attention KV)."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid" and self.window > 0:
            return True
        return False

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, len(self.block_pattern) or 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            # drop-free at smoke scale so prefill ≡ decode exactly
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_seq=16 if self.enc_seq else 0,
            n_patches=8 if self.n_patches else 0,
            window=16 if self.window else 0,
            q_chunk=16,
            rec_chunk=8,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    def smoke(self) -> "ShapeSpec":
        return ShapeSpec(self.name + "-smoke", self.kind, 32, 2)


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = [
    "llama3_8b",
    "yi_9b",
    "command_r_plus_104b",
    "qwen1_5_32b",
    "granite_moe_3b_a800m",
    "qwen3_moe_235b_a22b",
    "internvl2_26b",
    "whisper_large_v3",
    "xlstm_350m",
    "recurrentgemma_2b",
]


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)


def cells_for(arch_id: str) -> List[str]:
    """Shape names applicable to an arch (skips per DESIGN.md §4)."""
    cfg = get_config(arch_id)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")  # full-attention archs skip (quadratic KV)
    return out
