"""qwen1.5-32b: dense, QKV bias [hf:Qwen/Qwen1.5-32B]. kv=40 => MHA."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064, head_dim=128, rope_theta=1_000_000.0,
    qkv_bias=True,
)
