"""Stable public namespace for the RIMMS runtime (ISSUE 10 satellite).

``import repro.rimms as rimms`` is the supported surface for user code:
the streaming session API, the op/variant registry, calibration and
autotuning, platform registration, and the public exception types.
Internal module layout (``repro.core.*``) may shift between issues;
names re-exported here — everything in ``__all__`` — stay put.

    import repro.rimms as rimms

    @rimms.op("fft", kinds=("cpu",))
    def my_fft(ins): ...

    with rimms.Session.emulated(n_cpu=2) as session:
        table = rimms.autotune(session)       # measured variant winners
        session.save_calibration("calib.json")
    session = rimms.Session.emulated(calibration="calib.json")
"""

from __future__ import annotations

from repro.core.allocator import AllocError
from repro.core.api import (
    BufferFuture, OpRegistry, OpVariant, Session, SessionClient,
    SessionClosedError, default_registry, op,
)
from repro.core.autotune import Tunable, autotune, register_tunables, tunables
from repro.core.calibrate import (
    DEFAULT_VARIANT, CalibrationTable, calibrate, heft_plan,
    resolve_calibration, simulate_plan,
)
from repro.core.graph import CostModel
from repro.core.locations import HOST, Location
from repro.core.pworker import WorkerDied
from repro.core.qos import BackpressureFull, QuotaExceeded
from repro.core.runtime import (
    BACKENDS, platform_names, register_platform, resolve_backend,
)

__all__ = [
    # streaming session API
    "Session", "SessionClient", "SessionClosedError", "BufferFuture",
    # op/variant registry
    "op", "OpRegistry", "OpVariant", "default_registry", "DEFAULT_VARIANT",
    # calibration + autotuning (ISSUE 10)
    "CalibrationTable", "calibrate", "resolve_calibration", "autotune",
    "register_tunables", "tunables", "Tunable", "heft_plan",
    "simulate_plan", "CostModel",
    # platforms / backends
    "register_platform", "platform_names", "BACKENDS", "resolve_backend",
    "HOST", "Location",
    # public exception types
    "AllocError", "QuotaExceeded", "BackpressureFull", "WorkerDied",
]
