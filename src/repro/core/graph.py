"""Task-graph construction + cost modeling for the async executor.

RIMMS's premise (§3.2.2) is that the runtime knows where valid bytes
live; this module gives the runtime the *other* half of what it needs to
exploit that: which API calls are actually ordered.  From each
:class:`~repro.core.runtime.Task`'s ``HeteData`` read/write sets we build
a dependency DAG automatically:

* **RAW** — a task reading a buffer depends on the buffer's live writers;
* **WAW** — a task writing a buffer depends on its earlier writers;
* **WAR** — a task writing a buffer depends on earlier readers (their
  input staging must not observe the new bytes).

Aliasing: a fragment (§3.2.3) aliases its parent allocation over its
byte interval; sibling fragments are disjoint and stay independent, so a
fragmented Pulse-Doppler phase parallelizes across ways while a task
touching the whole parent still orders against every fragment.

:class:`CostModel` provides per-(op, pe_kind) compute estimates — a
throughput prior refined online by an EMA of measured kernel seconds —
and, together with a :class:`~repro.core.locations.BandwidthModel`, the
upward-rank computation used by the HEFT-lite scheduler in
:mod:`repro.core.executor`.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["TaskNode", "TaskGraph", "GraphBuilder", "build_graph", "CostModel"]


@dataclasses.dataclass
class TaskNode:
    """One task in the DAG, with its dependency edges (by node index)."""

    index: int
    task: "Task"  # repro.core.runtime.Task (duck-typed; no import cycle)
    deps: Set[int] = dataclasses.field(default_factory=set)
    dependents: Set[int] = dataclasses.field(default_factory=set)
    rank: float = 0.0  # HEFT upward rank (filled by compute_ranks)

    @property
    def name(self) -> str:
        return self.task.name or self.task.op


class TaskGraph:
    """An immutable DAG over a submitted task list."""

    def __init__(self, nodes: List[TaskNode]) -> None:
        self.nodes = nodes

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n_edges(self) -> int:
        return sum(len(n.deps) for n in self.nodes)

    def roots(self) -> List[TaskNode]:
        return [n for n in self.nodes if not n.deps]

    def edges(self) -> List[Tuple[int, int]]:
        return sorted(
            (d, n.index) for n in self.nodes for d in n.deps
        )

    @property
    def critical_path_len(self) -> int:
        """Length (in tasks) of the longest dependency chain."""
        depth = [0] * len(self.nodes)
        for n in self.nodes:  # nodes are in submission order; deps point back
            depth[n.index] = 1 + max((depth[d] for d in n.deps), default=0)
        return max(depth, default=0)

    def compute_ranks(
        self,
        compute_cost: Callable[["Task"], float],
        comm_cost: Callable[["Task"], float],
    ) -> None:
        """Fill each node's HEFT *upward rank*: its mean compute cost plus
        the most expensive (communication + rank) path to an exit node."""
        for n in reversed(self.nodes):
            succ = max(
                (comm_cost(self.nodes[s].task) + self.nodes[s].rank
                 for s in n.dependents),
                default=0.0,
            )
            n.rank = compute_cost(n.task) + succ


def _overlaps(a: Tuple[int, int], b: Tuple[int, int]) -> bool:
    return a[0] < b[1] and b[0] < a[1]


def _covers(a: Tuple[int, int], b: Tuple[int, int]) -> bool:
    return a[0] <= b[0] and b[1] <= a[1]


class GraphBuilder:
    """Incremental RAW/WAR/WAW dependency tracking (ISSUE 4).

    The streaming session front-end (:mod:`repro.core.api`) submits tasks
    one at a time against *live* buffers; this builder extends the DAG
    per submission — :meth:`add` resolves the new task's dependencies
    from the live access state and updates it, in O(live accesses on the
    touched buffers), never re-scanning earlier tasks.  Batch
    :func:`build_graph` is a loop over :meth:`add`, so both entry points
    produce identical DAGs by construction.

    Dependency state is keyed on **HeteData versions**: every write
    submission bumps the target root's version counter, and the builder
    remembers which node produced each buffer's current version (the
    live-writer set per byte interval).  A
    :class:`~repro.core.api.BufferFuture` binds to the (buffer, version)
    pair its producing task will publish.

    Not thread-safe by itself — the session serializes :meth:`add` under
    its submission lock (admission order must equal node order).
    """

    def __init__(self) -> None:
        self.nodes: List[TaskNode] = []
        # per root allocation: live accesses as (interval, node_index)
        self._writes: Dict[int, List[Tuple[Tuple[int, int], int]]] = {}
        self._reads: Dict[int, List[Tuple[Tuple[int, int], int]]] = {}
        # id(root) -> write version (0 = the initial host bytes); bumped
        # once per writing task at *submission* time
        self._versions: Dict[int, int] = {}
        # id(root) -> index of the node that wrote it last (any interval)
        self._last_writer: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self.nodes)

    def version_of(self, hd: "HeteData") -> int:
        """Current submitted write version of ``hd``'s root (0 before any
        writer was submitted)."""
        return self._versions.get(id(hd.root), 0)

    def last_writer(self, hd: "HeteData") -> Optional[int]:
        """Index of the last submitted node writing ``hd``'s root, or
        None if the buffer was never a task output."""
        return self._last_writer.get(id(hd.root))

    def add(self, task: "Task") -> TaskNode:
        """Append ``task``, resolving its deps against the live access
        state.  Deps always point to earlier submissions, so the graph
        stays a DAG by construction."""
        i = len(self.nodes)
        node = TaskNode(i, task)
        writes, reads = self._writes, self._reads
        for hd in task.inputs:
            key, iv = id(hd.root), hd.byte_interval()
            # RAW: order after every live writer touching this interval
            for w_iv, w_idx in writes.get(key, ()):
                if _overlaps(iv, w_iv):
                    node.deps.add(w_idx)
            reads.setdefault(key, []).append((iv, i))
        for hd in task.outputs:
            key, iv = id(hd.root), hd.byte_interval()
            for w_iv, w_idx in writes.get(key, ()):  # WAW
                if w_idx != i and _overlaps(iv, w_iv):
                    node.deps.add(w_idx)
            for r_iv, r_idx in reads.get(key, ()):  # WAR
                if r_idx != i and _overlaps(iv, r_iv):
                    node.deps.add(r_idx)
            # This write shadows fully-covered earlier accesses: future
            # tasks order against us, and transitively against them.
            writes[key] = [
                (w_iv, w_idx) for w_iv, w_idx in writes.get(key, ())
                if not _covers(iv, w_iv)
            ] + [(iv, i)]
            reads[key] = [
                (r_iv, r_idx) for r_iv, r_idx in reads.get(key, ())
                if r_idx == i or not _covers(iv, r_iv)
            ]
            self._versions[key] = self._versions.get(key, 0) + 1
            self._last_writer[key] = i
        self.nodes.append(node)
        for d in node.deps:
            self.nodes[d].dependents.add(i)
        return node

    def graph(self) -> TaskGraph:
        """The DAG over everything added so far (shares the node list —
        later :meth:`add` calls keep extending it)."""
        return TaskGraph(self.nodes)


def build_graph(tasks: Sequence["Task"]) -> TaskGraph:
    """Build the RAW/WAR/WAW dependency DAG from ``tasks``' read/write
    sets (batch intake: one :class:`GraphBuilder` pass in submission
    order)."""
    builder = GraphBuilder()
    for t in tasks:
        builder.add(t)
    return builder.graph()


# ---------------------------------------------------------------------------
# Cost model — per-(op, pe_kind) compute estimates for HEFT-lite
# ---------------------------------------------------------------------------


class CostModel:
    """Per-(op, pe_kind) compute-seconds estimates.

    Prior: bytes / throughput, with a per-kind base throughput and a
    per-op weight (FFTs cost ~5× an elementwise zip per byte).  Every
    measured kernel execution refines the estimate via an EMA of observed
    seconds-per-byte, so schedules improve as the run progresses.

    Measured calibration (ISSUE 10): when a
    :class:`~repro.core.calibrate.CalibrationTable` is attached
    (:meth:`set_calibration` — e.g. via ``Session(calibration=...)``),
    :meth:`prior_estimate` consults the table's measured cell for the
    exact ``(op, pe_kind, shape bucket)`` *before* falling back to the
    ``BASE_THROUGHPUT`` prior, so placement and the modeled replays
    price work from measured hardware.  No table attached (the default)
    keeps the historical deterministic priors — committed bench
    baselines depend on that.
    """

    BASE_THROUGHPUT = {  # bytes/second prior per PE kind
        "cpu": 1.0e9,
        "acc": 8.0e9,
        "gpu": 1.6e10,
    }
    OP_WEIGHT = {"fft": 5.0, "ifft": 5.0, "zip": 1.0}
    LAUNCH_LATENCY_S = 20e-6  # per-dispatch overhead floor
    EMA = 0.3

    def __init__(self, calibration=None) -> None:
        self._observed: Dict[Tuple[str, str], float] = {}  # s per byte
        self._lock = threading.Lock()
        self._calibration = calibration

    def set_calibration(self, table) -> None:
        """Attach (or detach with None) a calibration table; measured
        cells then take precedence over the throughput priors."""
        self._calibration = table

    @property
    def calibration(self):
        return self._calibration

    def prior_estimate(self, op: str, pe_kind: str, nbytes: int) -> float:
        """Static estimate — deterministic, used for the schedule
        *simulation* so serial and graph modeled makespans are directly
        comparable (measured kernel times on this box are inflated by
        cross-PE CPU contention in graph mode).  A measured calibration
        cell for this exact (op, kind, bucket) wins; missing cells fall
        back to the throughput prior."""
        if self._calibration is not None:
            measured = self._calibration.estimate_s(
                op, pe_kind, nbytes, launch_s=self.LAUNCH_LATENCY_S)
            if measured is not None:
                return measured
        bw = self.BASE_THROUGHPUT.get(pe_kind, 1.0e9)
        per_byte = self.OP_WEIGHT.get(op, 2.0) / bw
        return self.LAUNCH_LATENCY_S + nbytes * per_byte

    def estimate(self, op: str, pe_kind: str, nbytes: int) -> float:
        """Best current estimate (observed EMA when available, else the
        prior) — used for HEFT placement decisions."""
        with self._lock:
            per_byte = self._observed.get((op, pe_kind))
        if per_byte is None:
            return self.prior_estimate(op, pe_kind, nbytes)
        return self.LAUNCH_LATENCY_S + nbytes * per_byte

    def observe(self, op: str, pe_kind: str, nbytes: int, seconds: float) -> None:
        if nbytes <= 0 or seconds <= 0:
            return
        per_byte = max(seconds - self.LAUNCH_LATENCY_S, 0.0) / nbytes
        with self._lock:
            prev = self._observed.get((op, pe_kind))
            self._observed[(op, pe_kind)] = (
                per_byte if prev is None
                else (1 - self.EMA) * prev + self.EMA * per_byte
            )

    def mean_estimate(self, op: str, pe_kinds: Sequence[str], nbytes: int) -> float:
        kinds = list(pe_kinds) or ["cpu"]
        return sum(self.estimate(op, k, nbytes) for k in kinds) / len(kinds)
