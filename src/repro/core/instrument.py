"""Transfer instrumentation — the evidence layer for the paper's claims.

Every copy the runtime performs (host→PE, PE→PE, PE→host) is recorded in
a :class:`TransferLedger`.  The paper's headline results are *eliminated
copies* (Fig 1, Fig 5: CPU-ACC saves 1 copy, ACC-ACC saves 3) — with the
ledger we can assert those counts exactly, and additionally integrate a
modeled transfer time under configurable link bandwidths.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import Counter
from typing import Iterator, Optional

from .locations import DEFAULT_BANDWIDTH_MODEL, BandwidthModel, Location

__all__ = ["TransferLedger", "ledger", "Timer"]


@dataclasses.dataclass
class TransferLedger:
    """Counts copies and bytes per (src, dst) pair + modeled seconds."""

    bandwidth_model: BandwidthModel = dataclasses.field(
        default_factory=lambda: DEFAULT_BANDWIDTH_MODEL
    )
    copies: Counter = dataclasses.field(default_factory=Counter)
    bytes_moved: Counter = dataclasses.field(default_factory=Counter)
    modeled_seconds: float = 0.0
    flag_checks: int = 0  # last-resource-flag checks (§5.2.2 microbench)

    def record(self, src: Location, dst: Location, nbytes: int) -> None:
        key = (str(src), str(dst))
        self.copies[key] += 1
        self.bytes_moved[key] += nbytes
        self.modeled_seconds += self.bandwidth_model.seconds(src, dst, nbytes)

    def record_flag_check(self, n: int = 1) -> None:
        self.flag_checks += n

    # -- aggregates -------------------------------------------------------
    @property
    def total_copies(self) -> int:
        return sum(self.copies.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_moved.values())

    def reset(self) -> None:
        self.copies.clear()
        self.bytes_moved.clear()
        self.modeled_seconds = 0.0
        self.flag_checks = 0

    def snapshot(self) -> dict:
        return {
            "total_copies": self.total_copies,
            "total_bytes": self.total_bytes,
            "modeled_seconds": self.modeled_seconds,
            "flag_checks": self.flag_checks,
            "by_pair": {f"{s}->{d}": c for (s, d), c in sorted(self.copies.items())},
        }


#: process-global ledger; runtimes may use their own instance instead.
ledger = TransferLedger()


@contextlib.contextmanager
def fresh_ledger(l: Optional[TransferLedger] = None) -> Iterator[TransferLedger]:
    """Context manager: reset (or swap in) a ledger for one experiment."""
    target = l if l is not None else ledger
    saved = target.snapshot()
    target.reset()
    try:
        yield target
    finally:
        del saved  # snapshots are for callers; we do not restore


class Timer:
    """Monotonic wall-clock timer for benchmark harnesses."""

    def __enter__(self) -> "Timer":
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self.start
