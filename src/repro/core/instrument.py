"""Transfer instrumentation — the evidence layer for the paper's claims.

Every copy the runtime performs (host→PE, PE→PE, PE→host) is recorded in
a :class:`TransferLedger`.  The paper's headline results are *eliminated
copies* (Fig 1, Fig 5: CPU-ACC saves 1 copy, ACC-ACC saves 3) — with the
ledger we can assert those counts exactly, and additionally integrate a
modeled transfer time under configurable link bandwidths.

Both :class:`TransferLedger` and :class:`Timeline` are thread-safe: the
graph executor (:mod:`repro.core.executor`) records from one worker
thread per PE plus a transfer pool concurrently.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import Counter
from typing import Iterator, List, Optional

from .locations import DEFAULT_BANDWIDTH_MODEL, BandwidthModel, Location

__all__ = ["TransferLedger", "ledger", "Timer", "Timeline", "TimelineEvent",
           "TransferEvent", "jain_index"]


def jain_index(values) -> float:
    """Jain's fairness index over a sequence of non-negative allocations:
    ``(Σx)² / (n·Σx²)`` — 1.0 means perfectly equal, 1/n means one
    participant got everything.  Empty or all-zero input is vacuously
    fair (1.0)."""
    vals = [float(v) for v in values]
    if not vals:
        return 1.0
    sq = sum(v * v for v in vals)
    if sq == 0.0:
        return 1.0
    total = sum(vals)
    return (total * total) / (len(vals) * sq)


@dataclasses.dataclass
class TransferLedger:
    """Counts copies and bytes per (src, dst) pair + modeled seconds.

    Capacity-pressure counters (ISSUE 2): every eviction a
    :class:`~repro.core.hete.HeteContext` performs under arena pressure is
    recorded here — how many, how many bytes were dirty (written back to
    host through the coherence paths; those copies also appear in
    :attr:`copies` as ``loc->host``), and how much modeled time staging
    paths stalled on eviction write-backs (spill stalls).
    """

    bandwidth_model: BandwidthModel = dataclasses.field(
        default_factory=lambda: DEFAULT_BANDWIDTH_MODEL
    )
    copies: Counter = dataclasses.field(default_factory=Counter)
    bytes_moved: Counter = dataclasses.field(default_factory=Counter)
    # per-(src,dst) modeled seconds — with a topology model the keys are
    # the individual *links* each routed transfer traversed (ISSUE 3)
    modeled_by_pair: Counter = dataclasses.field(default_factory=Counter)
    modeled_seconds: float = 0.0
    flag_checks: int = 0  # last-resource-flag checks (§5.2.2 microbench)
    # -- capacity-pressure counters (ISSUE 2) --
    evictions: Counter = dataclasses.field(default_factory=Counter)  # per loc
    evicted_bytes: int = 0
    writeback_bytes: int = 0  # dirty bytes written back on eviction
    spill_stall_s: float = 0.0  # modeled seconds staging spent on write-backs
    n_spill_stalls: int = 0  # alloc attempts that had to evict first
    prefetch_deferrals: int = 0  # prefetches skipped to protect queued readers
    # -- spill-to-peer counters (ISSUE 3) --
    spills_to_peer: int = 0  # evictions whose write-back went to a peer arena
    peer_writeback_bytes: int = 0  # dirty bytes spilled device→device
    # -- per-client (multi-tenant) counters (ISSUE 5) --
    client_tasks: Counter = dataclasses.field(default_factory=Counter)
    client_bytes: Counter = dataclasses.field(default_factory=Counter)
    client_service_s: Counter = dataclasses.field(default_factory=Counter)
    client_stall_s: Counter = dataclasses.field(default_factory=Counter)
    client_evictions: Counter = dataclasses.field(default_factory=Counter)
    client_writeback_bytes: Counter = dataclasses.field(default_factory=Counter)
    client_failures: Counter = dataclasses.field(default_factory=Counter)
    # -- tracing hook (ISSUE 6): when a TraceCollector is attached, every
    # record() emits a matching trace event *under the ledger lock*, so
    # trace_lint's conservation check (trace events == ledger counters)
    # holds by construction rather than by sampling.
    tracer: object = dataclasses.field(default=None, repr=False, compare=False)
    trace_label: str = dataclasses.field(default="", repr=False, compare=False)
    _lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False, compare=False
    )

    def record(self, src: Location, dst: Location, nbytes: int,
               seconds: Optional[float] = None) -> None:
        """Record one copy (or one hop of a routed copy).  ``seconds``
        overrides the bandwidth model's estimate — routed staging passes
        the per-link service time so multi-hop accounting stays exact."""
        key = (str(src), str(dst))
        if seconds is None:
            seconds = self.bandwidth_model.seconds(src, dst, nbytes)
        with self._lock:
            self.copies[key] += 1
            self.bytes_moved[key] += nbytes
            self.modeled_by_pair[key] += seconds
            self.modeled_seconds += seconds
            if self.tracer is not None:
                self.tracer.transfer(self.trace_label, key[0], key[1],
                                     nbytes, seconds)

    def attach_tracer(self, tracer, label: str) -> dict:
        """Attach a TraceCollector atomically w.r.t. in-flight records.

        Returns the per-link counters already accumulated at attach time
        — the conservation baseline ``trace_lint`` nets out, since those
        copies predate the trace."""
        with self._lock:
            baseline = self.per_link_summary()
            self.tracer = tracer
            self.trace_label = label
        return baseline

    def record_eviction(self, loc: Location, nbytes: int,
                        writeback_bytes: int, stall_s: float,
                        target: Optional[Location] = None,
                        owner: Optional[str] = None) -> None:
        with self._lock:
            self.evictions[str(loc)] += 1
            self.evicted_bytes += nbytes
            self.writeback_bytes += writeback_bytes
            self.spill_stall_s += stall_s
            if owner is not None:
                self.client_evictions[owner] += 1
                self.client_writeback_bytes[owner] += writeback_bytes
            if (target is not None and target.kind != "host"
                    and writeback_bytes > 0):
                self.spills_to_peer += 1
                self.peer_writeback_bytes += writeback_bytes

    def record_spill_stall(self, n: int = 1) -> None:
        with self._lock:
            self.n_spill_stalls += n

    def record_prefetch_deferral(self, n: int = 1) -> None:
        with self._lock:
            self.prefetch_deferrals += n

    # -- per-client (multi-tenant) accounting (ISSUE 5) ---------------------
    def record_client_task(self, client: Optional[str], nbytes: int,
                           service_s: float) -> None:
        """One completed task attributed to ``client``: its input bytes
        and the modeled service it consumed (staging + spill stall +
        compute estimate + output transfer) — the quantity
        :meth:`fairness_report` computes Jain's index over."""
        if client is None:
            return
        with self._lock:
            self.client_tasks[client] += 1
            self.client_bytes[client] += nbytes
            self.client_service_s[client] += service_s

    def record_client_stall(self, client: Optional[str],
                            seconds: float) -> None:
        """Seconds a client's submitter spent blocked in QoS admission
        (backpressure window or DRR queue)."""
        if client is None:
            return
        with self._lock:
            self.client_stall_s[client] += seconds

    def record_client_failure(self, client: Optional[str]) -> None:
        if client is None:
            return
        with self._lock:
            self.client_failures[client] += 1

    def client_names(self) -> list:
        with self._lock:
            names = (set(self.client_tasks) | set(self.client_bytes)
                     | set(self.client_service_s) | set(self.client_stall_s)
                     | set(self.client_evictions) | set(self.client_failures))
        return sorted(names)

    def fairness_report(self, weights: Optional[dict] = None,
                        clients: Optional[list] = None) -> dict:
        """Per-client QoS evidence + Jain's fairness index.

        The index is computed over each selected client's
        *weight-normalized modeled service* (``service_model_s /
        weight``): with equal weights it measures how equally the runtime
        served the clients; with configured weights, 1.0 means service
        landed exactly in the weight ratios.  ``clients`` restricts the
        index to a subset (e.g. the equal-demand light tenants in
        ``bench_multitenant`` — comparing tenants with deliberately
        unequal demands would conflate demand with unfairness);
        ``weights`` default to 1.0 per client.
        """
        names = sorted(clients) if clients is not None else self.client_names()
        w = {n: float((weights or {}).get(n, 1.0)) for n in names}
        with self._lock:
            per = {
                n: {
                    "tasks": self.client_tasks.get(n, 0),
                    "bytes": self.client_bytes.get(n, 0),
                    "service_model_s": self.client_service_s.get(n, 0.0),
                    "stall_s": self.client_stall_s.get(n, 0.0),
                    "evictions": self.client_evictions.get(n, 0),
                    "failures": self.client_failures.get(n, 0),
                    "weight": w[n],
                }
                for n in names
            }
        shares = [per[n]["service_model_s"] / w[n] for n in names]
        return {
            "clients": per,
            "n_clients": len(names),
            "jain_index": jain_index(shares),
        }

    def record_flag_check(self, n: int = 1) -> None:
        # Deliberately lock-free: this sits on the §5.2.2 flag-check hot
        # path, and flag_checks is a diagnostic counter where a rare lost
        # update under contention is acceptable.
        self.flag_checks += n

    # -- aggregates -------------------------------------------------------
    @property
    def total_copies(self) -> int:
        return sum(self.copies.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_moved.values())

    @property
    def total_evictions(self) -> int:
        return sum(self.evictions.values())

    def per_link_summary(self) -> dict:
        """The per-(src,dst) traffic matrix: one row per directed pair
        (with a topology model, per *link* — multi-hop transfers appear
        once per hop they traversed)."""
        with self._lock:
            return {
                f"{s}->{d}": {
                    "copies": c,
                    "bytes": self.bytes_moved[(s, d)],
                    "modeled_s": self.modeled_by_pair[(s, d)],
                }
                for (s, d), c in sorted(self.copies.items())
            }

    def reset(self) -> None:
        with self._lock:
            self.copies.clear()
            self.bytes_moved.clear()
            self.modeled_by_pair.clear()
            self.modeled_seconds = 0.0
            self.flag_checks = 0
            self.evictions.clear()
            self.evicted_bytes = 0
            self.writeback_bytes = 0
            self.spill_stall_s = 0.0
            self.n_spill_stalls = 0
            self.prefetch_deferrals = 0
            self.spills_to_peer = 0
            self.peer_writeback_bytes = 0
            self.client_tasks.clear()
            self.client_bytes.clear()
            self.client_service_s.clear()
            self.client_stall_s.clear()
            self.client_evictions.clear()
            self.client_writeback_bytes.clear()
            self.client_failures.clear()
            if self.tracer is not None:
                # Open a fresh conservation epoch: trace events recorded
                # before this point no longer correspond to any counter.
                self.tracer.ledger_reset(self.trace_label)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "total_copies": self.total_copies,
                "total_bytes": self.total_bytes,
                "modeled_seconds": self.modeled_seconds,
                "flag_checks": self.flag_checks,
                "by_pair": {
                    f"{s}->{d}": c for (s, d), c in sorted(self.copies.items())
                },
                "per_link": self.per_link_summary(),
                "evictions": dict(sorted(self.evictions.items())),
                "total_evictions": self.total_evictions,
                "evicted_bytes": self.evicted_bytes,
                "writeback_bytes": self.writeback_bytes,
                "spill_stall_s": self.spill_stall_s,
                "n_spill_stalls": self.n_spill_stalls,
                "prefetch_deferrals": self.prefetch_deferrals,
                "spills_to_peer": self.spills_to_peer,
                "peer_writeback_bytes": self.peer_writeback_bytes,
                "client_tasks": dict(sorted(self.client_tasks.items())),
                "client_service_s": dict(
                    sorted(self.client_service_s.items())
                ),
                "client_writeback_bytes": dict(
                    sorted(self.client_writeback_bytes.items())
                ),
            }


#: process-global ledger; runtimes may use their own instance instead.
ledger = TransferLedger()


@contextlib.contextmanager
def fresh_ledger(
    led: Optional[TransferLedger] = None,
) -> Iterator[TransferLedger]:
    """Context manager: reset (or swap in) a ledger for one experiment.

    Semantics (deliberate, tested in ``tests/test_instrument.py``): the
    target ledger is reset on entry and the counts accumulated inside
    the block are **kept** on exit — they are the experiment's evidence.
    Nothing is restored; a caller that needs the pre-experiment counts
    takes its own :meth:`TransferLedger.snapshot` first.
    """
    target = led if led is not None else ledger
    target.reset()
    yield target


class Timer:
    """Monotonic wall-clock timer for benchmark harnesses."""

    def __enter__(self) -> "Timer":
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self.start


# ---------------------------------------------------------------------------
# Per-task timeline — Gantt-style evidence for transfer/compute overlap
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimelineEvent:
    """One executed task: wall-clock and modeled intervals on one PE.

    ``model_start``/``model_end`` come from the executor's schedule
    simulation (modeled transfer seconds + measured compute seconds), so
    a Gantt over them shows where overlap saved modeled makespan even on
    a box where all PEs share one physical CPU.
    """

    task: str
    pe: str
    wall_start: float
    wall_end: float
    model_start: float
    model_end: float
    transfer_s: float  # modeled input-staging seconds (0 on flag hits)
    compute_s: float  # measured kernel seconds
    out_transfer_s: float = 0.0  # modeled output writeback (reference policy)
    spill_s: float = 0.0  # modeled eviction write-back stall during staging
    # modeled instant the kernel itself starts (staging + spill done);
    # -1.0 on legacy events — consumers fall back to model_start+transfer_s
    compute_start_m: float = -1.0
    node: int = -1  # graph node index (-1 when not graph-scheduled)


@dataclasses.dataclass(frozen=True)
class TransferEvent:
    """One hop of a routed transfer occupying one interconnect link in
    modeled time — the Gantt's transfer lanes (ISSUE 3)."""

    link: str  # link label, e.g. "host:cpu->device:gpu0"
    task: str  # consumer task the bytes were staged for
    nbytes: int
    model_start: float
    model_end: float
    node: int = -1  # consumer's graph node index (-1 when unknown)


class Timeline:
    """Thread-safe ordered record of :class:`TimelineEvent` (per-PE
    compute lanes) and :class:`TransferEvent` (per-link transfer
    lanes)."""

    def __init__(self) -> None:
        self._events: List[TimelineEvent] = []
        self._transfers: List[TransferEvent] = []
        self._lock = threading.Lock()

    def add(self, ev: TimelineEvent) -> None:
        with self._lock:
            self._events.append(ev)

    def add_transfer(self, ev: TransferEvent) -> None:
        with self._lock:
            self._transfers.append(ev)

    def events(self) -> List[TimelineEvent]:
        with self._lock:
            return sorted(self._events, key=lambda e: (e.model_start, e.pe))

    def transfers(self) -> List[TransferEvent]:
        with self._lock:
            return sorted(
                self._transfers, key=lambda e: (e.model_start, e.link)
            )

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    @property
    def makespan_model(self) -> float:
        with self._lock:
            return max((e.model_end for e in self._events), default=0.0)

    @property
    def total_spill_s(self) -> float:
        """Modeled seconds tasks stalled on eviction write-backs."""
        with self._lock:
            return sum(e.spill_s for e in self._events)

    def gantt(self, width: int = 72) -> str:
        """Render a text Gantt chart over modeled time: one row per PE
        (``#`` compute) and, when routed transfers were recorded, one
        lane per interconnect link (``=`` link busy)."""
        width = max(width, 12)  # room for the axis label row
        evs = self.events()
        xfers = self.transfers()
        if not evs and not xfers:
            return "(empty timeline)"
        span = (
            max(
                [e.model_end for e in evs]
                + [x.model_end for x in xfers]
            )
            or 1.0
        )
        labels = sorted({e.pe for e in evs}) + sorted({x.link for x in xfers})
        lw = max([10] + [len(label) for label in labels])

        def paint(line, start, end, mark):
            a = int(start / span * (width - 1))
            b = max(a + 1, int(end / span * (width - 1)))
            for i in range(a, min(b, width)):
                line[i] = mark if line[i] == " " else "+"

        rows = []
        for pe in sorted({e.pe for e in evs}):
            line = [" "] * width
            for e in evs:
                if e.pe == pe:
                    paint(line, e.model_start, e.model_end, "#")
            rows.append(f"{pe:>{lw}s} |{''.join(line)}|")
        for link in sorted({x.link for x in xfers}):
            line = [" "] * width
            for x in xfers:
                if x.link == link:
                    paint(line, x.model_start, x.model_end, "=")
            rows.append(f"{link:>{lw}s} |{''.join(line)}|")
        rows.append(
            f"{'':>{lw}s}  0{'':{width - 10}s}{span * 1e3:.2f} ms (modeled)"
        )
        return "\n".join(rows)
