"""Arena allocators — the paper's two "marking systems" (RIMMS §3.2.2).

Two interchangeable heap managers over a byte-addressed resource arena:

* :class:`BitsetAllocator` — the paper's lightweight bitset-based marking
  system.  The arena is divided into fixed-size blocks; one bit per block
  marks it used.  Allocation is an exhaustive first-fit search for a
  contiguous run of free blocks.  Metadata footprint: 1 bit / block.

* :class:`NextFitAllocator` — the paper's NF-based marking system.  A
  circular doubly-linked list of segments with a rolling search pointer;
  allocation splits the first fitting free segment, deallocation coalesces
  with free neighbours.  Metadata footprint ≈ 17 bytes / entry (paper's
  figure; we model the same per-entry cost in :meth:`metadata_bytes`).
  No fixed block-size constraint → arbitrary-size allocations.

Both are host-side metadata structures (exactly as in the paper, where the
marking systems run on the host CPU and manage accelerator memory): they
never touch the payload bytes, they only hand out ``(offset, size)``
extents inside a resource memory region (a UDMA buffer on the ZCU102; a
KV-page pool or a pinned staging arena in this JAX port).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "AllocError",
    "Extent",
    "BitsetAllocator",
    "NextFitAllocator",
    "make_allocator",
]


class AllocError(Exception):
    """Raised when an allocation cannot be satisfied.

    The paper terminates the runtime in this case (§3.2.2: "If there is
    not enough space for allocation, the runtime system is terminated").
    We surface the condition as an exception so the embedding runtime can
    choose to terminate, evict, or spill.
    """


@dataclasses.dataclass(frozen=True)
class Extent:
    """An allocated extent inside an arena: ``[offset, offset + size)``."""

    offset: int
    size: int

    @property
    def end(self) -> int:
        return self.offset + self.size


class _AllocatorBase:
    """Shared bookkeeping: capacity, counters for benchmarks.

    Eviction support (ISSUE 2): ``alloc`` accepts an optional opaque
    ``tag`` (the owning buffer identity, set by the eviction engine in
    :mod:`repro.core.hete`); :meth:`tags` exposes the live
    ``offset → tag`` map so pressure diagnostics can attribute every
    resident extent.  ``n_coalesces`` counts free-list merges and
    :meth:`largest_free` reports the biggest contiguous hole — together
    they tell whether an :class:`AllocError` under pressure means "full"
    or "fragmented".
    """

    def __init__(self, capacity: int) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.used_bytes = 0
        # Instrumentation for the paper's Fig 7 / Fig 10 benchmarks.
        self.n_allocs = 0
        self.n_frees = 0
        self.n_steps = 0  # search steps taken (comparisons / node visits)
        self.n_coalesces = 0  # free-list merges performed on free()
        self._tags: dict = {}  # offset -> opaque per-extent metadata

    # --- interface -----------------------------------------------------
    def alloc(self, nbytes: int, tag=None) -> Extent:  # pragma: no cover
        raise NotImplementedError

    def free(self, extent: Extent) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def metadata_bytes(self) -> int:  # pragma: no cover - abstract
        raise NotImplementedError

    def largest_free(self) -> int:  # pragma: no cover - abstract
        raise NotImplementedError

    @property
    def free_bytes(self) -> int:
        return self.capacity - self.used_bytes

    def tags(self) -> dict:
        """Live ``offset → tag`` map for resident extents."""
        return dict(self._tags)

    def frag_stats(self) -> dict:
        """Fragmentation evidence for pressure diagnostics."""
        largest = self.largest_free()
        return {
            "free_bytes": self.free_bytes,
            "largest_free": largest,
            "frag_ratio": 0.0 if not self.free_bytes
            else 1.0 - largest / self.free_bytes,
            "n_coalesces": self.n_coalesces,
        }

    def reset_counters(self) -> None:
        self.n_allocs = self.n_frees = self.n_steps = self.n_coalesces = 0


class BitsetAllocator(_AllocatorBase):
    """Bitset marking system: 1 bit per fixed-size block, first-fit runs.

    The bitmap is held in a single Python int (bit ``i`` set ⇔ block ``i``
    used), so the contiguous-run search is a handful of big-int AND/shift
    operations (a word-parallel version of the paper's exhaustive scan)
    while remaining semantically a first-fit over all blocks.
    """

    def __init__(self, capacity: int, block_size: int) -> None:
        super().__init__(capacity)
        if block_size <= 0:
            raise ValueError(f"block_size must be positive, got {block_size}")
        self.block_size = int(block_size)
        self.n_blocks = (self.capacity + self.block_size - 1) // self.block_size
        self._bits = 0  # bit i set == block i in use
        self._full_mask = (1 << self.n_blocks) - 1

    # -- helpers --------------------------------------------------------
    def _find_run(self, k: int) -> int:
        """Lowest block index starting a run of ``k`` free blocks, or -1.

        Uses shift-doubling: ``g`` keeps, at bit ``i``, whether blocks
        ``i .. i+s-1`` are all free; doubling ``s`` reaches ``k`` in
        O(log k) big-int ops.
        """
        g = ~self._bits & self._full_mask
        s = 1
        while s < k and g:
            step = min(s, k - s)
            g &= g >> step
            s += step
            self.n_steps += 1
        if g == 0:
            return -1
        return (g & -g).bit_length() - 1

    # -- interface -------------------------------------------------------
    def alloc(self, nbytes: int, tag=None) -> Extent:
        if nbytes <= 0:
            raise ValueError(f"alloc size must be positive, got {nbytes}")
        k = (nbytes + self.block_size - 1) // self.block_size
        idx = self._find_run(k)
        if idx < 0 or idx + k > self.n_blocks:
            raise AllocError(
                f"bitset arena exhausted: need {k} contiguous blocks "
                f"({nbytes} B), capacity {self.n_blocks} blocks"
            )
        run_mask = ((1 << k) - 1) << idx
        self._bits |= run_mask
        self.n_allocs += 1
        size = k * self.block_size
        self.used_bytes += size
        if tag is not None:
            self._tags[idx * self.block_size] = tag
        return Extent(idx * self.block_size, size)

    def free(self, extent: Extent) -> None:
        if extent.offset % self.block_size or extent.size % self.block_size:
            raise ValueError(f"extent {extent} not block-aligned")
        idx = extent.offset // self.block_size
        k = extent.size // self.block_size
        run_mask = ((1 << k) - 1) << idx
        if self._bits & run_mask != run_mask:
            raise AllocError(f"double free / corrupt extent: {extent}")
        self._bits &= ~run_mask
        self.n_frees += 1
        self.used_bytes -= extent.size
        self._tags.pop(extent.offset, None)

    def metadata_bytes(self) -> int:
        return (self.n_blocks + 7) // 8  # 1 bit per block

    def largest_free(self) -> int:
        """Largest contiguous free run in bytes (shift-doubling probe)."""
        g = ~self._bits & self._full_mask
        if g == 0:
            return 0
        # Binary-search the largest k with a surviving run: double until
        # extinction, then the last surviving mask's run length is exact
        # enough for diagnostics (lower bound within 2×); refine linearly.
        k = 1
        cur = g
        while True:
            nxt = cur & (cur >> k)
            if nxt == 0:
                break
            cur = nxt
            k *= 2
        # cur holds runs of length k; extend one block at a time
        n = k
        while True:
            nxt = cur & (g >> n)
            if nxt == 0:
                break
            cur = nxt
            n += 1
        return n * self.block_size


@dataclasses.dataclass
class _Seg:
    """Next-fit linked-list node. ~17 B of payload metadata in the paper."""

    offset: int
    size: int
    used: bool
    prev: Optional["_Seg"] = dataclasses.field(default=None, repr=False)
    next: Optional["_Seg"] = dataclasses.field(default=None, repr=False)


class NextFitAllocator(_AllocatorBase):
    """NF marking system: rolling pointer, split on alloc, coalesce on free."""

    #: the paper's figure for per-entry metadata footprint.
    BYTES_PER_ENTRY = 17

    def __init__(self, capacity: int) -> None:
        super().__init__(capacity)
        head = _Seg(0, capacity, used=False)
        head.prev = head.next = head  # circular
        self._head = head
        self._cursor = head
        self._n_segs = 1
        # offset -> segment, for O(1) free()
        self._by_offset = {0: head}

    # -- interface -------------------------------------------------------
    def alloc(self, nbytes: int, tag=None) -> Extent:
        if nbytes <= 0:
            raise ValueError(f"alloc size must be positive, got {nbytes}")
        seg = self._cursor
        for _ in range(self._n_segs):
            self.n_steps += 1
            if not seg.used and seg.size >= nbytes:
                ext = self._take(seg, nbytes)
                if tag is not None:
                    self._tags[ext.offset] = tag
                return ext
            seg = seg.next
        raise AllocError(
            f"next-fit arena exhausted: need {nbytes} B, "
            f"free {self.free_bytes} B (largest hole {self.largest_free()} B)"
        )

    def _take(self, seg: _Seg, nbytes: int) -> Extent:
        if seg.size > nbytes:
            # Split: first part sized exactly to the request (paper §3.2.2),
            # remainder stays free and becomes the new rolling cursor.
            rest = _Seg(seg.offset + nbytes, seg.size - nbytes, used=False)
            rest.prev, rest.next = seg, seg.next
            seg.next.prev = rest
            seg.next = rest
            seg.size = nbytes
            self._by_offset[rest.offset] = rest
            self._n_segs += 1
            self._cursor = rest
        else:
            self._cursor = seg.next
        seg.used = True
        self.n_allocs += 1
        self.used_bytes += seg.size
        return Extent(seg.offset, seg.size)

    def free(self, extent: Extent) -> None:
        seg = self._by_offset.get(extent.offset)
        if seg is None or not seg.used or seg.size != extent.size:
            raise AllocError(f"double free / corrupt extent: {extent}")
        seg.used = False
        self.n_frees += 1
        self.used_bytes -= seg.size
        self._tags.pop(extent.offset, None)
        # Coalesce with next, then prev (watching the circular wrap).
        nxt = seg.next
        if nxt is not seg and not nxt.used and nxt.offset == seg.offset + seg.size:
            self._absorb(seg, nxt)
        prv = seg.prev
        if prv is not seg and not prv.used and seg.offset == prv.offset + prv.size:
            self._absorb(prv, seg)

    def _absorb(self, left: _Seg, right: _Seg) -> None:
        """Merge ``right`` into ``left`` (both free, adjacent)."""
        if self._cursor is right:
            self._cursor = left
        left.size += right.size
        left.next = right.next
        right.next.prev = left
        del self._by_offset[right.offset]
        self._n_segs -= 1
        self.n_coalesces += 1

    def metadata_bytes(self) -> int:
        return self._n_segs * self.BYTES_PER_ENTRY

    def largest_free(self) -> int:
        """Largest free segment in bytes (free list is always coalesced)."""
        largest = 0
        seg = self._head
        for _ in range(self._n_segs):
            if not seg.used and seg.size > largest:
                largest = seg.size
            seg = seg.next
        return largest

    # -- introspection (tests / benchmarks) ------------------------------
    def segments(self) -> list[tuple[int, int, bool]]:
        out = []
        seg = self._head
        for _ in range(self._n_segs):
            out.append((seg.offset, seg.size, seg.used))
            seg = seg.next
        return sorted(out)


def make_allocator(kind: str, capacity: int, block_size: int = 4096):
    """Factory. ``kind`` ∈ {"bitset", "nextfit"}."""
    if kind == "bitset":
        return BitsetAllocator(capacity, block_size)
    if kind == "nextfit":
        return NextFitAllocator(capacity)
    raise ValueError(f"unknown allocator kind {kind!r}")
