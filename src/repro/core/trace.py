"""Full-lifecycle runtime tracing and metrics (ISSUE 6).

Three pieces, deliberately decoupled from the rest of ``core`` (this
module imports only the stdlib, so every other layer may import it):

``TraceCollector``
    Low-overhead event collection.  Wall-clock events (spans and
    instants) go into per-thread append-only ring buffers — no locks on
    the record path, bounded memory, a drop counter when a ring fills.
    Modeled-time events are derived in bulk from ``Timeline`` objects
    pushed at sync points (end of ``Runtime.run`` / ``GraphExecutor.run``
    / ``Session.close``), so the deterministic replay timebase costs
    nothing while tasks execute.  ``export()`` writes Chrome/Perfetto
    trace-event JSON with two process groups — pid 1 "wall clock",
    pid 2 "modeled time" — and one track per PE, per interconnect link,
    and per tenant in each group.  Open the file in ui.perfetto.dev.

``MetricsRegistry``
    Named counters, gauges and HDR-style log-bucketed histograms
    (32 sub-buckets per octave => <= 2.2 % relative quantisation error
    on percentiles).  ``Session.qos_report()`` uses the histograms to
    publish per-client p50/p95/p99 modeled latency.

``trace_lint``
    A validator that treats the trace as evidence and cross-checks the
    executor against it: span well-formedness (no negative durations,
    no overlapping intervals on exclusive resource tracks), transfer
    events reconciling *exactly* with ``TransferLedger`` copies/bytes
    (conservation holds by construction — the ledger itself emits the
    trace event under its lock), and no modeled compute span starting
    before its staging spans end.  ``python -m repro.core.trace f.json``
    runs it from the command line; CI uses it as a fail-fast gate.

Tracing is off by default.  Enable per session via
``Session(trace=True)``, scoped via the ``trace()`` context manager, or
process-wide via ``install_global()`` (newly created ``HeteContext``
objects auto-attach — this is how ``benchmarks/run.py --trace-dir``
traces every benchmark without touching bench internals).
"""

from __future__ import annotations

import contextlib
import json
import math
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

__all__ = [
    "TraceCollector",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "trace",
    "trace_lint",
    "install_global",
    "global_collector",
]

# Wall-clock events live in process group 1, modeled-time events in
# group 2, so Perfetto renders the two timebases as separate track
# groups that can be compared side by side.
WALL_PID = 1
MODEL_PID = 2

# Span categories that claim an exclusive resource (a PE's execution
# port, an interconnect link).  Intervals in these categories must not
# overlap within a track; "stage" is deliberately absent because staging
# legitimately overlaps compute (prefetch, double-buffering).
EXCLUSIVE_CATS = frozenset({"compute", "writeback", "transfer"})

_ZERO_BUCKET = -(1 << 60)  # histogram bucket index for v <= 0


class _Ring:
    """One thread's append-only event buffer (single writer, no lock)."""

    __slots__ = ("events", "capacity", "drops", "thread_name")

    def __init__(self, capacity: int, thread_name: str):
        self.events: List[tuple] = []
        self.capacity = capacity
        self.drops = 0
        self.thread_name = thread_name


class TraceCollector:
    """Collects wall + modeled events; exports Perfetto trace JSON.

    Wall events are tuples ``(ph, name, cat, track, t0, dur, args)``
    with times in seconds relative to the collector's epoch; modeled
    events use the same layout with times in modeled seconds.
    """

    def __init__(self, capacity_per_thread: int = 1 << 16):
        self.enabled = True
        self._cap = int(capacity_per_thread)
        self._t0 = time.perf_counter()
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._rings: List[_Ring] = []
        self._model: List[tuple] = []  # modeled-timebase events
        self._contexts: Dict[str, Any] = {}  # label -> HeteContext
        self._baseline: Dict[str, dict] = {}  # label -> per_link at attach
        self._epoch: Dict[str, int] = {}  # label -> ledger reset epoch
        self._edges: Dict[str, List[Tuple[int, int]]] = {}  # run -> dep edges
        self._divergence: Optional[dict] = None  # wall/modeled ratio table
        self._nctx = 0
        self._nrun = 0

    # -- hot path ----------------------------------------------------------

    def _ring(self) -> _Ring:
        r = getattr(self._tls, "ring", None)
        if r is None:
            r = _Ring(self._cap, threading.current_thread().name)
            self._tls.ring = r
            with self._lock:
                self._rings.append(r)
        return r

    def instant(self, name: str, cat: str, track: str, args: Optional[dict] = None) -> None:
        """Record a wall-clock instant event (now)."""
        if not self.enabled:
            return
        r = self._ring()
        if len(r.events) < r.capacity:
            r.events.append(("i", name, cat, track, time.perf_counter() - self._t0, 0.0, args))
        else:
            r.drops += 1

    def span(
        self,
        name: str,
        cat: str,
        track: str,
        t0: float,
        t1: float,
        args: Optional[dict] = None,
    ) -> None:
        """Record a completed wall-clock span; t0/t1 are perf_counter values."""
        if not self.enabled:
            return
        r = self._ring()
        if len(r.events) < r.capacity:
            r.events.append(("X", name, cat, track, t0 - self._t0, t1 - t0, args))
        else:
            r.drops += 1

    def forward_span(
        self,
        name: str,
        cat: str,
        track: str,
        t0: float,
        t1: float,
        *,
        lo: float,
        hi: float,
        args: Optional[dict] = None,
    ) -> None:
        """Record a span measured on *another process's* clock (ISSUE 7).

        ``t0``/``t1`` are the worker's interval already shifted into this
        process's ``perf_counter`` timebase by the caller's clock-offset
        handshake; ``lo``/``hi`` bound it to the parent-observed call
        window, so handshake drift can never produce a span that starts
        before its dispatch or ends after its reply — which would violate
        the exclusive-track invariants :func:`trace_lint` checks."""
        t0 = min(max(t0, lo), hi)
        t1 = min(max(t1, t0), hi)
        self.span(name, cat, track, t0, t1, args)

    def now(self) -> float:
        """perf_counter() — the clock spans must be stamped with."""
        return time.perf_counter()

    # -- ledger hooks (called by TransferLedger under its own lock) --------

    def transfer(self, ctx: str, src: str, dst: str, nbytes: int, seconds) -> None:
        """One data movement, mirrored 1:1 from ``TransferLedger.record``."""
        if not self.enabled:
            return
        r = self._ring()
        if len(r.events) < r.capacity:
            args = {
                "ctx": ctx,
                "src": src,
                "dst": dst,
                "nbytes": int(nbytes),
                "epoch": self._epoch.get(ctx, 0),
            }
            if seconds is not None:
                args["modeled_s"] = float(seconds)
            r.events.append(
                (
                    "i",
                    "copy",
                    "transfer",
                    f"link:{src}->{dst}",
                    time.perf_counter() - self._t0,
                    0.0,
                    args,
                )
            )
        else:
            r.drops += 1

    def ledger_reset(self, ctx: str) -> None:
        """Ledger counters were zeroed: open a fresh conservation epoch."""
        epoch = self._epoch.get(ctx, 0) + 1
        self._epoch[ctx] = epoch
        self._baseline[ctx] = {}
        self.instant("ledger_reset", "ledger", f"ctx:{ctx}", {"ctx": ctx, "epoch": epoch})

    # -- registration / modeled timebase -----------------------------------

    def register_context(self, ctx) -> str:
        """Register a HeteContext; returns its trace label ("ctx0"...)."""
        with self._lock:
            label = f"ctx{self._nctx}"
            self._nctx += 1
            self._contexts[label] = ctx
        return label

    def set_ledger_baseline(self, label: str, per_link: dict) -> None:
        """Per-link counters already in the ledger when the tracer attached
        (excluded from conservation checks for the current epoch)."""
        self._baseline[label] = dict(per_link)

    def add_timeline(self, timeline, label: str = "run") -> str:
        """Derive modeled-time spans from a Timeline; returns the run label.

        Each push gets a unique run prefix ("stream0", "serial1", ...)
        so repeated runs land in distinct modeled track groups.
        """
        with self._lock:
            run = f"{label}{self._nrun}"
            self._nrun += 1
        out: List[tuple] = []
        for ev in timeline.events():
            node = getattr(ev, "node", -1)
            cs = getattr(ev, "compute_start_m", -1.0)
            if cs < ev.model_start or cs > ev.model_end:
                # Legacy event without a recorded compute start: best-effort.
                cs = min(ev.model_end, ev.model_start + ev.transfer_s + ev.spill_s)
            ce = max(cs, ev.model_end - ev.out_transfer_s)
            base = {"task": ev.task, "node": node, "pe": ev.pe}
            if cs > ev.model_start:
                out.append(
                    (
                        "X",
                        ev.task,
                        "stage",
                        f"{run}/pe:{ev.pe}:stage",
                        ev.model_start,
                        cs - ev.model_start,
                        dict(base),
                    )
                )
            cargs = dict(base)
            cargs["wall_start"] = ev.wall_start
            cargs["wall_end"] = ev.wall_end
            out.append(("X", ev.task, "compute", f"{run}/pe:{ev.pe}", cs, ce - cs, cargs))
            if ev.model_end > ce:
                out.append(
                    (
                        "X",
                        ev.task,
                        "writeback",
                        f"{run}/pe:{ev.pe}",
                        ce,
                        ev.model_end - ce,
                        dict(base),
                    )
                )
        for tx in timeline.transfers():
            out.append(
                (
                    "X",
                    tx.task,
                    "transfer",
                    f"{run}/link:{tx.link}",
                    tx.model_start,
                    tx.model_end - tx.model_start,
                    {
                        "task": tx.task,
                        "node": getattr(tx, "node", -1),
                        "nbytes": tx.nbytes,
                        "link": tx.link,
                    },
                )
            )
        with self._lock:
            self._model.extend(out)
        return run

    def add_edges(self, edges: Sequence[Tuple[int, int]], run: str) -> None:
        """Producer->consumer node-index pairs; exported as flow arrows."""
        with self._lock:
            self._edges.setdefault(run, []).extend((int(a), int(b)) for a, b in edges)

    def add_model_instant(
        self,
        name: str,
        cat: str,
        track: str,
        t: float,
        args: Optional[dict] = None,
    ) -> None:
        """Record an instant on the *modeled* timebase (e.g. an SLO alert
        at a replayed finish time).  ``t`` is in modeled seconds."""
        with self._lock:
            self._model.append(("i", name, cat, track, float(t), 0.0, args))

    def set_divergence(self, table: Optional[dict]) -> None:
        """Attach a wall/modeled divergence table (``DivergenceMonitor
        .table()``); embedded under ``rimms.divergence`` on export so the
        profile CLI can render it without re-deriving pairings."""
        with self._lock:
            self._divergence = table

    def add_tenant_spans(self, spans: Sequence[tuple], run: str) -> None:
        """Modeled per-tenant residency: (client, t0, t1, name, node)."""
        out = []
        for client, t0, t1, name, node in spans:
            out.append(
                (
                    "X",
                    name,
                    "admitted",
                    f"{run}/tenant:{client}",
                    float(t0),
                    max(0.0, float(t1) - float(t0)),
                    {"task": name, "node": int(node), "client": client},
                )
            )
        with self._lock:
            self._model.extend(out)

    # -- introspection ------------------------------------------------------

    def drops(self) -> int:
        with self._lock:
            return sum(r.drops for r in self._rings)

    def event_count(self) -> int:
        with self._lock:
            return sum(len(r.events) for r in self._rings) + len(self._model)

    def wall_events(self) -> List[tuple]:
        """Snapshot of all wall events (testing / debugging)."""
        with self._lock:
            rings = list(self._rings)
        out: List[tuple] = []
        for r in rings:
            out.extend(r.events)
        return out

    def pause(self) -> None:
        self.enabled = False

    def resume(self) -> None:
        self.enabled = True

    # -- export -------------------------------------------------------------

    @staticmethod
    def _track_key(track: str) -> tuple:
        run, _, name = track.rpartition("/")
        if name.startswith("tenant:"):
            grp = 0
        elif name.startswith("pe:") and not name.endswith(":stage"):
            grp = 1
        elif name.endswith(":stage"):
            grp = 2
        elif name.startswith("link:"):
            grp = 3
        else:
            grp = 4
        return (run, grp, name)

    def export(self, path=None) -> dict:
        """Assemble the Perfetto trace dict; write JSON if ``path`` given.

        Call at a sync point (session closed / runtime idle) — the wall
        rings are snapshotted, not locked against concurrent writers.
        """
        with self._lock:
            rings = list(self._rings)
            model = list(self._model)
            edges = {k: list(v) for k, v in self._edges.items()}
            contexts = dict(self._contexts)
            baseline = {k: dict(v) for k, v in self._baseline.items()}
            epochs = dict(self._epoch)
            divergence = self._divergence
        wall: List[tuple] = []
        for r in rings:
            wall.extend(list(r.events))

        raw: List[tuple] = []  # (pid, ph, name, cat, track, ts_us, dur_us, args)
        for ph, name, cat, track, t0, dur, args in wall:
            raw.append((WALL_PID, ph, name, cat, track, t0 * 1e6, dur * 1e6, args))
        for ph, name, cat, track, t0, dur, args in model:
            raw.append((MODEL_PID, ph, name, cat, track, t0 * 1e6, dur * 1e6, args))

        tracks = sorted({(pid, tr) for pid, _, _, _, tr, _, _, _ in raw})
        tracks.sort(key=lambda pt: (pt[0],) + self._track_key(pt[1]))
        tid_of = {pt: i + 1 for i, pt in enumerate(tracks)}

        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": WALL_PID, "tid": 0,
             "args": {"name": "wall clock"}},
            {"ph": "M", "name": "process_sort_index", "pid": WALL_PID, "tid": 0,
             "args": {"sort_index": 1}},
            {"ph": "M", "name": "process_name", "pid": MODEL_PID, "tid": 0,
             "args": {"name": "modeled time"}},
            {"ph": "M", "name": "process_sort_index", "pid": MODEL_PID, "tid": 0,
             "args": {"sort_index": 2}},
        ]
        for (pid, track), tid in tid_of.items():
            events.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                           "args": {"name": track}})
            events.append({"ph": "M", "name": "thread_sort_index", "pid": pid, "tid": tid,
                           "args": {"sort_index": tid}})

        for pid, ph, name, cat, track, ts, dur, args in raw:
            ev = {"ph": ph, "name": name, "cat": cat, "pid": pid,
                  "tid": tid_of[(pid, track)], "ts": ts}
            if ph == "X":
                ev["dur"] = dur
            else:
                ev["s"] = "t"
            if args:
                ev["args"] = args
            events.append(ev)

        # Causal flow links: producer compute end -> consumer compute start.
        compute_at: Dict[Tuple[str, int], Tuple[int, float, float]] = {}
        for pid, ph, name, cat, track, ts, dur, args in raw:
            if pid != MODEL_PID or cat != "compute" or not args:
                continue
            node = args.get("node", -1)
            if node is None or node < 0:
                continue
            run = track.rpartition("/")[0]
            compute_at[(run, node)] = (tid_of[(pid, track)], ts, dur)
        fid = 0
        for run, pairs in edges.items():
            for src, dst in pairs:
                p = compute_at.get((run, src))
                c = compute_at.get((run, dst))
                if p is None or c is None:
                    continue
                fid += 1
                s_ts = p[1] + max(p[2] - 0.001, p[2] * 0.5)
                f_ts = c[1] + min(0.001, c[2] * 0.5)
                events.append({"ph": "s", "id": fid, "name": "dep", "cat": "flow",
                               "pid": MODEL_PID, "tid": p[0], "ts": s_ts})
                events.append({"ph": "f", "bp": "e", "id": fid, "name": "dep",
                               "cat": "flow", "pid": MODEL_PID, "tid": c[0], "ts": f_ts})

        ledgers = {}
        for label, ctx in contexts.items():
            led = getattr(ctx, "ledger", None)
            if led is None:
                continue
            ledgers[label] = {
                "per_link": led.per_link_summary(),
                "bytes_moved": led.total_bytes,
            }
        doc = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "rimms": {
                "ledgers": ledgers,
                "baselines": baseline,
                "epochs": epochs,
                "drops": sum(r.drops for r in rings),
                "capacity_per_thread": self._cap,
                "n_wall_events": len(wall),
                "n_model_events": len(model),
            },
        }
        if divergence is not None:
            doc["rimms"]["divergence"] = divergence
        if path is not None:
            with open(path, "w") as fh:
                json.dump(doc, fh)
        return doc


# ---------------------------------------------------------------------------
# Global installation + context-manager enablement
# ---------------------------------------------------------------------------

_global: Optional[TraceCollector] = None


def install_global(collector: Optional[TraceCollector]) -> None:
    """Install a process-global collector (or None to uninstall).

    ``HeteContext`` instances created while one is installed attach to
    it automatically — used by ``benchmarks/run.py --trace-dir`` to
    trace whole benchmarks without touching their internals.
    """
    global _global
    _global = collector


def global_collector() -> Optional[TraceCollector]:
    return _global


@contextlib.contextmanager
def trace(context=None, *, capacity_per_thread: int = 1 << 16, collector=None):
    """Enable tracing for the dynamic extent of a ``with`` block.

    With ``context=``, attaches to that ``HeteContext`` (and detaches on
    exit); without, installs a process-global collector so every context
    created inside the block is traced.  Yields the ``TraceCollector``.
    """
    tc = collector if collector is not None else TraceCollector(capacity_per_thread)
    if context is not None:
        context.set_tracer(tc)
        try:
            yield tc
        finally:
            context.set_tracer(None)
    else:
        prev = _global
        install_global(tc)
        try:
            yield tc
        finally:
            install_global(prev)


# ---------------------------------------------------------------------------
# Metrics: counters, gauges, log-bucketed histograms
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str = ""):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._v}


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str = ""):
        self.name = name
        self._v = 0.0

    def set(self, v: float) -> None:
        self._v = float(v)

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._v}


class Histogram:
    """HDR-style log-bucketed histogram.

    Values land in buckets of constant *relative* width: 32 sub-buckets
    per power of two, i.e. bucket edges at ``2**(i/32)``, bounding the
    quantisation error of any reported percentile at 2^(1/32)-1 < 2.2 %.
    Non-positive values share a single zero bucket.  Memory is O(octaves
    covered * 32), independent of sample count.
    """

    SUBBUCKETS = 32

    __slots__ = ("name", "count", "sum", "min", "max", "_counts", "_lock")

    def __init__(self, name: str = ""):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._counts: Dict[int, int] = {}
        self._lock = threading.Lock()

    def record(self, value: float) -> None:
        v = float(value)
        idx = _ZERO_BUCKET if v <= 0.0 else math.floor(math.log2(v) * self.SUBBUCKETS)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            self._counts[idx] = self._counts.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """Value at the q-th percentile, accurate to the bucket width.

        Returns ``None`` for an empty histogram — callers must not
        confuse "no samples" with "all samples were zero".
        """
        with self._lock:
            return self.percentile_unlocked(q)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "type": "histogram",
                "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile_unlocked(50),
                "p95": self.percentile_unlocked(95),
                "p99": self.percentile_unlocked(99),
            }

    # snapshot() holds the lock; percentile() would deadlock on re-entry.
    def percentile_unlocked(self, q: float) -> Optional[float]:
        if self.count == 0:
            return None
        rank = max(1, math.ceil(self.count * q / 100.0))
        cum = 0
        for idx in sorted(self._counts):
            cum += self._counts[idx]
            if cum >= rank:
                if idx == _ZERO_BUCKET:
                    return 0.0
                hi = 2.0 ** ((idx + 1) / self.SUBBUCKETS)
                return min(max(hi, self.min), self.max)
        return self.max

    # -- state transfer / merge (cross-process aggregation, ISSUE 8) -------

    def to_state(self) -> dict:
        """Picklable/JSON-safe snapshot of the full bucket state."""
        with self._lock:
            return {
                "name": self.name,
                "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "counts": {str(k): v for k, v in self._counts.items()},
            }

    @classmethod
    def from_state(cls, state: dict) -> "Histogram":
        h = cls(state.get("name", ""))
        h.merge(state)
        return h

    def merge(self, other: Union["Histogram", dict]) -> "Histogram":
        """Fold ``other`` (a Histogram or a ``to_state()`` dict) into this
        one.  Exact on counts/sum/min/max and bucket-exact on percentiles
        — merging is associative and commutative because buckets are
        fixed by value, not by sample order."""
        state = other.to_state() if isinstance(other, Histogram) else other
        counts = state.get("counts", {})
        with self._lock:
            self.count += int(state.get("count", 0))
            self.sum += float(state.get("sum", 0.0))
            o_min, o_max = state.get("min"), state.get("max")
            if o_min is not None and o_min < self.min:
                self.min = float(o_min)
            if o_max is not None and o_max > self.max:
                self.max = float(o_max)
            for k, v in counts.items():
                idx = int(k)
                self._counts[idx] = self._counts.get(idx, 0) + int(v)
        return self


class MetricsRegistry:
    """Named instruments; create-or-get semantics, snapshot for export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(f"metric {name!r} already registered as {type(inst).__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def histograms(self) -> List[Tuple[str, Histogram]]:
        with self._lock:
            return sorted(
                (n, i) for n, i in self._instruments.items() if isinstance(i, Histogram)
            )

    def snapshot(self) -> dict:
        with self._lock:
            items = list(self._instruments.items())
        return {name: inst.snapshot() for name, inst in sorted(items)}

    # -- cross-process aggregation (ISSUE 8) --------------------------------

    def state(self) -> dict:
        """Picklable, mergeable registry state.  Counters travel as their
        totals and histograms as full bucket states; gauges are
        point-in-time local readings and deliberately do not transfer."""
        with self._lock:
            items = list(self._instruments.items())
        counters = {n: i.value for n, i in items if isinstance(i, Counter)}
        hists = {n: i.to_state() for n, i in items if isinstance(i, Histogram)}
        return {"counters": counters, "histograms": hists}

    def merge_state(self, state: dict) -> None:
        """Fold a ``state()`` dict (e.g. shipped back from a process-backend
        worker at run end) into this registry."""
        for name, v in sorted((state.get("counters") or {}).items()):
            self.counter(name).inc(int(v))
        for name, hs in sorted((state.get("histograms") or {}).items()):
            self.histogram(name).merge(hs)


# ---------------------------------------------------------------------------
# trace_lint: the trace as a correctness cross-check
# ---------------------------------------------------------------------------


def _load(trace_or_path: Union[dict, str]) -> dict:
    if isinstance(trace_or_path, dict):
        return trace_or_path
    with open(trace_or_path) as fh:
        return json.load(fh)


def trace_lint(trace_or_path: Union[dict, str], eps: float = 1e-9) -> List[str]:
    """Validate a Perfetto trace dict (or JSON file path).

    Returns a list of violation strings (empty == clean):

    1. well-formedness — every complete span has ``dur >= 0``;
    2. exclusivity — spans on exclusive resource tracks (categories
       ``compute``/``writeback``/``transfer``) never overlap within a
       track (``eps`` microseconds of float tolerance);
    3. conservation — wall transfer events in the current ledger epoch
       sum *exactly* (count and bytes per link) to the embedded
       ``TransferLedger`` per-link counters, net of the pre-attach
       baseline;
    4. causality — no modeled compute span starts before its own
       staging/transfer spans end (matched by (run, node));
    5. completeness — the ring buffers dropped nothing;
    6. worker forwarding — wall spans forwarded from process-backend
       workers (tracks ending ``:worker``) carry ``args.backend ==
       "process"`` and nest inside a compute span on the parent PE
       track; a worker span with no enclosing parent compute window is
       an orphan.
    """
    doc = _load(trace_or_path)
    violations: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    meta = doc.get("rimms", {})

    spans = [e for e in events if e.get("ph") == "X"]

    # 1. well-formedness
    for e in spans:
        if e.get("dur", 0) < 0:
            violations.append(
                f"negative duration: {e.get('name')} on tid {e.get('tid')} dur={e.get('dur')}"
            )

    # 2. per-track exclusivity for resource categories
    by_track: Dict[Tuple[int, int], List[dict]] = {}
    for e in spans:
        if e.get("cat") in EXCLUSIVE_CATS:
            by_track.setdefault((e.get("pid"), e.get("tid")), []).append(e)
    names = {
        (e.get("pid"), e.get("tid")): e.get("args", {}).get("name", "?")
        for e in events
        if e.get("ph") == "M" and e.get("name") == "thread_name"
    }
    for key, evs in by_track.items():
        evs.sort(key=lambda e: (e["ts"], e["ts"] + e.get("dur", 0)))
        prev_end = -math.inf
        prev_name = ""
        for e in evs:
            if e["ts"] < prev_end - eps:
                violations.append(
                    f"overlap on track {names.get(key, key)!r}: "
                    f"{e.get('name')} starts at {e['ts']:.3f}us before "
                    f"{prev_name} ends at {prev_end:.3f}us"
                )
            prev_end = max(prev_end, e["ts"] + e.get("dur", 0))
            prev_name = e.get("name", "")

    # 3. conservation vs TransferLedger, per context, current epoch only
    ledgers = meta.get("ledgers", {})
    baselines = meta.get("baselines", {})
    epochs = meta.get("epochs", {})
    traced: Dict[str, Dict[str, List[int]]] = {}  # ctx -> link -> [count, bytes]
    for e in events:
        if e.get("ph") != "i" or e.get("cat") != "transfer":
            continue
        args = e.get("args", {})
        ctx = args.get("ctx")
        if ctx is None or ctx not in ledgers:
            continue
        if args.get("epoch", 0) != epochs.get(ctx, 0):
            continue
        link = f"{args.get('src')}->{args.get('dst')}"
        cell = traced.setdefault(ctx, {}).setdefault(link, [0, 0])
        cell[0] += 1
        cell[1] += int(args.get("nbytes", 0))
    for ctx, led in ledgers.items():
        base = baselines.get(ctx, {})
        got = traced.get(ctx, {})
        links = set(led.get("per_link", {})) | set(got) | set(base)
        for link in sorted(links):
            want = led.get("per_link", {}).get(link, {})
            b = base.get(link, {})
            want_copies = want.get("copies", 0) - b.get("copies", 0)
            want_bytes = want.get("bytes", 0) - b.get("bytes", 0)
            have_copies, have_bytes = got.get(link, [0, 0])
            if have_copies != want_copies or have_bytes != want_bytes:
                violations.append(
                    f"conservation: ctx {ctx} link {link} traced "
                    f"{have_copies} copies/{have_bytes} B but ledger has "
                    f"{want_copies} copies/{want_bytes} B"
                )

    # 4. modeled causality: compute never starts before its staging ends
    compute_start: Dict[Tuple[str, int], float] = {}
    tid_track = {k: v for k, v in names.items()}
    for e in spans:
        track = tid_track.get((e.get("pid"), e.get("tid")), "")
        if e.get("pid") != MODEL_PID:
            continue
        node = e.get("args", {}).get("node", -1)
        if node is None or node < 0:
            continue
        run = track.rpartition("/")[0]
        if e.get("cat") == "compute":
            key = (run, node)
            if key not in compute_start or e["ts"] < compute_start[key]:
                compute_start[key] = e["ts"]
    for e in spans:
        if e.get("pid") != MODEL_PID or e.get("cat") not in ("stage", "transfer"):
            continue
        node = e.get("args", {}).get("node", -1)
        if node is None or node < 0:
            continue
        track = tid_track.get((e.get("pid"), e.get("tid")), "")
        run = track.rpartition("/")[0]
        cs = compute_start.get((run, node))
        if cs is not None and cs + eps < e["ts"] + e.get("dur", 0):
            violations.append(
                f"causality: node {node} ({e.get('name')}) compute starts at "
                f"{cs:.3f}us before its {e.get('cat')} ends at "
                f"{e['ts'] + e.get('dur', 0):.3f}us (run {run or 'wall'!r})"
            )

    # 6. process-backend worker forwarding: every wall span on a
    # ":worker" track must be tagged backend=process and sit inside a
    # compute span on its parent PE track (forward_span clamps to the
    # parent-observed call window, so true forwards always nest; an
    # orphan means a span was forged or mis-clamped).
    worker_eps = max(eps, 1e-3)  # us; forwarded spans are clamped, allow 1 ns
    parent_computes: Dict[str, List[Tuple[float, float]]] = {}
    for e in spans:
        if e.get("pid") != WALL_PID or e.get("cat") != "compute":
            continue
        track = tid_track.get((e.get("pid"), e.get("tid")), "")
        if track.endswith(":worker"):
            continue
        parent_computes.setdefault(track, []).append(
            (e["ts"], e["ts"] + e.get("dur", 0))
        )
    for e in spans:
        if e.get("pid") != WALL_PID:
            continue
        track = tid_track.get((e.get("pid"), e.get("tid")), "")
        if not track.endswith(":worker"):
            continue
        name = e.get("name", "?")
        if e.get("args", {}).get("backend") != "process":
            violations.append(
                f"worker span {name!r} on track {track!r} missing "
                f"args.backend='process'"
            )
        t0, t1 = e["ts"], e["ts"] + e.get("dur", 0)
        windows = parent_computes.get(track[: -len(":worker")], [])
        if not any(w0 - worker_eps <= t0 and t1 <= w1 + worker_eps for w0, w1 in windows):
            violations.append(
                f"orphaned worker span {name!r} on track {track!r}: "
                f"[{t0:.3f}, {t1:.3f}]us not nested in any parent compute span"
            )

    # 5. completeness
    drops = meta.get("drops", 0)
    if drops:
        violations.append(
            f"incomplete trace: {drops} events dropped "
            f"(raise capacity_per_thread, currently {meta.get('capacity_per_thread')})"
        )
    return violations


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.trace",
        description="Lint RIMMS Perfetto traces against runtime invariants.",
    )
    ap.add_argument("paths", nargs="+", help="trace JSON files to validate")
    ns = ap.parse_args(argv)
    failures = 0
    for p in ns.paths:
        try:
            violations = trace_lint(p)
        except (OSError, json.JSONDecodeError) as exc:
            violations = [f"unreadable: {exc}"]
        if violations:
            failures += 1
            print(f"FAIL {p}")
            for v in violations:
                print(f"  - {v}")
        else:
            doc = _load(p)
            meta = doc.get("rimms", {})
            print(
                f"OK   {p} ({meta.get('n_wall_events', '?')} wall + "
                f"{meta.get('n_model_events', '?')} modeled events)"
            )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(_main())
