"""Paged KV-cache pool — the production arena instance of RIMMS on TPU.

This is the load-bearing mapping of the paper's allocator + ``fragment``
machinery onto an LM serving system (DESIGN.md §2, row "hete_Malloc
arena"):

* The device holds one dense KV *page pool* per layer (analogous to the
  ZCU102's physically-contiguous 64 MiB UDMA buffer: jittable code needs
  static shapes, so all KV lives in one preallocated region).
* A host-side **marking system** (bitset or next-fit from
  :mod:`repro.core.allocator`, block = one page) hands out page extents.
* A sequence's KV buffer is *one* extent search fragmented into pages
  (§3.2.3): one ``alloc`` + O(n) fragment instead of n allocs.  When the
  pool is too fragmented for a contiguous run, we degrade to per-page
  allocation (next-fit's rolling cursor makes that amortized O(1)).
* Block tables (page id per logical page of each sequence) are the
  "resource pointers"; they are device inputs to the paged-attention
  kernel.

The pool *arrays* are functional jax values threaded through the serving
step; this class owns only host metadata — exactly the paper's split
(marking metadata on host, payload in resource memory).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .allocator import AllocError, Extent, make_allocator

__all__ = ["PagedKVPool", "init_pool_arrays", "write_token", "gather_kv"]


@dataclasses.dataclass
class _SeqInfo:
    extents: List[Extent]
    page_ids: List[int]
    n_tokens: int = 0


class PagedKVPool:
    """Host-side page bookkeeping for a device KV pool."""

    def __init__(
        self,
        *,
        num_pages: int,
        page_size: int,
        allocator: str = "bitset",
    ) -> None:
        self.num_pages = num_pages
        self.page_size = page_size
        # Arena in units of pages: block_size=1 page.
        self.arena = make_allocator(allocator, capacity=num_pages, block_size=1)
        self._seqs: Dict[int, _SeqInfo] = {}
        self.fragment_allocs = 0  # single-search contiguous grabs
        self.fallback_allocs = 0  # per-page fallbacks under fragmentation

    # -- allocation ---------------------------------------------------------
    def alloc_sequence(self, seq_id: int, n_tokens: int) -> np.ndarray:
        """Reserve pages for ``n_tokens`` tokens; returns int32 page ids."""
        if seq_id in self._seqs:
            raise KeyError(f"sequence {seq_id} already allocated")
        n_pages = max(1, -(-n_tokens // self.page_size))
        extents, page_ids = self._grab(n_pages)
        self._seqs[seq_id] = _SeqInfo(extents, page_ids, n_tokens)
        return np.asarray(page_ids, dtype=np.int32)

    def extend_sequence(self, seq_id: int, n_new_tokens: int) -> np.ndarray:
        """Grow a sequence (decode appends); returns the full page table."""
        info = self._seqs[seq_id]
        need = -(-(info.n_tokens + n_new_tokens) // self.page_size)
        if need > len(info.page_ids):
            extents, page_ids = self._grab(need - len(info.page_ids))
            info.extents.extend(extents)
            info.page_ids.extend(page_ids)
        info.n_tokens += n_new_tokens
        return np.asarray(info.page_ids, dtype=np.int32)

    def _grab(self, n_pages: int) -> Tuple[List[Extent], List[int]]:
        # Fast path: one extent, fragmented into pages (the paper's
        # fragment(): one search for n buffers).
        try:
            ext = self.arena.alloc(n_pages)
            self.fragment_allocs += 1
            return [ext], list(range(ext.offset, ext.offset + n_pages))
        except AllocError:
            pass
        # Fragmented pool: fall back to page-at-a-time.
        extents: List[Extent] = []
        try:
            for _ in range(n_pages):
                extents.append(self.arena.alloc(1))
        except AllocError:
            for e in extents:
                self.arena.free(e)
            raise AllocError(
                f"KV pool exhausted: need {n_pages} pages, "
                f"{self.free_pages} free"
            )
        self.fallback_allocs += 1
        return extents, [e.offset for e in extents]

    def free_sequence(self, seq_id: int) -> None:
        info = self._seqs.pop(seq_id)
        for ext in info.extents:
            self.arena.free(ext)

    # -- introspection --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return self.arena.free_bytes  # capacity is in page units

    def n_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def page_table(self, seq_id: int, pad_to: Optional[int] = None) -> np.ndarray:
        ids = list(self._seqs[seq_id].page_ids)
        if pad_to is not None:
            ids = ids + [0] * (pad_to - len(ids))
        return np.asarray(ids, dtype=np.int32)


# ---------------------------------------------------------------------------
# Functional device-side helpers (pure jnp; used by serve engine + kernel ref)
# ---------------------------------------------------------------------------


def init_pool_arrays(num_pages, page_size, kv_heads, head_dim, dtype):
    """(k_pool, v_pool) with shape (num_pages, page_size, kv_heads, head_dim)."""
    import jax.numpy as jnp

    shape = (num_pages, page_size, kv_heads, head_dim)
    return jnp.zeros(shape, dtype=dtype), jnp.zeros(shape, dtype=dtype)


def write_token(pool, block_table, pos, new):
    """Scatter one token per sequence into the pool.

    pool:        (num_pages, page_size, kv_heads, head_dim)
    block_table: (batch, max_pages) int32 — page id per logical page
    pos:         (batch,) int32 — token position being written
    new:         (batch, kv_heads, head_dim)
    """
    import jax.numpy as jnp

    page_size = pool.shape[1]
    logical_page = pos // page_size
    slot = pos % page_size
    batch_idx = jnp.arange(block_table.shape[0])
    page_id = block_table[batch_idx, logical_page]
    return pool.at[page_id, slot].set(new.astype(pool.dtype))


def gather_kv(pool, block_table, max_len):
    """Gather a dense (batch, max_len, kv_heads, head_dim) view of the pool
    (reference path / tests; the Pallas kernel reads pages in place)."""
    page_size = pool.shape[1]
    n_pages = max_len // page_size
    pages = pool[block_table[:, :n_pages]]  # (B, n_pages, page, H, D)
    b = pages.shape[0]
    return pages.reshape(b, n_pages * page_size, *pool.shape[2:])
