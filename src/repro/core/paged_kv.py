"""Paged KV-cache pool — the production arena instance of RIMMS on TPU.

This is the load-bearing mapping of the paper's allocator + ``fragment``
machinery onto an LM serving system (DESIGN.md §2, row "hete_Malloc
arena"):

* The device holds one dense KV *page pool* per layer (analogous to the
  ZCU102's physically-contiguous 64 MiB UDMA buffer: jittable code needs
  static shapes, so all KV lives in one preallocated region).
* A host-side **marking system** (bitset or next-fit from
  :mod:`repro.core.allocator`, block = one page) hands out page extents.
* A sequence's KV buffer is *one* extent search fragmented into pages
  (§3.2.3): one ``alloc`` + O(n) fragment instead of n allocs.  When the
  pool is too fragmented for a contiguous run, we degrade to per-page
  allocation (next-fit's rolling cursor makes that amortized O(1)).
* Block tables (page id per logical page of each sequence) are the
  "resource pointers"; they are device inputs to the paged-attention
  kernel.

The pool *arrays* are functional jax values threaded through the serving
step; this class owns only host metadata — exactly the paper's split
(marking metadata on host, payload in resource memory).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .allocator import AllocError, Extent, make_allocator
from .qos import QuotaExceeded

__all__ = [
    "PagedKVPool",
    "SCRATCH_SEQ",
    "init_pool_arrays",
    "write_token",
    "gather_kv",
]

#: reserved sequence id for the sacrificial scratch page: inactive batch
#: slots and block-table padding point at it so full-batch scatter/gather
#: kernels never touch live pages.
SCRATCH_SEQ = -1


@dataclasses.dataclass
class _SeqInfo:
    extents: List[Extent]
    page_ids: List[int]
    n_tokens: int = 0
    tenant: Optional[str] = None


class PagedKVPool:
    """Host-side page bookkeeping for a device KV pool.

    With ``scratch=True`` the pool reserves one sacrificial page at
    construction under :data:`SCRATCH_SEQ`; it is pinned for the pool's
    lifetime (``free_sequence(SCRATCH_SEQ)`` raises) and is charged to no
    tenant.  Per-tenant page quotas (``set_quota``) turn over-budget
    allocations into :class:`~repro.core.qos.QuotaExceeded` instead of
    silently eating the shared pool.
    """

    def __init__(
        self,
        *,
        num_pages: int,
        page_size: int,
        allocator: str = "bitset",
        scratch: bool = False,
    ) -> None:
        self.num_pages = num_pages
        self.page_size = page_size
        # Arena in units of pages: block_size=1 page.
        self.arena = make_allocator(allocator, capacity=num_pages, block_size=1)
        self._seqs: Dict[int, _SeqInfo] = {}
        self._quotas: Dict[str, int] = {}
        self._tenant_pages: Dict[str, int] = {}
        self.fragment_allocs = 0  # single-search contiguous grabs
        self.fallback_allocs = 0  # per-page fallbacks under fragmentation
        self.scratch_page: Optional[int] = None
        if scratch:
            table = self.alloc_sequence(SCRATCH_SEQ, 1)
            self.scratch_page = int(table[0])

    # -- tenant quotas ------------------------------------------------------
    def set_quota(self, tenant: str, max_pages: Optional[int]) -> None:
        """Cap ``tenant`` at ``max_pages`` live pages (None clears)."""
        if max_pages is None:
            self._quotas.pop(tenant, None)
        else:
            self._quotas[tenant] = int(max_pages)

    def tenant_pages(self, tenant: str) -> int:
        """Pages currently held by ``tenant`` (scratch never counts)."""
        return self._tenant_pages.get(tenant, 0)

    def _charge(self, tenant: Optional[str], n_pages: int) -> None:
        if tenant is None:
            return
        quota = self._quotas.get(tenant)
        held = self._tenant_pages.get(tenant, 0)
        if quota is not None and held + n_pages > quota:
            raise QuotaExceeded(
                f"tenant {tenant!r} KV quota exceeded: holds {held} pages, "
                f"wants {n_pages} more, quota {quota}",
                tenant=tenant, location="kv_pool",
            )
        self._tenant_pages[tenant] = held + n_pages

    # -- allocation ---------------------------------------------------------
    def alloc_sequence(
        self, seq_id: int, n_tokens: int, *, tenant: Optional[str] = None
    ) -> np.ndarray:
        """Reserve pages for ``n_tokens`` tokens; returns int32 page ids."""
        if seq_id in self._seqs:
            raise KeyError(f"sequence {seq_id} already allocated")
        n_pages = max(1, -(-n_tokens // self.page_size))
        self._charge(tenant, n_pages)  # quota check before touching arena
        try:
            extents, page_ids = self._grab(n_pages)
        except AllocError:
            if tenant is not None:
                self._tenant_pages[tenant] -= n_pages
            raise
        self._seqs[seq_id] = _SeqInfo(extents, page_ids, n_tokens, tenant)
        return np.asarray(page_ids, dtype=np.int32)

    def extend_sequence(self, seq_id: int, n_new_tokens: int) -> np.ndarray:
        """Grow a sequence (decode appends); returns the full page table."""
        info = self._seqs[seq_id]
        need = -(-(info.n_tokens + n_new_tokens) // self.page_size)
        if need > len(info.page_ids):
            grow = need - len(info.page_ids)
            self._charge(info.tenant, grow)
            try:
                extents, page_ids = self._grab(grow)
            except AllocError:
                if info.tenant is not None:
                    self._tenant_pages[info.tenant] -= grow
                raise
            info.extents.extend(extents)
            info.page_ids.extend(page_ids)
        info.n_tokens += n_new_tokens
        return np.asarray(info.page_ids, dtype=np.int32)

    def _grab(self, n_pages: int) -> Tuple[List[Extent], List[int]]:
        # Fast path: one extent, fragmented into pages (the paper's
        # fragment(): one search for n buffers).
        try:
            ext = self.arena.alloc(n_pages)
            self.fragment_allocs += 1
            return [ext], list(range(ext.offset, ext.offset + n_pages))
        except AllocError:
            pass
        # Fragmented pool: fall back to page-at-a-time.
        extents: List[Extent] = []
        try:
            for _ in range(n_pages):
                extents.append(self.arena.alloc(1))
        except AllocError:
            for e in extents:
                self.arena.free(e)
            raise AllocError(
                f"KV pool exhausted: need {n_pages} pages, "
                f"{self.free_pages} free"
            )
        self.fallback_allocs += 1
        return extents, [e.offset for e in extents]

    def free_sequence(self, seq_id: int) -> None:
        if seq_id == SCRATCH_SEQ and self.scratch_page is not None:
            raise ValueError(
                "scratch page is pool-owned and pinned; it cannot be freed"
            )
        info = self._seqs.pop(seq_id, None)
        if info is None:
            raise KeyError(
                f"sequence {seq_id} is not allocated (double free?)"
            )
        if info.tenant is not None:
            self._tenant_pages[info.tenant] -= len(info.page_ids)
        for ext in info.extents:
            self.arena.free(ext)

    # -- introspection --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return self.arena.free_bytes  # capacity is in page units

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.free_pages

    def n_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def page_table(self, seq_id: int, pad_to: Optional[int] = None) -> np.ndarray:
        ids = list(self._seqs[seq_id].page_ids)
        if pad_to is not None:
            ids = ids + [0] * (pad_to - len(ids))
        return np.asarray(ids, dtype=np.int32)


# ---------------------------------------------------------------------------
# Functional device-side helpers (pure jnp; used by serve engine + kernel ref)
# ---------------------------------------------------------------------------


def init_pool_arrays(num_pages, page_size, kv_heads, head_dim, dtype):
    """(k_pool, v_pool) with shape (num_pages, page_size, kv_heads, head_dim)."""
    import jax.numpy as jnp

    shape = (num_pages, page_size, kv_heads, head_dim)
    return jnp.zeros(shape, dtype=dtype), jnp.zeros(shape, dtype=dtype)


def write_token(pool, block_table, pos, new):
    """Scatter one token per sequence into the pool.

    pool:        (num_pages, page_size, kv_heads, head_dim)
    block_table: (batch, max_pages) int32 — page id per logical page
    pos:         (batch,) int32 — token position being written
    new:         (batch, kv_heads, head_dim)
    """
    import jax.numpy as jnp

    page_size = pool.shape[1]
    logical_page = pos // page_size
    slot = pos % page_size
    batch_idx = jnp.arange(block_table.shape[0])
    page_id = block_table[batch_idx, logical_page]
    return pool.at[page_id, slot].set(new.astype(pool.dtype))


def gather_kv(pool, block_table, max_len):
    """Gather a dense (batch, max_len, kv_heads, head_dim) view of the pool
    (reference path / tests; the Pallas kernel reads pages in place)."""
    page_size = pool.shape[1]
    n_pages = max_len // page_size
    pages = pool[block_table[:, :n_pages]]  # (B, n_pages, page, H, D)
    b = pages.shape[0]
    return pages.reshape(b, n_pages * page_size, *pool.shape[2:])
