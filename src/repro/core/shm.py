"""Shared-memory host arenas — pickling-free buffer handles (ISSUE 7).

The process PE backend executes registered kernels in subprocess workers.
Shipping numpy payloads through a pipe costs one serialize + one copy per
array per task; RIMMS's whole point is that the runtime *knows* where
bytes live, so it can do better.  :class:`SharedHostArena` carves host
buffers out of one ``multiprocessing.shared_memory`` segment managed by
the same extent allocators that already run the modeled device arenas
(:mod:`repro.core.allocator`).  Any array whose bytes live inside a
registered arena travels to a worker as a 4-tuple *handle* —
``(segment name, byte offset, shape, dtype)`` — and the worker maps the
same physical pages: zero-copy host↔worker, exactly the "resource
pointer" discipline of ``hete_Data`` extended across process boundaries.

Lifecycle is garbage-collection driven: every array handed out holds the
segment's buffer alive, and a ``weakref.finalize`` on the array returns
its extent to the allocator when the last reference drops.  Callers
therefore never pair mallocs with frees, and an arena that fills up
degrades gracefully — :meth:`SharedHostArena.zeros` / :meth:`copy_in`
return ``None`` and the caller falls back to ordinary heap numpy (whose
handles are sent inline instead).

Nothing here imports jax: worker subprocesses importing this module stay
numpy-only, which keeps spawn latency at "import numpy", not "import
XLA".
"""

from __future__ import annotations

import os
import threading
import weakref
from multiprocessing import shared_memory
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .allocator import AllocError, make_allocator

__all__ = [
    "SharedHostArena",
    "attach_segment",
    "describe_array",
    "resolve_handle",
]

# Alignment for every extent we hand out.  64 bytes covers any numpy
# dtype and keeps views cache-line aligned for the workers.
_ALIGN = 64

# Registry of live arenas in THIS process, keyed by segment name — the
# lookup :func:`describe_array` scans to turn an array into a handle.
_ARENAS: Dict[str, "SharedHostArena"] = {}
_ARENAS_LOCK = threading.Lock()


class SharedHostArena:
    """One shared-memory segment + extent allocator for host buffers.

    ``alloc`` hands out 64-byte-aligned extents via the block-aligned
    :class:`~repro.core.allocator.BitsetAllocator` (block size =
    alignment, so offsets are aligned by construction); arrays are numpy
    views over the segment with a GC finalizer returning the extent.
    """

    def __init__(self, capacity: int, *, name: Optional[str] = None) -> None:
        capacity = max(int(capacity), _ALIGN)
        self.shm = shared_memory.SharedMemory(
            create=True, size=capacity, name=name)
        self.name = self.shm.name
        self.capacity = capacity
        self.arena = make_allocator("bitset", capacity, _ALIGN)
        self._lock = threading.Lock()
        self._closed = False
        # Base address of the mapping in this process — describe_array
        # turns array data pointers into segment offsets against it.
        self.base = np.frombuffer(self.shm.buf, dtype=np.uint8)
        self._base_addr = self.base.__array_interface__["data"][0]
        with _ARENAS_LOCK:
            _ARENAS[self.name] = self
        # Last-resort cleanup if the owner never calls destroy().
        self._finalizer = weakref.finalize(
            self, SharedHostArena._destroy_raw, self.shm, self.name)

    # -- allocation ---------------------------------------------------------
    def _free_extent(self, ext) -> None:
        with self._lock:
            if not self._closed:
                self.arena.free(ext)

    def empty(self, shape, dtype) -> Optional[np.ndarray]:
        """An uninitialised array inside the segment, or ``None`` when
        the arena can't fit it (caller falls back to heap numpy)."""
        shape = (int(shape),) if isinstance(shape, (int, np.integer)) \
            else tuple(int(s) for s in shape)
        dtype = np.dtype(dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        with self._lock:
            if self._closed:
                return None
            try:
                ext = self.arena.alloc(max(nbytes, 1))
            except AllocError:
                return None
        arr = np.ndarray(shape, dtype=dtype, buffer=self.shm.buf,
                         offset=ext.offset)
        weakref.finalize(arr, self._free_extent, ext)
        return arr

    def zeros(self, shape, dtype) -> Optional[np.ndarray]:
        arr = self.empty(shape, dtype)
        if arr is not None:
            arr.fill(0)
        return arr

    def copy_in(self, value: np.ndarray) -> Optional[np.ndarray]:
        """A fresh arena-backed copy of ``value`` (or ``None`` if full)."""
        value = np.asarray(value)
        arr = self.empty(value.shape, value.dtype)
        if arr is not None:
            np.copyto(arr, value)
        return arr

    # -- handle mapping -----------------------------------------------------
    def describe(self, arr: np.ndarray) -> Optional[Tuple[str, int, tuple, str]]:
        """Handle for ``arr`` if its bytes live in this segment."""
        if not (isinstance(arr, np.ndarray) and arr.flags["C_CONTIGUOUS"]):
            return None
        addr = arr.__array_interface__["data"][0]
        off = addr - self._base_addr
        if 0 <= off and off + arr.nbytes <= self.capacity:
            return (self.name, off, arr.shape, arr.dtype.str)
        return None

    # -- stats / lifecycle --------------------------------------------------
    def used_bytes(self) -> int:
        with self._lock:
            return int(self.arena.used_bytes)

    @staticmethod
    def _destroy_raw(shm: shared_memory.SharedMemory, name: str) -> None:
        with _ARENAS_LOCK:
            _ARENAS.pop(name, None)
        try:
            shm.close()
        except Exception:  # pragma: no cover - interpreter teardown
            pass
        try:
            shm.unlink()
        except Exception:
            pass

    def destroy(self) -> None:
        """Close + unlink the segment (idempotent).  Outstanding views
        keep their pages mapped until they are collected; new allocations
        are refused."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self.base = None
        self._finalizer.detach()
        self._destroy_raw(self.shm, self.name)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SharedHostArena({self.name!r}, {self.used_bytes()}/"
                f"{self.capacity} bytes)")


# ---------------------------------------------------------------------------
# Module-level handle plumbing (used by both parent and workers)
# ---------------------------------------------------------------------------


def describe_array(arr: Any) -> Optional[Tuple[str, int, tuple, str]]:
    """Zero-copy handle for ``arr`` if it lives in any registered arena
    of this process, else ``None`` (send it inline)."""
    if not isinstance(arr, np.ndarray):
        return None
    with _ARENAS_LOCK:
        arenas = list(_ARENAS.values())
    for arena in arenas:
        h = arena.describe(arr)
        if h is not None:
            return h
    return None


# Worker-side cache of attached segments: name -> SharedMemory.  The
# parent's own segments resolve through _ARENAS without re-attaching.
_ATTACHED: Dict[str, shared_memory.SharedMemory] = {}
_ATTACHED_LOCK = threading.Lock()


def attach_segment(name: str) -> shared_memory.SharedMemory:
    """Attach (once) to the named segment created by another process.

    Attaching re-registers the name with the resource tracker, but
    spawned workers *share* the parent's tracker process, so that add is
    idempotent — the one ``unlink`` by whoever destroys the segment
    balances it.  (Per-process trackers would need ``track=False`` /
    manual unregistering here; shared-tracker semantics make that both
    unnecessary and wrong.)"""
    with _ATTACHED_LOCK:
        shm = _ATTACHED.get(name)
        if shm is None:
            shm = shared_memory.SharedMemory(name=name)
            _ATTACHED[name] = shm
        return shm


def resolve_handle(handle: Tuple[str, int, tuple, str],
                   *, writable: bool = False) -> np.ndarray:
    """Map a ``(name, offset, shape, dtype)`` handle to a numpy view of
    the shared pages (read-only unless ``writable``)."""
    name, off, shape, dtype = handle
    with _ARENAS_LOCK:
        own = _ARENAS.get(name)
    buf = own.shm.buf if own is not None else attach_segment(name).buf
    arr = np.ndarray(tuple(shape), dtype=np.dtype(dtype), buffer=buf,
                     offset=int(off))
    if not writable:
        arr.flags.writeable = False
    return arr


def detach_all() -> None:
    """Drop every worker-side attachment (called at worker exit)."""
    with _ATTACHED_LOCK:
        for shm in _ATTACHED.values():
            try:
                shm.close()
            except Exception:  # pragma: no cover
                pass
        _ATTACHED.clear()


def default_arena_bytes() -> int:
    """Default host-arena capacity: a quarter of /dev/shm (if knowable)
    clamped to [64 MiB, 1 GiB]."""
    try:
        st = os.statvfs("/dev/shm")
        quarter = st.f_frsize * st.f_blocks // 4
    except OSError:  # pragma: no cover - non-Linux
        quarter = 256 << 20
    return int(min(max(quarter, 64 << 20), 1 << 30))
