"""Continuous telemetry (ISSUE 8): divergence, samplers, metrics export.

Four pieces, all stdlib-only (this module imports nothing from ``core``
except :mod:`repro.core.trace`, so every layer may import it):

``DivergenceMonitor``
    Pairs every compute/stage span's *wall* duration with its *modeled*
    duration into per-(span kind, op, PE kind, shape bucket) wall/modeled
    ratio cells — an EMA for "what is the current correction factor" and
    a log-bucketed histogram for "how stable is it".  The table is the
    calibration substrate ROADMAP item 4 consumes: a ratio of 1.0 means
    the cost model's prior matches this machine; persist it with
    :meth:`DivergenceMonitor.save_json` and fold it back with
    :meth:`DivergenceMonitor.load_json`.  Each :class:`Runtime` owns one
    monitor; :func:`aggregate_divergence` merges every monitor created
    since a serial mark (how ``benchmarks/run.py --metrics-dir`` scopes
    tables per bench).

``Sampler``
    A bounded-overhead background sampler over one :class:`Session`:
    per-PE occupancy and queue depth, arena used/free/pinned bytes and
    pressure counters, per-link modeled busy fraction, and per-tenant
    window occupancy + DRR deficit — written as gauges into the
    session's :class:`~repro.core.trace.MetricsRegistry` and kept as a
    bounded ring of samples.  Off by default; ``period=0`` is the
    deterministic manual-tick mode tests drive.

``metrics_text`` / ``serve_metrics``
    Prometheus text-exposition rendering of a registry
    (``Session.metrics_text()``), plus an optional localhost HTTP
    endpoint serving ``/metrics``.

``slo_eval``
    Per-tenant SLO burn-rate evaluation: declare a latency objective on
    ``session.client(slo_latency_s=...)`` and ``qos_report()`` grows an
    ``slo`` section (violation rate, burn rate = budget consumption
    multiple, breached flag) with alert instants in the trace.
"""

from __future__ import annotations

import http.server
import json
import math
import re
import threading
import time
import warnings
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from .trace import Counter, Gauge, Histogram, MetricsRegistry

__all__ = [
    "DivergenceMonitor",
    "Sampler",
    "metrics_text",
    "serve_metrics",
    "MetricsServer",
    "slo_eval",
    "shape_bucket",
    "divergence_serial",
    "aggregate_divergence",
]


# ---------------------------------------------------------------------------
# Measured-vs-modeled divergence
# ---------------------------------------------------------------------------


def _fmt_bytes(n: int) -> str:
    if n <= 0:
        return "0B"
    for unit, shift in (("GiB", 30), ("MiB", 20), ("KiB", 10)):
        if n >= (1 << shift):
            v = n / (1 << shift)
            return f"{v:g}{unit}"
    return f"{n}B"


def shape_bucket(nbytes: int) -> str:
    """Power-of-two shape bucket label for ``nbytes`` of input
    (``"<=64KiB"`` …) — coarse enough that repeated runs of one workload
    land in the same cell, fine enough that a 1 KiB and a 64 MiB FFT
    never share a correction factor."""
    n = int(nbytes)
    if n <= 0:
        return "0B"
    return "<=" + _fmt_bytes(1 << (n - 1).bit_length())


# Monitors self-register here so aggregate_divergence() can merge every
# monitor created after a serial mark (per-bench scoping).  References
# are strong but bounded: a monitor holds only its ratio cells (no
# back-reference to its runtime), and the registry keeps at most
# _DIV_KEEP recent monitors — benches aggregate right after their run,
# long processes (test suites) shed the old ones.
_DIV_KEEP = 512
_div_lock = threading.Lock()
_div_serial = 0
_div_monitors: Dict[int, "DivergenceMonitor"] = {}


def divergence_serial() -> int:
    """High-water serial of created monitors — capture before a run,
    pass to :func:`aggregate_divergence` after to scope the merge."""
    with _div_lock:
        return _div_serial


def aggregate_divergence(since: int = 0) -> "DivergenceMonitor":
    """A fresh monitor holding the merged cells of every registered
    monitor with serial > ``since`` (0 = all retained monitors this
    process created)."""
    with _div_lock:
        monitors = [m for s, m in _div_monitors.items() if s > since]
    agg = DivergenceMonitor(register=False)
    for m in monitors:
        agg.merge(m.state())
    return agg


class DivergenceMonitor:
    """Wall/modeled ratio tables per (span kind, op, PE kind, shape
    bucket).

    ``observe`` is called from the runtime's compute and stage paths
    with both durations; pairs where either side is non-positive cannot
    form a ratio and are tallied as ``skipped`` instead of poisoning the
    EMA.  Thread-safe; O(1) per observation.
    """

    EMA = 0.2

    def __init__(self, *, register: bool = True) -> None:
        self._lock = threading.Lock()
        # key -> [count, skipped, wall_s, model_s, ema, Histogram]
        self._cells: Dict[Tuple[str, str, str, str], list] = {}
        if register:
            global _div_serial
            with _div_lock:
                _div_serial += 1
                self.serial = _div_serial
                _div_monitors[self.serial] = self
                while len(_div_monitors) > _DIV_KEEP:
                    _div_monitors.pop(next(iter(_div_monitors)))
        else:
            self.serial = 0

    def observe(self, kind: str, op: str, pe_kind: str, nbytes: int,
                wall_s: float, model_s: float) -> None:
        key = (kind, op, pe_kind, shape_bucket(nbytes))
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = [0, 0, 0.0, 0.0, None, Histogram("ratio")]
                self._cells[key] = cell
            if wall_s <= 0.0 or model_s <= 0.0:
                cell[1] += 1
                return
            ratio = wall_s / model_s
            cell[0] += 1
            cell[2] += wall_s
            cell[3] += model_s
            cell[4] = (ratio if cell[4] is None
                       else (1 - self.EMA) * cell[4] + self.EMA * ratio)
            cell[5].record(ratio)

    @staticmethod
    def key_str(key: Tuple[str, str, str, str]) -> str:
        return "/".join(key)

    def table(self) -> Dict[str, dict]:
        """The ratio table: ``"kind/op/pe_kind/bucket"`` → stats.  Every
        row with ``count > 0`` has a finite positive ``ema_ratio``."""
        with self._lock:
            items = sorted(self._cells.items())
        out: Dict[str, dict] = {}
        for key, (count, skipped, wall_s, model_s, ema, hist) in items:
            out[self.key_str(key)] = {
                "kind": key[0], "op": key[1], "pe_kind": key[2],
                "bucket": key[3],
                "count": count, "skipped": skipped,
                "wall_s": wall_s, "model_s": model_s,
                "ema_ratio": ema,
                "mean_ratio": hist.mean if count else None,
                "p50_ratio": hist.percentile(50),
                "p95_ratio": hist.percentile(95),
            }
        return out

    # -- persistence / merge ------------------------------------------------

    def state(self) -> dict:
        """JSON-safe full state (bucket-exact; mergeable)."""
        with self._lock:
            items = sorted(self._cells.items())
        return {
            "cells": {
                self.key_str(k): {
                    "count": c[0], "skipped": c[1],
                    "wall_s": c[2], "model_s": c[3], "ema": c[4],
                    "hist": c[5].to_state(),
                }
                for k, c in items
            }
        }

    def merge(self, state: dict) -> "DivergenceMonitor":
        """Fold a ``state()`` dict into this monitor.  Counts, sums and
        histograms merge exactly; the EMA takes a count-weighted blend
        (order across monitors is not recoverable, nor meaningful)."""
        for key_s, c in (state.get("cells") or {}).items():
            parts = tuple(key_s.split("/"))
            if len(parts) != 4:
                continue
            with self._lock:
                cell = self._cells.get(parts)
                if cell is None:
                    cell = [0, 0, 0.0, 0.0, None, Histogram("ratio")]
                    self._cells[parts] = cell
                n_old, n_new = cell[0], int(c.get("count", 0))
                cell[0] = n_old + n_new
                cell[1] += int(c.get("skipped", 0))
                cell[2] += float(c.get("wall_s", 0.0))
                cell[3] += float(c.get("model_s", 0.0))
                ema_new = c.get("ema")
                if ema_new is not None:
                    if cell[4] is None or n_old + n_new == 0:
                        cell[4] = ema_new
                    else:
                        cell[4] = ((n_old * cell[4] + n_new * ema_new)
                                   / (n_old + n_new))
                cell[5].merge(c.get("hist", {}))
        return self

    def save_json(self, path: str) -> None:
        """.. deprecated:: ISSUE 10
           Raw divergence-JSON plumbing is superseded by the calibration
           table (``Session.save_calibration(path)`` embeds the same
           divergence snapshot in a "rimms-calib-v1" file).  One
           :class:`DeprecationWarning` per process."""
        _warn_divergence_json("save_json",
                              "Session.save_calibration(path)")
        with open(path, "w") as fh:
            json.dump({"format": "rimms-divergence-v1",
                       "state": self.state(), "table": self.table()},
                      fh, indent=1, sort_keys=True)

    @classmethod
    def load_json(cls, path: str) -> "DivergenceMonitor":
        """.. deprecated:: ISSUE 10
           Load through ``Session(calibration=path)`` instead — a
           calibration table's embedded divergence snapshot merges into
           the runtime's live monitor at construction."""
        _warn_divergence_json("load_json", "Session(calibration=path)")
        with open(path) as fh:
            doc = json.load(fh)
        mon = cls(register=False)
        mon.merge(doc.get("state", doc))
        return mon


# One DeprecationWarning per process (same pattern as the ISSUE-7
# Runtime.run/run_graph deprecation): the first raw divergence-JSON call
# warns, later ones stay quiet.
_divergence_json_warned = False


def _warn_divergence_json(which: str, instead: str) -> None:
    global _divergence_json_warned
    if _divergence_json_warned:
        return
    _divergence_json_warned = True
    warnings.warn(
        f"DivergenceMonitor.{which}() raw divergence-JSON plumbing is "
        f"deprecated; use the calibration-table entry point instead "
        f"({instead} — 'rimms-calib-v1' files embed the divergence "
        f"snapshot).",
        DeprecationWarning,
        stacklevel=3,
    )


# ---------------------------------------------------------------------------
# Background sampler
# ---------------------------------------------------------------------------


class Sampler:
    """Gauge time-series sampler over one :class:`Session`.

    ``period > 0`` runs a daemon thread waking every ``period`` seconds;
    ``period == 0`` (default) takes samples only on explicit
    :meth:`tick` calls — the deterministic mode tests use.  Each tick
    writes current gauges into ``session.metrics`` and appends one
    sample dict to the bounded :attr:`samples` ring.  The work per tick
    is O(PEs + arenas + links + tenants) dictionary reads — no kernel
    path is touched, so overhead is bounded by the period, not the task
    rate (gated in ``bench_overhead.py``).
    """

    def __init__(self, session, *, period: float = 0.0,
                 max_samples: int = 4096) -> None:
        if period < 0:
            raise ValueError(f"sampler period must be >= 0, got {period}")
        if max_samples <= 0:
            raise ValueError("sampler max_samples must be > 0")
        self.session = session
        self.period = float(period)
        self.samples: deque = deque(maxlen=int(max_samples))
        self.ticks = 0
        self._t0 = time.perf_counter()
        self._stop = threading.Event()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        self._last_link: Optional[Tuple[float, Dict[str, float]]] = None
        self._lock = threading.Lock()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "Sampler":
        """Start the background thread (no-op in manual-tick mode or if
        already running/stopped)."""
        if self._stopped or self.period <= 0 or self.running:
            return self
        self._thread = threading.Thread(
            target=self._loop, name="rimms-sampler", daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.period):
            try:
                self.tick()
            except Exception:  # pragma: no cover - sampling must not kill
                pass

    def stop(self) -> None:
        """Stop permanently: the thread exits and further ticks (manual
        included) become no-ops — a closed session takes no samples."""
        self._stopped = True
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
            self._thread = None

    def tick(self) -> Optional[dict]:
        """Take one sample now; returns the sample dict (None after
        :meth:`stop`)."""
        if self._stopped:
            return None
        with self._lock:
            return self._tick_locked()

    def _tick_locked(self) -> dict:
        session = self.session
        metrics = session.metrics
        now = time.perf_counter()
        gauges: Dict[str, float] = {}

        def put(name: str, value: float) -> None:
            gauges[name] = float(value)
            metrics.gauge(name).set(value)

        # per-PE queue depth + busy flag (worker pool, when running)
        pool = getattr(session.runtime, "_worker_pool", None)
        if pool is not None and not pool.closed:
            for pe_name in pool.pe_names:
                put(f"pe_queue_depth/{pe_name}",
                    pool.queues[pe_name].qsize())
                put(f"pe_busy/{pe_name}",
                    1.0 if pool.active.get(pe_name) else 0.0)

        # arena used/free/pinned bytes per device space
        ctx = session.context
        with ctx._arena_lock:
            spaces = [(loc, sp) for loc, sp in ctx.spaces.items()
                      if sp.arena is not None]
            for loc, sp in spaces:
                label = str(loc)
                free = sp.arena.free_bytes
                put(f"arena_free_bytes/{label}", free)
                put(f"arena_used_bytes/{label}", sp.arena.capacity - free)
                put(f"arena_pinned_bytes/{label}",
                    sum(hd.nbytes for hd in sp.residents.values()
                        if hd.pin_count(loc) > 0))

        # pressure counters (cumulative, exported as gauges so the ring
        # holds a time series CI and dashboards can difference)
        led = ctx.ledger
        put("pressure_evictions", led.total_evictions)
        put("pressure_spill_stalls", led.n_spill_stalls)
        put("pressure_prefetch_deferrals", led.prefetch_deferrals)

        # per-link modeled busy seconds + busy fraction since last tick
        per_link = led.per_link_summary()
        link_s = {link: row["modeled_s"] for link, row in per_link.items()}
        prev = self._last_link
        for link, total_s in sorted(link_s.items()):
            put(f"link_modeled_busy_s/{link}", total_s)
            frac = 0.0
            if prev is not None:
                dt = now - prev[0]
                if dt > 0:
                    frac = max(0.0, total_s - prev[1].get(link, 0.0)) / dt
            put(f"link_busy_fraction/{link}", frac)
        self._last_link = (now, link_s)

        # per-tenant window occupancy + DRR deficit
        snap = session.qos.snapshot()
        for name, c in sorted(snap.get("clients", {}).items()):
            window = max(1, c.get("window", 1))
            put(f"tenant_window_occupancy/{name}",
                c.get("inflight", 0) / window)
            put(f"tenant_drr_deficit/{name}", c.get("deficit", 0.0))

        self.ticks += 1
        sample = {"seq": self.ticks, "t": now - self._t0, "gauges": gauges}
        self.samples.append(sample)
        return sample


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_ESC = str.maketrans({"\\": r"\\", '"': r"\"", "\n": r"\n"})


def _metric_name(base: str, prefix: str) -> str:
    name = _NAME_RE.sub("_", base)
    if prefix:
        name = f"{prefix}_{name}"
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _labels(key: Optional[str], extra: str = "") -> str:
    parts = []
    if key:
        parts.append(f'key="{key.translate(_LABEL_ESC)}"')
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def metrics_text(registry: MetricsRegistry, *, prefix: str = "rimms") -> str:
    """Render ``registry`` in the Prometheus text exposition format
    (version 0.0.4).  A metric named ``"base/key"`` becomes family
    ``{prefix}_{base}`` with label ``key="key"``; counters gain the
    conventional ``_total`` suffix; histograms export as summaries
    (``quantile`` labels + ``_sum``/``_count``).  Deterministic output
    order (sorted families, then labels)."""
    with registry._lock:
        items = sorted(registry._instruments.items())
    families: Dict[Tuple[str, str], List[Tuple[str, Any]]] = {}
    for name, inst in items:
        base, _, key = name.partition("/")
        if isinstance(inst, Counter):
            ftype = "counter"
        elif isinstance(inst, Gauge):
            ftype = "gauge"
        elif isinstance(inst, Histogram):
            ftype = "summary"
        else:  # pragma: no cover - unknown instrument kinds are skipped
            continue
        families.setdefault((base, ftype), []).append((key, inst))

    lines: List[str] = []
    for (base, ftype), members in sorted(families.items()):
        fam = _metric_name(base, prefix)
        if ftype == "counter":
            fam += "_total"
        lines.append(f"# TYPE {fam} {ftype}")
        for key, inst in members:
            if ftype == "counter":
                lines.append(f"{fam}{_labels(key)} {inst.value}")
            elif ftype == "gauge":
                lines.append(f"{fam}{_labels(key)} {_fmt_val(inst.value)}")
            else:
                snap = inst.snapshot()
                for q, field in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
                    v = snap[field]
                    if v is None:
                        continue
                    qlabel = 'quantile="%s"' % q
                    lines.append(
                        f"{fam}{_labels(key, qlabel)} {_fmt_val(v)}")
                lines.append(f"{fam}_sum{_labels(key)} {_fmt_val(snap['sum'])}")
                lines.append(f"{fam}_count{_labels(key)} {snap['count']}")
    return "\n".join(lines) + "\n"


def _fmt_val(v: float) -> str:
    if v != v:  # pragma: no cover - NaN guard
        return "NaN"
    if v in (math.inf, -math.inf):  # pragma: no cover
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


class MetricsServer:
    """Localhost HTTP endpoint serving ``GET /metrics`` (and ``/``) in
    Prometheus text format.  Runs on a daemon thread; :meth:`close`
    shuts it down.  Obtain via :func:`serve_metrics` or
    ``Session.serve_metrics()``."""

    def __init__(self, render: Callable[[], str], host: str, port: int) -> None:
        server = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib API)
                if self.path.split("?", 1)[0] not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                try:
                    body = server._render().encode()
                except Exception as exc:  # pragma: no cover - render bug
                    self.send_error(500, str(exc))
                    return
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a: Any) -> None:  # silence stderr
                pass

        self._render = render
        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="rimms-metrics-http",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve_metrics(source: Union[MetricsRegistry, Callable[[], str]],
                  *, host: str = "127.0.0.1", port: int = 0) -> MetricsServer:
    """Serve ``source`` (a registry, or a callable returning exposition
    text) over HTTP on localhost.  ``port=0`` picks a free port — read
    it back from ``server.port`` / ``server.url``."""
    if isinstance(source, MetricsRegistry):
        reg = source
        render = lambda: metrics_text(reg)  # noqa: E731
    else:
        render = source
    return MetricsServer(render, host, port)


# ---------------------------------------------------------------------------
# SLO burn-rate evaluation
# ---------------------------------------------------------------------------


def slo_eval(latencies: List[float], objective_s: float,
             target: float) -> dict:
    """Evaluate a latency SLO over one tenant's task latencies.

    ``target`` is the success objective (e.g. 0.99 = 99 % of tasks under
    ``objective_s``); the error budget is ``1 - target`` and the *burn
    rate* is the multiple of that budget the observed violation rate
    consumes — burn 1.0 exactly exhausts the budget, > 1.0 breaches."""
    if objective_s <= 0:
        raise ValueError(f"slo objective_s must be > 0, got {objective_s}")
    if not 0.0 < target < 1.0:
        raise ValueError(f"slo target must be in (0, 1), got {target}")
    tasks = len(latencies)
    violations = sum(1 for v in latencies if v > objective_s)
    rate = violations / tasks if tasks else 0.0
    budget = 1.0 - target
    burn = rate / budget
    return {
        "objective_s": float(objective_s),
        "target": float(target),
        "tasks": tasks,
        "violations": violations,
        "violation_rate": rate,
        "burn_rate": burn,
        "breached": burn > 1.0,
    }
