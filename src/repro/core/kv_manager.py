"""Runtime-managed paged KV cache — the bridge between the serving
stack's page bookkeeping (:mod:`repro.core.paged_kv`) and RIMMS-owned
device memory (:class:`~repro.core.api.Session`).

The legacy :class:`~repro.serve.engine.ServeEngine` holds its KV pool as
two bare jax arrays, outside runtime management: no quotas, no pressure
handling, no telemetry.  :class:`KVManager` instead splits the pool into
fixed-size *page groups* and allocates each group's K and V planes as
Session buffers (``hete_Malloc`` under a dedicated owner).  Serving
kernels receive only the groups their block tables actually reference,
remapped into a compact pool, so:

* hot groups stay resident in the device arena (flag-hit staging);
* cold groups become LRU eviction victims under arena pressure — their
  dirty pages write back to host through the *existing* coherence path
  (``ledger.client_writeback_bytes[owner]`` is the spill evidence);
* a later step that references a spilled group re-stages it
  transparently in ``_stage_inputs`` — no serving-specific copy code.

Page bookkeeping (extents, tenant quotas, the sacrificial scratch page)
stays in the tenant-aware :class:`~repro.core.paged_kv.PagedKVPool`;
this class owns only the group geometry and the Session buffers.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .paged_kv import PagedKVPool

__all__ = ["KVManager"]


class KVManager:
    """Group-granular, Session-owned KV pool.

    ``num_pages`` global pages are split into ``num_pages /
    pages_per_group`` groups; group ``g`` holds pages ``[g * gp, (g + 1)
    * gp)``.  Each group is two Session buffers (K and V) of shape
    ``(n_layers, pages_per_group, page_size, kv_heads, head_dim)``.
    """

    def __init__(
        self,
        session,
        *,
        n_layers: int,
        kv_heads: int,
        head_dim: int,
        num_pages: int,
        page_size: int,
        pages_per_group: int = 8,
        dtype=np.float32,
        allocator: str = "bitset",
        owner: str = "kv-cache",
    ) -> None:
        if num_pages % pages_per_group != 0:
            raise ValueError(
                f"num_pages ({num_pages}) must be a multiple of "
                f"pages_per_group ({pages_per_group})"
            )
        self.session = session
        self.owner = owner
        self.page_size = page_size
        self.pages_per_group = pages_per_group
        self.n_groups = num_pages // pages_per_group
        self.pool = PagedKVPool(
            num_pages=num_pages, page_size=page_size,
            allocator=allocator, scratch=True,
        )
        shape = (n_layers, pages_per_group, page_size, kv_heads, head_dim)
        # hete_Malloc zeroes the host planes, matching init_pool_arrays.
        self.k_bufs: List = [
            session.malloc(shape, dtype, client=owner)
            for _ in range(self.n_groups)
        ]
        self.v_bufs: List = [
            session.malloc(shape, dtype, client=owner)
            for _ in range(self.n_groups)
        ]
        self._scratch_group = self.pool.scratch_page // pages_per_group

    # -- page bookkeeping (delegated to the tenant-aware pool) --------------
    @property
    def scratch_page(self) -> int:
        return self.pool.scratch_page

    def set_quota(self, tenant: str, max_pages: Optional[int]) -> None:
        self.pool.set_quota(tenant, max_pages)

    def alloc(self, seq_id: int, n_tokens: int, *,
              tenant: Optional[str] = None) -> np.ndarray:
        return self.pool.alloc_sequence(seq_id, n_tokens, tenant=tenant)

    def free(self, seq_id: int) -> None:
        self.pool.free_sequence(seq_id)

    @property
    def used_pages(self) -> int:
        return self.pool.used_pages

    # -- group referencing ---------------------------------------------------
    def referenced_groups(self, block_tables: np.ndarray) -> List[int]:
        """Sorted group ids any entry of ``block_tables`` touches.  The
        scratch group is always included: inactive slots and table
        padding point at the scratch page."""
        groups = set(np.unique(block_tables // self.pages_per_group).tolist())
        groups.add(self._scratch_group)
        return sorted(groups)

    def compact_tables(self, block_tables: np.ndarray,
                       groups: Sequence[int]) -> np.ndarray:
        """Remap global page ids to positions in the pool formed by
        concatenating ``groups`` in order (the kernel-side view)."""
        gp = self.pages_per_group
        lut = np.zeros((self.n_groups * gp,), np.int32)
        for i, g in enumerate(groups):
            lut[g * gp:(g + 1) * gp] = np.arange(i * gp, (i + 1) * gp)
        return lut[block_tables].astype(np.int32)

    def buffers(self, groups: Sequence[int]) -> List:
        """K then V Session buffers for ``groups``, the order kernels
        expect their pool inputs/outputs in."""
        return ([self.k_bufs[g] for g in groups]
                + [self.v_bufs[g] for g in groups])

    # -- telemetry -----------------------------------------------------------
    def spill_bytes(self) -> int:
        """Bytes of dirty KV written back to host by arena eviction (the
        runtime coherence path) — 0 while every group fits on device."""
        ledger = self.session.context.ledger
        return int(ledger.client_writeback_bytes.get(self.owner, 0))

    def publish_metrics(self) -> None:
        """Refresh the serving gauges in the session's MetricsRegistry
        (exported by ``metrics_text()``)."""
        m = self.session.metrics
        m.gauge("serve_kv_pages_resident").set(self.used_pages)
        m.gauge("serve_kv_spill_bytes").set(self.spill_bytes())
