"""Interconnect topology — routed transfers over a link graph (ISSUE 3).

RIMMS's premise is that the *runtime* decides how bytes move between
heterogeneous memories.  Up to now the cost side of that decision was a
flat 3-bucket :class:`~repro.core.locations.BandwidthModel`; real
platforms are *topologies*: PCIe trees with a shared root complex,
NVLink-style peer meshes, FPGAs reachable only through a host bridge.
This module models them:

* :class:`Link` — one directed edge: bandwidth, latency, and a per-link
  ``busy_until`` contention state in modeled time;
* :class:`Topology` — the interconnect graph over
  :class:`~repro.core.locations.Location` nodes, with Dijkstra
  cheapest-path routing (:meth:`Topology.route`, cached) yielding
  multi-hop store-and-forward transfer plans, and
  :meth:`Topology.transfer` which walks a plan through per-link
  contention (a shared bridge link serializes concurrent transfers);
* :func:`build_preset` — named platform shapes: ``emulated_soc`` (flat,
  equal to the scalar model's defaults), ``pcie_tree`` (devices behind a
  shared switch), ``nvlink_mesh`` (all-pairs fast peer links),
  ``host_bridged_fpga`` (no peer links at all — device↔device bytes
  route through the host);
* :class:`TopologyBandwidthModel` — drop-in for the scalar model: the
  same ``seconds(src, dst, nbytes)`` interface (so the ledger, eviction
  cost ranking and HEFT all price transfers by *route*), plus
  ``hops()`` so :meth:`repro.core.hete.HeteContext.stage` can record
  per-hop ledger traffic.

Routing between nodes the graph does not connect raises
:class:`TopologyError` — a mis-built platform should fail loudly, not
fall back to a made-up constant.  The scalar model remains the default
everywhere; a topology is opted into via
``make_emulated_soc(topology=...)``.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .locations import HOST, Location

__all__ = [
    "TopologyError",
    "Link",
    "Topology",
    "TopologyBandwidthModel",
    "build_preset",
    "PRESETS",
]

#: reference transfer size for route selection: Dijkstra weights are the
#: per-hop seconds of moving this many bytes, so routes are chosen for
#: bulk traffic, not for the latency-dominated empty-transfer corner.
ROUTE_REF_BYTES = 1 << 20


class TopologyError(Exception):
    """No route between two locations (or an unknown location) in a
    :class:`Topology` — the platform graph does not connect them."""


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed interconnect edge.

    ``bandwidth`` in bytes/second, ``latency_s`` seconds per transfer.
    ``name`` groups the two directions of a physical link for reporting
    (both directions of a PCIe lane pair share one name).
    """

    src: Location
    dst: Location
    bandwidth: float
    latency_s: float
    name: str

    def seconds(self, nbytes: int) -> float:
        """Uncontended service time for one transfer over this link."""
        return self.latency_s + nbytes / self.bandwidth

    @property
    def key(self) -> Tuple[str, str]:
        return (str(self.src), str(self.dst))

    @property
    def label(self) -> str:
        return f"{self.src}->{self.dst}"


class Topology:
    """Interconnect graph: Locations as nodes, :class:`Link` edges,
    cached cheapest-path routing, per-link contention state.

    Thread safety: route computation and contention state are guarded by
    one lock; the graph itself is append-only (``add_link`` invalidates
    the route cache).
    """

    def __init__(self, name: str = "custom") -> None:
        self.name = name
        self.nodes: set = set()
        self._adj: Dict[Location, List[Link]] = {}
        self._routes: Dict[Tuple[Location, Location], Tuple[Link, ...]] = {}
        self._busy: Dict[Tuple[str, str], float] = {}  # link key -> busy-until
        self._lock = threading.RLock()

    # -- construction -------------------------------------------------------
    def add_node(self, loc: Location) -> None:
        with self._lock:
            self.nodes.add(loc)
            self._adj.setdefault(loc, [])

    def add_link(
        self,
        a: Location,
        b: Location,
        *,
        bandwidth: float,
        latency_s: float = 5e-6,
        bidirectional: bool = True,
        name: Optional[str] = None,
    ) -> None:
        """Add a link ``a→b`` (and ``b→a`` unless ``bidirectional`` is
        False).  The two directions contend independently (full duplex),
        like the paper's platforms' DMA engines."""
        name = name or f"{a}<->{b}"
        with self._lock:
            self.add_node(a)
            self.add_node(b)
            self._adj[a].append(Link(a, b, bandwidth, latency_s, name))
            if bidirectional:
                self._adj[b].append(Link(b, a, bandwidth, latency_s, name))
            self._routes.clear()

    def links(self) -> List[Link]:
        with self._lock:
            return [l for adj in self._adj.values() for l in adj]

    # -- routing ------------------------------------------------------------
    def route(self, src: Location, dst: Location) -> Tuple[Link, ...]:
        """Cheapest path ``src→dst`` as a tuple of hops (empty when
        ``src == dst``).  Dijkstra over per-hop seconds at
        :data:`ROUTE_REF_BYTES`; deterministic tie-break on node names.
        Raises :class:`TopologyError` when no route exists."""
        if src == dst:
            return ()
        with self._lock:
            cached = self._routes.get((src, dst))
            if cached is not None:
                return cached
            if src not in self._adj or dst not in self.nodes:
                raise TopologyError(
                    f"no route {src} -> {dst}: "
                    f"{src if src not in self._adj else dst} is not a node of "
                    f"topology {self.name!r} (nodes: "
                    f"{sorted(str(n) for n in self.nodes)})"
                )
            # Dijkstra; entries (cost, node_name_for_ties, node, path)
            best: Dict[Location, float] = {src: 0.0}
            heap: List[tuple] = [(0.0, str(src), src, ())]
            while heap:
                cost, _, node, path = heapq.heappop(heap)
                if node == dst:
                    self._routes[(src, dst)] = path
                    return path
                if cost > best.get(node, float("inf")):
                    continue
                for link in self._adj.get(node, ()):
                    nxt = cost + link.seconds(ROUTE_REF_BYTES)
                    if nxt < best.get(link.dst, float("inf")):
                        best[link.dst] = nxt
                        heapq.heappush(
                            heap, (nxt, str(link.dst), link.dst, path + (link,))
                        )
            raise TopologyError(
                f"no route {src} -> {dst} in topology {self.name!r}: "
                f"the link graph does not connect them"
            )

    def seconds(self, src: Location, dst: Location, nbytes: int) -> float:
        """Uncontended store-and-forward seconds along the cheapest
        route (sum of per-hop seconds)."""
        return sum(l.seconds(nbytes) for l in self.route(src, dst))

    def plan(
        self, src: Location, dst: Location, nbytes: int
    ) -> List[Tuple[Link, float]]:
        """The routed transfer plan: ``[(hop, hop_seconds), ...]``."""
        return [(l, l.seconds(nbytes)) for l in self.route(src, dst)]

    # -- contention (modeled time) ------------------------------------------
    def reset_contention(self) -> None:
        with self._lock:
            self._busy.clear()

    def transfer(
        self,
        src: Location,
        dst: Location,
        nbytes: int,
        *,
        at: float = 0.0,
        commit: bool = True,
    ) -> Tuple[float, float, List[Tuple[Link, float, float]]]:
        """Walk the routed plan through per-link contention starting at
        modeled time ``at``.  Each hop begins when both the previous hop
        has delivered the bytes *and* the link is free (``busy_until``);
        with ``commit`` the link reservations stick, so a later transfer
        sharing a link queues behind this one — that is the serialization
        a shared host bridge imposes.  Returns ``(start, end, hops)``
        with ``hops = [(link, hop_start, hop_end), ...]``."""
        with self._lock:
            t = at
            first: Optional[float] = None
            hops: List[Tuple[Link, float, float]] = []
            for link in self.route(src, dst):
                s = max(t, self._busy.get(link.key, 0.0))
                e = s + link.seconds(nbytes)
                if commit:
                    self._busy[link.key] = e
                hops.append((link, s, e))
                if first is None:
                    first = s
                t = e
            return (at if first is None else first), t, hops

    def queue_delay(
        self, src: Location, dst: Location, nbytes: int, *, at: float = 0.0
    ) -> float:
        """Extra modeled seconds a transfer issued at ``at`` would wait
        on busy links beyond its uncontended service time (peek only)."""
        _, end, _ = self.transfer(src, dst, nbytes, at=at, commit=False)
        return max(0.0, (end - at) - self.seconds(src, dst, nbytes))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Topology({self.name!r}, nodes={len(self.nodes)}, "
            f"links={len(self.links())})"
        )


class TopologyBandwidthModel:
    """Routes transfer costs over a :class:`Topology` — a drop-in for
    :class:`~repro.core.locations.BandwidthModel` (same ``seconds()``
    interface), so the ledger, eviction write-back ranking and HEFT
    placement all price transfers by route instead of by kind pair."""

    def __init__(self, topology: Topology) -> None:
        self.topology = topology

    def seconds(self, src: Location, dst: Location, nbytes: int) -> float:
        if src == dst:
            return 0.0
        return self.topology.seconds(src, dst, nbytes)

    def hops(self, src: Location, dst: Location) -> Tuple[Link, ...]:
        """The routed hop list (empty when src == dst).  The scalar
        model's counterpart returns ``None`` (single direct record)."""
        return self.topology.route(src, dst)

    def typical(self, nbytes: int) -> float:
        """Mean single-link seconds for ``nbytes`` — the topology
        analogue of the scalar model's host↔device estimate, used for
        HEFT's placement-agnostic communication term."""
        links = self.topology.links()
        if not links:
            return 0.0
        lat = sum(l.latency_s for l in links) / len(links)
        inv_bw = sum(1.0 / l.bandwidth for l in links) / len(links)
        return lat + nbytes * inv_bw


# ---------------------------------------------------------------------------
# Named presets (ISSUE 3) — the platform shapes the paper's targets span
# ---------------------------------------------------------------------------


def _emulated_soc(devices: Sequence[Location], host: Location) -> Topology:
    """Flat SoC: every device one hop from host, fast direct peer DMA —
    numerically identical to the scalar BandwidthModel's defaults."""
    topo = Topology("emulated_soc")
    for d in devices:
        topo.add_link(host, d, bandwidth=20e9, latency_s=5e-6,
                      name=f"dma:{d.name}")
    for i, a in enumerate(devices):
        for b in devices[i + 1:]:
            topo.add_link(a, b, bandwidth=100e9, latency_s=5e-6,
                          name=f"p2p:{a.name}-{b.name}")
    return topo


def _pcie_tree(devices: Sequence[Location], host: Location) -> Topology:
    """PCIe tree: all devices behind one switch; the host↔switch uplink
    is shared by every host-bound transfer (the contention hot spot),
    and peer traffic turns around at the switch without touching it."""
    topo = Topology("pcie_tree")
    bridge = Location("bridge", "pcie0")
    topo.add_link(host, bridge, bandwidth=25e9, latency_s=2e-6,
                  name="pcie:uplink")
    for d in devices:
        topo.add_link(bridge, d, bandwidth=12e9, latency_s=3e-6,
                      name=f"pcie:{d.name}")
    return topo


def _nvlink_mesh(devices: Sequence[Location], host: Location) -> Topology:
    """NVLink-style peer mesh: modest host links, fast low-latency
    direct links between every device pair."""
    topo = Topology("nvlink_mesh")
    for d in devices:
        topo.add_link(host, d, bandwidth=20e9, latency_s=5e-6,
                      name=f"pcie:{d.name}")
    for i, a in enumerate(devices):
        for b in devices[i + 1:]:
            topo.add_link(a, b, bandwidth=100e9, latency_s=2e-6,
                          name=f"nvlink:{a.name}-{b.name}")
    return topo


def _host_bridged_fpga(devices: Sequence[Location], host: Location) -> Topology:
    """Host-bridged FPGA fabric (ZCU102-style UDMA): slow high-latency
    host links and *no* peer links — device↔device bytes must route
    through the host, so both host links serialize under contention."""
    topo = Topology("host_bridged_fpga")
    for d in devices:
        topo.add_link(host, d, bandwidth=6e9, latency_s=20e-6,
                      name=f"udma:{d.name}")
    return topo


PRESETS = {
    "emulated_soc": _emulated_soc,
    "pcie_tree": _pcie_tree,
    "nvlink_mesh": _nvlink_mesh,
    "host_bridged_fpga": _host_bridged_fpga,
}


def build_preset(
    name: str,
    devices: Iterable[Union[Location, str]],
    *,
    host: Location = HOST,
) -> Topology:
    """Instantiate a named preset over ``devices`` (Locations, or bare
    names which become ``Location("device", name)``)."""
    try:
        builder = PRESETS[name]
    except KeyError:
        raise TopologyError(
            f"unknown topology preset {name!r} (have: {sorted(PRESETS)})"
        ) from None
    locs = [
        d if isinstance(d, Location) else Location("device", d)
        for d in devices
    ]
    return builder(locs, host)
