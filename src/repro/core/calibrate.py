"""Measured calibration — the runtime's last guessed constants become
measured (ROADMAP item 4, ISSUE 10 tentpole).

HEFT placement and every gated modeled metric rested on
:class:`~repro.core.graph.CostModel` throughput *priors*
(``BASE_THROUGHPUT``), only nudged by an online EMA.  This module closes
the loop:

* :class:`CalibrationTable` — a versioned ("rimms-calib-v1"),
  mergeable, persistable table of measured kernel timings keyed
  ``(op, variant, pe_kind, shape bucket)`` — the same power-of-two
  bucket keying the :class:`~repro.core.telemetry.DivergenceMonitor`
  uses, so calibration cells and divergence cells line up.  Winner rows
  per ``(op, pe_kind, bucket)`` record which registered kernel variant
  measured fastest (autotuning, see :mod:`repro.core.autotune`), and a
  table may embed a divergence-monitor state snapshot so one file
  carries both calibration and live EMA evidence
  (:meth:`~repro.core.api.Session.save_calibration`).
* :func:`calibrate` — the measurement harness: microbenchmarks every
  registered ``@rimms.op`` variant per PE kind across a ladder of input
  sizes (warmup + median-of-k, ``jax.block_until_ready``), on the
  thread backend *or* through the PE's subprocess worker under
  ``backend="process"``, verifying every non-default variant's outputs
  are **bit-identical** to the default variant before it may win.
* :func:`heft_plan` / :func:`simulate_plan` — a deterministic static
  HEFT planner + plan evaluator over the runtime's cost basis, used by
  ``bench_calibrate`` to gate *calibrated placement ≤ prior placement*
  without wall-clock noise: plan once with the prior model, once with a
  calibrated model, and price both plans under the measured truth.

Consumption: :meth:`CostModel.prior_estimate
<repro.core.graph.CostModel.prior_estimate>` consults an attached table
before falling back to ``BASE_THROUGHPUT``, so serial dispatch, the
windowed-HEFT stream placement, and the modeled replays all price work
from measured throughput; :meth:`Runtime._run_kernel
<repro.core.runtime.Runtime._run_kernel>` consults the table's winner
rows to dispatch the fastest bit-identical kernel variant.
"""

from __future__ import annotations

import json
import threading
import time
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from .graph import build_graph
from .telemetry import shape_bucket

__all__ = [
    "FORMAT", "DEFAULT_VARIANT", "DEFAULT_LADDER", "CalibrationTable",
    "calibrate", "resolve_calibration", "heft_plan", "simulate_plan",
]

#: on-disk format tag — bump on incompatible cell/winner layout changes
FORMAT = "rimms-calib-v1"

#: name of the reference variant every op has (the plain registration)
DEFAULT_VARIANT = "default"

#: default input-size ladder (bytes of total kernel input) — one cell
#: per power-of-two shape bucket from small to cache-busting
DEFAULT_LADDER = (64 << 10, 1 << 20, 8 << 20)


def _cell_key(op: str, variant: str, pe_kind: str, bucket: str) -> str:
    return "/".join((op, variant, pe_kind, bucket))


def _win_key(op: str, pe_kind: str, bucket: str) -> str:
    return "/".join((op, pe_kind, bucket))


def _bucket_of(nbytes_or_bucket) -> str:
    if isinstance(nbytes_or_bucket, str):
        return nbytes_or_bucket
    return shape_bucket(int(nbytes_or_bucket))


class CalibrationTable:
    """Measured per-(op, variant, PE kind, shape-bucket) kernel timings
    plus per-(op, PE kind, bucket) variant winners.

    Cells record the median measured seconds for one variant at one
    bucket (count-weighted means under :meth:`merge`, so tables from
    repeated runs — or different workers — fold together).  Winner rows
    name the variant that measured fastest with bit-identical outputs;
    ``speedup`` is default-median / winner-median (≥ 1.0 whenever a
    non-default variant wins).  ``divergence`` optionally embeds a
    :meth:`DivergenceMonitor.state()
    <repro.core.telemetry.DivergenceMonitor.state>` snapshot so one file
    replaces the raw divergence-JSON plumbing.  Thread-safe.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # "op/variant/kind/bucket" -> {count, nbytes, median_s, identical}
        self._cells: Dict[str, Dict[str, Any]] = {}
        # "op/kind/bucket" -> {variant, speedup, median_s}
        self._winners: Dict[str, Dict[str, Any]] = {}
        #: optional embedded DivergenceMonitor.state() snapshot
        self.divergence: Optional[dict] = None
        #: free-form provenance (host, backend, ladder, …)
        self.meta: Dict[str, Any] = {}

    # -- recording -----------------------------------------------------------
    def record(self, op: str, variant: str, pe_kind: str, nbytes: int,
               seconds: float, *, identical: Optional[bool] = None) -> None:
        """Fold one measurement (median of a batch) into the cell for
        ``nbytes``'s shape bucket."""
        key = _cell_key(op, variant, pe_kind, shape_bucket(nbytes))
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                self._cells[key] = {
                    "count": 1, "nbytes": int(nbytes),
                    "median_s": float(seconds), "identical": identical,
                }
                return
            n = cell["count"]
            cell["median_s"] = (n * cell["median_s"] + float(seconds)) / (n + 1)
            cell["nbytes"] = int(round((n * cell["nbytes"] + nbytes) / (n + 1)))
            cell["count"] = n + 1
            if identical is not None:
                cell["identical"] = (identical if cell["identical"] is None
                                     else cell["identical"] and identical)

    def set_winner(self, op: str, pe_kind: str, nbytes_or_bucket,
                   variant: str, *, speedup: float, median_s: float) -> None:
        with self._lock:
            self._winners[_win_key(op, pe_kind,
                                   _bucket_of(nbytes_or_bucket))] = {
                "variant": variant, "speedup": float(speedup),
                "median_s": float(median_s),
            }

    # -- lookup --------------------------------------------------------------
    def cell(self, op: str, pe_kind: str, nbytes_or_bucket,
             variant: str = DEFAULT_VARIANT) -> Optional[Dict[str, Any]]:
        with self._lock:
            c = self._cells.get(_cell_key(op, variant, pe_kind,
                                          _bucket_of(nbytes_or_bucket)))
            return dict(c) if c is not None else None

    def winner(self, op: str, pe_kind: str,
               nbytes_or_bucket) -> Optional[Dict[str, Any]]:
        with self._lock:
            w = self._winners.get(_win_key(op, pe_kind,
                                           _bucket_of(nbytes_or_bucket)))
            return dict(w) if w is not None else None

    def best_variant(self, op: str, pe_kind: str, nbytes: int) -> Optional[str]:
        """The winning *non-default* variant name for this bucket, or
        None (default dispatch) — what ``Runtime._run_kernel`` asks."""
        w = self.winner(op, pe_kind, nbytes)
        if w is None or w["variant"] == DEFAULT_VARIANT:
            return None
        return w["variant"]

    def estimate_s(self, op: str, pe_kind: str, nbytes: int, *,
                   launch_s: float = 0.0) -> Optional[float]:
        """Measured compute-seconds estimate for ``nbytes`` of input, or
        None when this exact ``(op, pe_kind, bucket)`` has no cell (the
        cost model then falls back to its throughput prior).  Uses the
        bucket's winner cell when present, else the default variant's;
        scales by measured seconds-per-byte around ``launch_s``."""
        bucket = shape_bucket(nbytes)
        w = self.winner(op, pe_kind, bucket)
        cell = None
        if w is not None:
            cell = self.cell(op, pe_kind, bucket, w["variant"])
        if cell is None:
            cell = self.cell(op, pe_kind, bucket)
        if cell is None:
            return None
        ref_bytes = cell["nbytes"]
        if ref_bytes <= 0:
            return cell["median_s"]
        per_byte = max(cell["median_s"] - launch_s, 0.0) / ref_bytes
        return launch_s + nbytes * per_byte

    def cells(self) -> List[Tuple[str, Dict[str, Any]]]:
        with self._lock:
            return sorted((k, dict(v)) for k, v in self._cells.items())

    def winners(self) -> List[Tuple[str, Dict[str, Any]]]:
        with self._lock:
            return sorted((k, dict(v)) for k, v in self._winners.items())

    def __len__(self) -> int:
        with self._lock:
            return len(self._cells)

    # -- persistence / merge -------------------------------------------------
    def state(self) -> dict:
        """JSON-safe full state (mergeable via :meth:`merge`)."""
        with self._lock:
            return {
                "format": FORMAT,
                "meta": dict(self.meta),
                "cells": {k: dict(v) for k, v in sorted(self._cells.items())},
                "winners": {k: dict(v)
                            for k, v in sorted(self._winners.items())},
                "divergence": self.divergence,
            }

    def merge(self, other: "CalibrationTable | dict") -> "CalibrationTable":
        """Fold another table (or its :meth:`state` dict) into this one:
        cells take count-weighted means, a winner row is replaced only by
        a strictly faster one, divergence snapshots merge exactly."""
        state = other.state() if isinstance(other, CalibrationTable) else other
        for key, c in (state.get("cells") or {}).items():
            if len(key.split("/")) != 4:
                continue
            with self._lock:
                mine = self._cells.get(key)
                if mine is None:
                    self._cells[key] = {
                        "count": int(c.get("count", 1)),
                        "nbytes": int(c.get("nbytes", 0)),
                        "median_s": float(c.get("median_s", 0.0)),
                        "identical": c.get("identical"),
                    }
                else:
                    n0, n1 = mine["count"], int(c.get("count", 1))
                    tot = max(n0 + n1, 1)
                    mine["median_s"] = (n0 * mine["median_s"]
                                        + n1 * float(c.get("median_s", 0.0))
                                        ) / tot
                    mine["nbytes"] = int(round(
                        (n0 * mine["nbytes"] + n1 * int(c.get("nbytes", 0)))
                        / tot))
                    mine["count"] = n0 + n1
                    ident = c.get("identical")
                    if ident is not None:
                        mine["identical"] = (
                            ident if mine["identical"] is None
                            else mine["identical"] and ident)
        for key, w in (state.get("winners") or {}).items():
            with self._lock:
                mine = self._winners.get(key)
                if mine is None or float(w.get("median_s", float("inf"))) \
                        < mine["median_s"]:
                    self._winners[key] = {
                        "variant": w.get("variant", DEFAULT_VARIANT),
                        "speedup": float(w.get("speedup", 1.0)),
                        "median_s": float(w.get("median_s", 0.0)),
                    }
        div = state.get("divergence")
        if div:
            from .telemetry import DivergenceMonitor

            mon = DivergenceMonitor(register=False)
            if self.divergence:
                mon.merge(self.divergence)
            mon.merge(div)
            self.divergence = mon.state()
        for k, v in (state.get("meta") or {}).items():
            self.meta.setdefault(k, v)
        return self

    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.state(), fh, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "CalibrationTable":
        with open(path) as fh:
            doc = json.load(fh)
        fmt = doc.get("format")
        if fmt != FORMAT:
            raise ValueError(
                f"{path}: not a calibration table (format {fmt!r}, "
                f"expected {FORMAT!r})")
        table = cls()
        table.merge(doc)
        table.meta.update(doc.get("meta") or {})
        return table

    # -- reporting -----------------------------------------------------------
    def diff(self, other: "CalibrationTable") -> Dict[str, dict]:
        """Cells/winners that differ between two tables (``a`` = self,
        ``b`` = other): changed medians, changed winning variants, and
        rows present on only one side."""
        out: Dict[str, dict] = {}
        a_cells, b_cells = dict(self.cells()), dict(other.cells())
        for key in sorted(set(a_cells) | set(b_cells)):
            ca, cb = a_cells.get(key), b_cells.get(key)
            if ca is None or cb is None:
                out[key] = {"a": ca and ca["median_s"],
                            "b": cb and cb["median_s"]}
            elif not np.isclose(ca["median_s"], cb["median_s"],
                                rtol=0.25, atol=1e-7):
                out[key] = {"a": ca["median_s"], "b": cb["median_s"],
                            "ratio": cb["median_s"] / max(ca["median_s"],
                                                          1e-12)}
        a_w, b_w = dict(self.winners()), dict(other.winners())
        for key in sorted(set(a_w) | set(b_w)):
            wa, wb = a_w.get(key), b_w.get(key)
            va = wa and wa["variant"]
            vb = wb and wb["variant"]
            if va != vb:
                out[f"winner:{key}"] = {"a": va, "b": vb}
        return out

    def to_markdown(self) -> str:
        """Human-readable report: winner rows first, then every cell."""
        lines = ["## Calibration table", ""]
        if self.meta:
            lines += [f"- **{k}**: {v}" for k, v in sorted(self.meta.items())]
            lines.append("")
        lines += ["### Variant winners", "",
                  "| op | PE kind | bucket | variant | speedup | median |",
                  "|---|---|---|---|---:|---:|"]
        for key, w in self.winners():
            op, kind, bucket = key.split("/", 2)
            lines.append(
                f"| {op} | {kind} | {bucket} | {w['variant']} "
                f"| {w['speedup']:.2f}x | {w['median_s'] * 1e6:.1f} µs |")
        lines += ["", "### Measured cells", "",
                  "| op | variant | PE kind | bucket | median | n | "
                  "bit-identical |",
                  "|---|---|---|---|---:|---:|---|"]
        for key, c in self.cells():
            op, variant, kind, bucket = key.split("/", 3)
            ident = {None: "—", True: "yes", False: "NO"}[c["identical"]]
            lines.append(
                f"| {op} | {variant} | {kind} | {bucket} "
                f"| {c['median_s'] * 1e6:.1f} µs | {c['count']} | {ident} |")
        if self.divergence:
            n = len(self.divergence.get("cells") or {})
            lines += ["", f"_Embedded divergence snapshot: {n} cells._"]
        return "\n".join(lines) + "\n"


def resolve_calibration(calibration) -> Optional[CalibrationTable]:
    """The ``Session(calibration=...)`` coercion: None → None, a table →
    itself, ``"auto"`` → load ``$RIMMS_CALIBRATION`` if it names an
    existing file (else an empty table that fills from this session's
    autotuning), any other str/path → :meth:`CalibrationTable.load`."""
    if calibration is None:
        return None
    if isinstance(calibration, CalibrationTable):
        return calibration
    if calibration == "auto":
        import os

        path = os.environ.get("RIMMS_CALIBRATION")
        if path and os.path.exists(path):
            return CalibrationTable.load(path)
        return CalibrationTable()
    return CalibrationTable.load(calibration)


# ---------------------------------------------------------------------------
# Measurement harness
# ---------------------------------------------------------------------------


def _identical(outs: Sequence[Any], ref: Sequence[Any]) -> bool:
    """Bit-exact output comparison (the autotuner's eligibility bar —
    a faster variant that changes even one ULP never dispatches)."""
    if len(outs) != len(ref):
        return False
    for a, b in zip(outs, ref):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape or a.dtype != b.dtype:
            return False
        if a.tobytes() != b.tobytes():
            return False
    return True


def _block(outs: tuple) -> tuple:
    try:
        import jax

        return tuple(jax.block_until_ready(o) for o in outs)
    except ImportError:  # pragma: no cover - jax is baked in
        return outs


def _measure_thread(fn: Callable, ins: List[Any], params: Dict[str, Any],
                    *, k: int, warmup: int) -> Tuple[float, tuple]:
    outs: tuple = ()
    for _ in range(max(warmup, 1)):
        outs = fn(ins, **params)
        if not isinstance(outs, tuple):
            outs = (outs,)
        outs = _block(outs)
    times = []
    for _ in range(max(k, 1)):
        t0 = time.perf_counter()
        o = fn(ins, **params)
        _block(o if isinstance(o, tuple) else (o,))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), outs


def _measure_process(rt, pe, key: tuple, fn: Callable, ins: List[Any],
                     params: Dict[str, Any], *, k: int,
                     warmup: int) -> Tuple[float, tuple]:
    worker = rt._get_process_pool().worker(pe.name)
    worker.ensure_kernel(key, fn)
    outs: tuple = ()
    for _ in range(max(warmup, 1)):
        outs, _, _, _, _ = worker.run(key, ins, params)
    times = []
    for _ in range(max(k, 1)):
        _, w0, w1, _, _ = worker.run(key, ins, params)
        times.append(w1 - w0)
    return float(np.median(times)), outs


def calibrate(target, *, registry=None, ops: Optional[Iterable[str]] = None,
              nbytes: Sequence[int] = DEFAULT_LADDER, k: int = 5,
              warmup: int = 2, seed: int = 0,
              table: Optional[CalibrationTable] = None,
              verbose: bool = False) -> CalibrationTable:
    """Microbenchmark every registered op variant per PE kind across the
    ``nbytes`` ladder; return (or extend) a :class:`CalibrationTable`.

    ``target`` is a :class:`~repro.core.api.Session` (its runtime and
    registry are used) or a bare :class:`~repro.core.runtime.Runtime`
    (pass ``registry=`` explicitly, or the process-default one is used).
    Only ops with a registered input factory (``@rimms.op(...,
    calib=...)``) are measured — others are skipped and listed in
    ``table.meta["skipped_ops"]``.  Under ``backend="process"`` each
    kind's measurements run on the PE's subprocess worker (pipe + shm
    path included, exactly what dispatch pays); otherwise in-thread with
    ``jax.block_until_ready``.

    Winner selection per ``(op, PE kind, bucket)``: fastest variant
    whose outputs are bit-identical to the default variant's (the
    default is always eligible); ``speedup`` = default-median /
    winner-median.
    """
    rt = getattr(target, "runtime", target)
    reg = registry or getattr(target, "registry", None)
    if reg is None:
        from .api import default_registry

        reg = default_registry
    table = table if table is not None else CalibrationTable()
    table.meta.setdefault("backend", rt.backend)
    table.meta.setdefault("ladder", [int(n) for n in nbytes])
    op_filter = set(ops) if ops is not None else None
    # one representative PE per kind, deterministic (sorted by name)
    rep: Dict[str, Any] = {}
    for pe in sorted(rt.pes, key=lambda p: p.name):
        rep.setdefault(pe.kind, pe)
    skipped: List[str] = []
    for op_name in reg.ops():
        if op_filter is not None and op_name not in op_filter:
            continue
        maker = reg.input_maker(op_name)
        if maker is None:
            skipped.append(op_name)
            continue
        for kind in reg.kinds(op_name):
            pe = rep.get(kind)
            if pe is None:
                continue
            use_proc = rt.backend == "process" and rt._proc_eligible(pe)
            for nb in nbytes:
                rng = np.random.default_rng([seed, int(nb)])
                ins = [np.asarray(a) for a in maker(rng, int(nb))]
                nb_act = sum(a.nbytes for a in ins)
                ref_outs: Optional[tuple] = None
                measured: List[Tuple[str, float, Optional[bool]]] = []
                for vname in reg.variants(op_name, kind):
                    var = reg.variant(op_name, kind, vname)
                    if use_proc:
                        median, outs = _measure_process(
                            rt, pe, ("calib", op_name, kind, vname),
                            var.fn, ins, dict(var.params), k=k,
                            warmup=warmup)
                    else:
                        median, outs = _measure_thread(
                            var.fn, ins, dict(var.params), k=k,
                            warmup=warmup)
                    if vname == DEFAULT_VARIANT:
                        ref_outs = outs
                        ident: Optional[bool] = None
                    else:
                        ident = (_identical(outs, ref_outs)
                                 if ref_outs is not None else False)
                    table.record(op_name, vname, kind, nb_act, median,
                                 identical=ident)
                    measured.append((vname, median, ident))
                    if verbose:
                        print(f"  {op_name}/{vname}/{kind}/"
                              f"{shape_bucket(nb_act)}: "
                              f"{median * 1e6:.1f} µs"
                              + ("" if ident is None
                                 else f" identical={ident}"))
                default_s = next(m for v, m, _ in measured
                                 if v == DEFAULT_VARIANT)
                eligible = [(v, m) for v, m, ident in measured
                            if v == DEFAULT_VARIANT or ident]
                win_v, win_s = min(eligible, key=lambda x: (x[1], x[0]))
                table.set_winner(op_name, kind, nb_act, win_v,
                                 speedup=default_s / max(win_s, 1e-12),
                                 median_s=win_s)
    if skipped:
        prev = table.meta.get("skipped_ops", [])
        table.meta["skipped_ops"] = sorted(set(prev) | set(skipped))
    return table


# ---------------------------------------------------------------------------
# Deterministic static HEFT planner — the bench_calibrate gate's core
# ---------------------------------------------------------------------------


def _src_location(hd, out_loc: Dict[int, Any]):
    return out_loc.get(id(hd), hd.last_location)


def heft_plan(rt, tasks, *, cost_model=None) -> List[str]:
    """Static HEFT over ``tasks`` on ``rt``'s PEs under ``cost_model``
    (default: the runtime's): upward ranks, then earliest-finish-time
    placement in rank order.  Pure planning — nothing executes, no
    wall-clock enters, so the same inputs always produce the same plan.
    Returns the placed PE name per task (submission order)."""
    cm = cost_model or rt.cost_model
    graph = build_graph(tasks)
    bw = rt.context.ledger.bandwidth_model

    def compute_cost(task) -> float:
        kinds = sorted({pe.kind for pe in rt._eligible(task)})
        return cm.mean_estimate(task.op, kinds, task.in_bytes)

    graph.compute_ranks(compute_cost, lambda t: bw.typical(t.in_bytes))
    order = sorted(graph.nodes, key=lambda n: (-n.rank, n.index))
    pe_free: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
    finish: Dict[int, float] = {}
    out_loc: Dict[int, Any] = {}
    placement: Dict[int, str] = {}
    for node in order:
        task = node.task
        pes = ([rt.by_name[task.pin]] if task.pin is not None
               else rt._eligible(task))
        ready = max((finish[d] for d in node.deps), default=0.0)

        def eft(pe) -> float:
            tr = sum(
                bw.seconds(_src_location(hd, out_loc), pe.location, hd.nbytes)
                for hd in task.inputs
                if _src_location(hd, out_loc) != pe.location
            )
            start = max(pe_free[pe.name], ready + tr)
            return start + cm.estimate(task.op, pe.kind, task.in_bytes)

        best = min(pes, key=lambda pe: (eft(pe), pe.name))
        f = eft(best)
        pe_free[best.name] = f
        finish[node.index] = f
        placement[node.index] = best.name
        for hd in task.outputs:
            out_loc[id(hd)] = best.location
    return [placement[i] for i in range(len(graph.nodes))]


def simulate_plan(rt, tasks, placement: Sequence[str], *,
                  cost_model=None) -> float:
    """Modeled makespan of executing ``tasks`` under a fixed
    ``placement`` (PE name per task), priced by ``cost_model`` —
    evaluate plans from *different* models under one truth model to
    compare placement quality.  Deterministic; nothing executes."""
    cm = cost_model or rt.cost_model
    graph = build_graph(tasks)
    bw = rt.context.ledger.bandwidth_model
    pe_free: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
    finish: Dict[int, float] = {}
    out_loc: Dict[int, Any] = {}
    for node in graph.nodes:  # builder order: deps have lower indices
        task = node.task
        pe = rt.by_name[placement[node.index]]
        ready = max((finish[d] for d in node.deps), default=0.0)
        tr = sum(
            bw.seconds(_src_location(hd, out_loc), pe.location, hd.nbytes)
            for hd in task.inputs
            if _src_location(hd, out_loc) != pe.location
        )
        start = max(pe_free[pe.name], ready + tr)
        end = start + cm.estimate(task.op, pe.kind, task.in_bytes)
        pe_free[pe.name] = end
        finish[node.index] = end
        for hd in task.outputs:
            out_loc[id(hd)] = pe.location
    return max(finish.values(), default=0.0)
