"""Locations — where a logical buffer's bytes may live.

The paper's ``hete_Data`` keeps one *resource pointer* per memory region
(host DDR, GPU global memory, FPGA UDMA buffer).  On a JAX platform the
analogous set of regions is:

* ``host``     — host RAM (numpy arrays; the pipeline / CPU-PE side),
* ``device``   — a single accelerator's HBM (emulated PEs on this box),
* ``mesh``     — device HBM *under a particular named sharding* — two
  different shardings of the same logical array are different locations,
  because moving between them costs collective traffic exactly like a
  host↔device copy costs PCIe/DMA traffic.

A :class:`Location` is a hashable identity; the payload representation per
location is managed by :mod:`repro.core.hete`.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Location", "HOST", "BandwidthModel", "DEFAULT_BANDWIDTH_MODEL"]


@dataclasses.dataclass(frozen=True)
class Location:
    """Identity of one memory region.

    ``kind``  — coarse class: "host" | "device" | "mesh".
    ``name``  — unique name within the kind ("gpu0", "fft_acc1", ...).
    """

    kind: str
    name: str

    def __str__(self) -> str:  # compact for ledgers / logs
        return f"{self.kind}:{self.name}"


HOST = Location("host", "cpu")


@dataclasses.dataclass(frozen=True)
class BandwidthModel:
    """Models transfer cost between location kinds (for modeled-time
    reporting on the emulated SoC — measured wall time is reported too).

    Bandwidths in bytes/second, latency in seconds per transfer. Defaults
    approximate the paper's platforms (Jetson AGX PCIe-class host↔device
    link; direct device↔device DMA).
    """

    host_device_bw: float = 20e9  # ~PCIe4 x8 effective
    device_device_bw: float = 100e9  # on-SoC DMA / NVLink-class
    host_host_bw: float = 50e9
    latency_s: float = 5e-6

    def seconds(self, src: Location, dst: Location, nbytes: int) -> float:
        if src == dst:
            return 0.0
        if src.kind == "host" and dst.kind == "host":
            bw = self.host_host_bw
        elif src.kind == "host" or dst.kind == "host":
            bw = self.host_device_bw
        else:
            bw = self.device_device_bw
        return self.latency_s + nbytes / bw

    def hops(self, src: Location, dst: Location) -> None:
        """Routed hop list for a src→dst copy.  The scalar model has no
        topology: ``None`` means "record one direct hop".  The
        interconnect-aware counterpart
        (:class:`repro.core.topology.TopologyBandwidthModel`) returns
        the actual route."""
        return None

    def typical(self, nbytes: int) -> float:
        """Placement-agnostic single-transfer estimate (HEFT's
        communication term): the host↔device link."""
        return self.latency_s + nbytes / self.host_device_bw


DEFAULT_BANDWIDTH_MODEL = BandwidthModel()
