"""RIMMS core: allocators, hete_Data tracking, task runtime, KV page pool."""

from .allocator import AllocError, BitsetAllocator, Extent, NextFitAllocator, make_allocator
from .api import (
    BufferFuture, OpRegistry, Session, SessionClient, SessionClosedError,
    default_registry, op,
)
from .executor import GraphExecutor, StreamExecutor, WorkerPool, replay_schedule
from .graph import CostModel, GraphBuilder, TaskGraph, TaskNode, build_graph
from .hete import (
    HeteContext, HeteData, PrefetchDeferred, default_context,
    hete_free, hete_malloc, hete_sync,
)
from .instrument import (
    Timeline, TimelineEvent, TransferEvent, TransferLedger, Timer,
    jain_index, ledger,
)
from .locations import HOST, BandwidthModel, Location
from .qos import (
    BackpressureFull, ClientState, QoSManager, QuotaExceeded,
    admission_cost, fair_replay,
)
from .paged_kv import PagedKVPool, gather_kv, init_pool_arrays, write_token
from .pworker import ProcessWorker, ProcessWorkerPool, WorkerDied
from .runtime import (
    BACKENDS, PE, Runtime, Task, make_emulated_soc, platform_names,
    register_platform, resolve_backend,
)
from .shm import SharedHostArena, describe_array, resolve_handle
from .topology import (
    Link, Topology, TopologyBandwidthModel, TopologyError, build_preset,
)
from .trace import (
    Counter, Gauge, Histogram, MetricsRegistry, TraceCollector,
    global_collector, install_global, trace, trace_lint,
)

__all__ = [
    "AllocError", "BitsetAllocator", "Extent", "NextFitAllocator", "make_allocator",
    "BufferFuture", "OpRegistry", "Session", "SessionClient",
    "SessionClosedError", "default_registry", "op",
    "BackpressureFull", "ClientState", "QoSManager", "QuotaExceeded",
    "admission_cost", "fair_replay", "jain_index",
    "GraphExecutor", "StreamExecutor", "WorkerPool", "replay_schedule",
    "CostModel", "GraphBuilder", "TaskGraph", "TaskNode", "build_graph",
    "HeteContext", "HeteData", "PrefetchDeferred", "default_context",
    "hete_free", "hete_malloc", "hete_sync",
    "Timeline", "TimelineEvent", "TransferEvent", "TransferLedger", "Timer",
    "ledger",
    "HOST", "BandwidthModel", "Location",
    "Link", "Topology", "TopologyBandwidthModel", "TopologyError",
    "build_preset",
    "PagedKVPool", "gather_kv", "init_pool_arrays", "write_token",
    "ProcessWorker", "ProcessWorkerPool", "WorkerDied",
    "PE", "Runtime", "Task", "make_emulated_soc",
    "BACKENDS", "resolve_backend", "register_platform", "platform_names",
    "SharedHostArena", "describe_array", "resolve_handle",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "TraceCollector",
    "global_collector", "install_global", "trace", "trace_lint",
]
