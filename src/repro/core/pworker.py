"""Process-backed PE workers (ISSUE 7 tentpole).

Each eligible PE gets one subprocess (spawned lazily on first use) that
executes registered ``@rimms.op`` kernels against host payloads.  Arrays
whose bytes live in a :class:`~repro.core.shm.SharedHostArena` cross the
process boundary as zero-copy handles; everything else is sent inline.
Kernels are shipped once per ``(op, pe kind)`` by *reference* (standard
pickle of a module-level function), so the worker imports exactly the
module that defined the kernel — numpy-only kernel modules spawn in
milliseconds, jax ones pay one XLA import per worker.

The pool deliberately changes nothing about scheduling or the memory
model: staging, flag checks, the transfer ledger and the modeled replay
all run in the parent exactly as under the thread backend — only the
kernel call itself moves out of the GIL.  Per-PE serialization is
preserved (one pipe per worker, one executing thread per PE), which is
also what keeps forwarded worker spans non-overlapping on their tracks.

Failure model: a worker that dies mid-call surfaces as
:class:`WorkerDied` (with the exit code) from the task that was running
on it — a clean per-task error through the session's existing failure
paths, never a hang.  ``shutdown()`` asks workers to exit, then joins
and finally kills stragglers, so ``Runtime.close()`` reaps every
subprocess.
"""

from __future__ import annotations

import multiprocessing as mp
import pickle
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import shm as shm_mod
from .trace import MetricsRegistry

__all__ = ["WorkerDied", "ProcessWorker", "ProcessWorkerPool", "worker_main"]

# Scratch segment each worker allocates for its outputs (grown on demand).
_SCRATCH_START = 8 << 20


class WorkerDied(RuntimeError):
    """A PE worker subprocess exited while (or before) running a task."""


# ---------------------------------------------------------------------------
# Worker side (runs in the subprocess)
# ---------------------------------------------------------------------------


def _resolve_payloads(handles: List[Tuple[str, Any]]) -> List[Any]:
    out = []
    for kind, payload in handles:
        if kind == "shm":
            out.append(shm_mod.resolve_handle(payload))
        else:  # "inline"
            out.append(payload)
    return out


class _Scratch:
    """Bump allocator over the worker's own shared segment for outputs.

    Reset every task: the parent copies results out before it sends the
    next task on this pipe, so reuse is safe.
    """

    def __init__(self) -> None:
        self.shm = None
        self.size = 0
        self.off = 0

    def _ensure(self, nbytes: int) -> None:
        if self.shm is not None and self.off + nbytes <= self.size:
            return
        need = max(self.size * 2, self.off + nbytes, _SCRATCH_START)
        old = self.shm
        from multiprocessing import shared_memory

        self.shm = shared_memory.SharedMemory(create=True, size=need)
        self.size = need
        self.off = 0
        if old is not None:
            old.close()
            old.unlink()

    def place(self, arr: np.ndarray) -> Tuple[str, Any]:
        """Copy ``arr`` into scratch, return a handle (or inline on any
        shared-memory failure)."""
        arr = np.ascontiguousarray(arr)
        try:
            self._ensure(arr.nbytes)
        except Exception:  # pragma: no cover - /dev/shm exhausted
            return ("inline", arr)
        off = self.off
        view = np.ndarray(arr.shape, dtype=arr.dtype, buffer=self.shm.buf,
                          offset=off)
        np.copyto(view, arr)
        # 64-byte align the next placement (matches SharedHostArena).
        self.off = off + ((arr.nbytes + 63) & ~63)
        return ("shm", (self.shm.name, off, arr.shape, arr.dtype.str))

    def reset(self) -> None:
        self.off = 0

    def destroy(self) -> None:
        if self.shm is not None:
            try:
                self.shm.close()
                self.shm.unlink()
            except Exception:  # pragma: no cover
                pass
            self.shm = None


def _to_host(value: Any) -> np.ndarray:
    """Worker-side egress: kernels may return jax arrays; ship numpy."""
    if isinstance(value, np.ndarray):
        return value
    return np.asarray(value)


def worker_main(conn, pe_name: str) -> None:
    """Subprocess entry point: serve kernel calls over ``conn``.

    Protocol (parent → worker / worker → parent):

    * ``("init",)`` → ``("ready", pid, perf_counter)`` — the clock reply
      is the offset handshake trace forwarding uses.
    * ``("reg", key, fn_bytes)`` → ``("ok",)`` | ``("err", msg)``.
    * ``("run", key, handles, params)`` →
      ``("ok", out_handles, t0, t1)`` | ``("err", msg)`` where t0/t1 are
      the kernel interval on the *worker's* clock.
    * ``("metrics",)`` → ``("ok", state)`` — drain the worker-local
      metrics registry (counters + histograms accumulated since the last
      drain) for cross-process aggregation (ISSUE 8).
    * ``("exit",)`` → worker cleans up and leaves.
    """
    import os

    kernels: Dict[tuple, Any] = {}
    scratch = _Scratch()
    # Worker-local metrics (ISSUE 8): accumulated here without any IPC
    # on the hot path, merged into the parent registry on drain.
    metrics = MetricsRegistry()
    try:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):  # parent died
                break
            cmd = msg[0]
            if cmd == "exit":
                conn.send(("bye",))
                break
            if cmd == "init":
                conn.send(("ready", os.getpid(), time.perf_counter()))
                continue
            if cmd == "reg":
                _, key, fn_bytes = msg
                try:
                    kernels[tuple(key)] = pickle.loads(fn_bytes)
                    conn.send(("ok",))
                except BaseException:
                    conn.send(("err", traceback.format_exc()))
                continue
            if cmd == "run":
                _, key, handles, params = msg
                try:
                    fn = kernels[tuple(key)]
                    ins = _resolve_payloads(handles)
                    t0 = time.perf_counter()
                    outs = fn(ins, **params)
                    if not isinstance(outs, tuple):
                        outs = (outs,)
                    outs = tuple(_to_host(o) for o in outs)
                    t1 = time.perf_counter()
                    scratch.reset()
                    out_handles = [scratch.place(o) for o in outs]
                    metrics.counter(f"worker/{pe_name}/tasks").inc()
                    metrics.histogram(
                        f"worker/{pe_name}/kernel_s").record(t1 - t0)
                    conn.send(("ok", out_handles, t0, t1))
                except BaseException:
                    metrics.counter(f"worker/{pe_name}/errors").inc()
                    conn.send(("err", traceback.format_exc()))
                continue
            if cmd == "metrics":
                # Drain semantics: each reply carries only the delta
                # since the previous drain, so the parent can merge at
                # every session close without double counting.
                conn.send(("ok", metrics.state()))
                metrics = MetricsRegistry()
                continue
            conn.send(("err", f"unknown command {cmd!r}"))  # pragma: no cover
    finally:
        scratch.destroy()
        shm_mod.detach_all()
        try:
            conn.close()
        except Exception:  # pragma: no cover
            pass


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------


class ProcessWorker:
    """Parent handle for one PE's subprocess: pipe, clock offset, cache
    of which kernels were already shipped."""

    def __init__(self, pe_name: str, ctx: Optional[mp.context.BaseContext] = None) -> None:
        ctx = ctx or mp.get_context("spawn")
        self.pe_name = pe_name
        self.conn, child = ctx.Pipe(duplex=True)
        self.proc = ctx.Process(
            target=worker_main, args=(child, pe_name),
            name=f"rimms-pe-{pe_name}", daemon=True,
        )
        self.proc.start()
        child.close()
        self._sent: set = set()
        self._scratch_names: set = set()
        self._lock = threading.Lock()
        # Clock-offset handshake: worker perf_counter + offset ≈ parent
        # perf_counter (midpoint estimate; forwarded spans are clamped to
        # the parent-observed call window anyway).
        t_a = time.perf_counter()
        reply = self._rpc(("init",))
        t_b = time.perf_counter()
        self.pid = reply[1]
        self.clock_offset = (t_a + t_b) / 2 - reply[2]

    def _rpc(self, msg: tuple) -> tuple:
        try:
            self.conn.send(msg)
            reply = self.conn.recv()
        except (EOFError, OSError, BrokenPipeError) as e:
            self.proc.join(timeout=1.0)
            raise WorkerDied(
                f"PE worker {self.pe_name!r} (pid {self.proc.pid}) died "
                f"with exit code {self.proc.exitcode} during {msg[0]!r}"
            ) from e
        if reply[0] == "err":
            raise RuntimeError(
                f"kernel error on PE worker {self.pe_name!r}:\n{reply[1]}")
        return reply

    def ensure_kernel(self, key: tuple, fn: Any) -> None:
        if key in self._sent:
            return
        try:
            fn_bytes = pickle.dumps(fn)
        except Exception as e:
            raise RuntimeError(
                f"kernel {key} is not picklable ({e}); the process backend "
                f"needs module-level kernel functions — use backend='thread' "
                f"for closures/lambdas") from e
        self._rpc(("reg", key, fn_bytes))
        self._sent.add(key)

    def run(self, key: tuple, ins: List[Any], params: Dict[str, Any]
            ) -> Tuple[tuple, float, float, float, float]:
        """Execute; returns (outputs, wall call window in parent clock
        w0..w1, kernel interval in parent clock k0..k1)."""
        handles: List[Tuple[str, Any]] = []
        for v in ins:
            h = shm_mod.describe_array(v)
            handles.append(("shm", h) if h is not None
                           else ("inline", np.asarray(v)))
        with self._lock:
            w0 = time.perf_counter()
            reply = self._rpc(("run", key, handles, params))
            w1 = time.perf_counter()
            _, out_handles, t0_w, t1_w = reply
            for kind, p in out_handles:
                if kind == "shm":
                    self._scratch_names.add(p[0])
            # Copy results out of the worker's scratch before the next
            # task reuses it (one copy; inputs were zero-copy).
            outs = tuple(
                np.array(shm_mod.resolve_handle(p)) if kind == "shm" else p
                for kind, p in out_handles
            )
        k0 = min(max(t0_w + self.clock_offset, w0), w1)
        k1 = min(max(t1_w + self.clock_offset, k0), w1)
        return outs, w0, w1, k0, k1

    def metrics_state(self) -> Dict[str, Any]:
        """Drain the worker's local metrics registry: returns a
        :meth:`~repro.core.trace.MetricsRegistry.state` dict and resets
        the worker-side accumulators."""
        with self._lock:
            reply = self._rpc(("metrics",))
        return reply[1]

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()

    def shutdown(self, timeout: float = 5.0) -> None:
        try:
            self.conn.send(("exit",))
        except (OSError, BrokenPipeError):
            pass
        self.proc.join(timeout=timeout)
        if self.proc.is_alive():  # pragma: no cover - stuck worker
            self.proc.kill()
            self.proc.join(timeout=1.0)
        try:
            self.conn.close()
        except Exception:  # pragma: no cover
            pass
        # A clean worker unlinks its own scratch; one that died hard
        # leaves it registered with the (shared) resource tracker until
        # interpreter exit.  Reap it here so worker death never leaks a
        # segment or a shutdown warning.
        from multiprocessing import shared_memory

        for name in self._scratch_names:
            try:
                seg = shared_memory.SharedMemory(name=name)
            except (FileNotFoundError, OSError):
                continue
            try:
                seg.close()
                seg.unlink()
            except Exception:  # pragma: no cover
                pass


class ProcessWorkerPool:
    """Lazy per-PE subprocess registry; thread-safe get-or-spawn."""

    def __init__(self) -> None:
        self._workers: Dict[str, ProcessWorker] = {}
        self._lock = threading.Lock()
        self._ctx = mp.get_context("spawn")
        self.closed = False

    def worker(self, pe_name: str) -> ProcessWorker:
        with self._lock:
            if self.closed:
                raise WorkerDied("process worker pool is shut down")
            w = self._workers.get(pe_name)
            if w is not None and not w.alive:
                # Died outside a call (e.g. killed externally): replace so
                # later tasks get a live worker; the task that *observed*
                # the death already got its WorkerDied.
                w.shutdown(timeout=0.1)
                w = None
            if w is None:
                w = ProcessWorker(pe_name, self._ctx)
                self._workers[pe_name] = w
            return w

    def pids(self) -> Dict[str, int]:
        with self._lock:
            return {n: w.pid for n, w in self._workers.items()}

    def collect_metrics(self, registry: MetricsRegistry) -> int:
        """Drain every live worker's local metrics into ``registry``
        (ISSUE 8 cross-process aggregation).  Dead workers are skipped —
        their un-drained deltas are lost, which is the documented
        trade-off for a lock-free worker hot path.  Returns the number
        of workers merged."""
        with self._lock:
            workers = list(self._workers.values())
        merged = 0
        for w in workers:
            try:
                registry.merge_state(w.metrics_state())
                merged += 1
            except (WorkerDied, RuntimeError):
                continue
        return merged

    def procs(self) -> List[mp.Process]:
        with self._lock:
            return [w.proc for w in self._workers.values()]

    def shutdown(self) -> None:
        with self._lock:
            if self.closed:
                return
            self.closed = True
            workers = list(self._workers.values())
            self._workers.clear()
        for w in workers:
            w.shutdown()
