"""Task runtime — the CEDR analogue RIMMS integrates with (§2, §3.2.2).

A small dynamic task runtime: applications submit *API calls* (tasks) over
:class:`~repro.core.hete.HeteData` buffers; a scheduler maps each task to a
processing element (PE) at dispatch time (round-robin, pinned,
data-affinity, or transfer-aware HEFT-lite); the memory policy decides
what data movement happens.

Two memory policies, both first-class so every experiment reports the pair:

* ``"reference"`` — the paper's baseline (host-owned data): every input is
  copied host→PE before execution and every output PE→host after, so the
  host always holds the valid copy (Fig 1a).
* ``"rimms"``     — the paper's contribution: per-input last-resource-flag
  check, direct src→PE copy only when the flag names another location,
  output flag update to the executing PE (Fig 1b).

The **primary public entry point is the streaming session API**
(:mod:`repro.core.api`, ISSUE 4): ``@rimms.op``-registered kernels,
``Session.malloc``/``Session.submit`` returning
:class:`~repro.core.api.BufferFuture` handles, and the persistent
:class:`~repro.core.executor.StreamExecutor` consuming the task stream
continuously.  This class is the **dispatch engine behind it** — the
session drives the same stage → execute → commit pipeline, scheduler
cost bases, and kernel registry defined here.

Two batch execution modes are kept as thin compat wrappers over that
pipeline:

* :meth:`Runtime.run` — serial, submission order (CEDR's API-level
  serialization);
* :meth:`Runtime.run_graph` — the batch task-graph executor
  (:class:`~repro.core.executor.GraphExecutor`): automatic DAG
  construction, one worker per PE, input prefetch overlapping transfers
  with compute.

PEs are emulated on this CPU-only box: a "cpu" PE executes numpy
callables against host memory; accelerator PEs ("fft_acc", "zip_acc",
"gpu") execute jitted JAX callables against their own
:class:`~repro.core.hete.MemorySpace`. Transfers between spaces are real
array movements and are recorded in the ledger (count, bytes, modeled
seconds under platform bandwidths).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
import warnings
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import CostModel
from .hete import HeteContext, HeteData, MemorySpace
from .instrument import Timeline, TimelineEvent
from .locations import HOST, Location
from .telemetry import DivergenceMonitor

__all__ = ["PE", "Task", "Runtime", "make_emulated_soc", "SCHEDULERS",
           "BACKENDS", "resolve_backend", "register_platform",
           "platform_names"]

# ---------------------------------------------------------------------------
# Execution backends (ISSUE 7) — one knob, threaded everywhere
# ---------------------------------------------------------------------------

#: valid values for the ``backend=`` knob (Session / Session.emulated /
#: Runtime / make_emulated_soc / benchmarks).
BACKENDS = ("thread", "process", "auto")


def resolve_backend(backend: Optional[str]) -> str:
    """Validate + resolve a backend name to ``"thread"`` or ``"process"``.

    ``None`` means thread (the historical default).  ``"auto"`` picks the
    process backend when real parallelism is available — more than one
    CPU core, or more than one JAX device — and thread otherwise."""
    if backend is None:
        return "thread"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}: choose one of {BACKENDS}")
    if backend == "auto":
        if (os.cpu_count() or 1) > 1:
            return "process"
        try:
            import jax

            if len(jax.devices()) > 1:
                return "process"
        except Exception:  # pragma: no cover - jax is baked in
            pass
        return "thread"
    return backend


# ---------------------------------------------------------------------------
# Platform-preset shorthand registry (ISSUE 7 satellite, carried from PR 4)
# ---------------------------------------------------------------------------

# name -> (topology_factory(dev_locs) -> Topology | None, arena_bytes | None)
_PLATFORMS: Dict[str, tuple] = {}


def register_platform(name: str, topology_factory: Optional[Callable] = None,
                      arena_bytes: Optional[int] = None, *,
                      replace: bool = False) -> None:
    """Register a platform preset so ``Session.emulated("name")`` (and
    ``make_emulated_soc(topology="name")``) resolves it.

    ``topology_factory(dev_locs)`` returns the
    :class:`~repro.core.topology.Topology` for the platform's device
    locations (``None`` keeps the scalar bandwidth model);
    ``arena_bytes`` is the preset's default per-accelerator arena
    capacity (callers may still override it).  Built-in presets mirror
    :data:`repro.core.topology.PRESETS`; re-registering a name raises
    unless ``replace=True``."""
    _register_builtin_platforms()
    if not replace and name in _PLATFORMS:
        raise ValueError(f"platform {name!r} already registered "
                         f"(pass replace=True to override)")
    _PLATFORMS[name] = (topology_factory, arena_bytes)


def platform_names() -> Tuple[str, ...]:
    """Registered platform preset names (built-ins + user presets)."""
    _register_builtin_platforms()
    return tuple(sorted(_PLATFORMS))


def _resolve_platform(name: str):
    """The registry entry for ``name`` or None (fall through to the raw
    topology presets for back-compat)."""
    _register_builtin_platforms()
    return _PLATFORMS.get(name)


def _register_builtin_platforms() -> None:
    # Lazy (first use), so importing this module never imports topology.
    from .topology import PRESETS, build_preset

    for preset in PRESETS:
        _PLATFORMS.setdefault(
            preset,
            (lambda locs, _p=preset: build_preset(_p, locs), 64 << 20),
        )

SCHEDULERS = ("round_robin", "data_affinity", "heft")


@dataclasses.dataclass
class PE:
    """A processing element: name, kind, its memory location, supported ops."""

    name: str
    kind: str  # "cpu" | "acc" | "gpu" | ...
    location: Location
    supports: frozenset

    def __post_init__(self) -> None:
        self.supports = frozenset(self.supports)


@dataclasses.dataclass
class Task:
    """One API call: op over HeteData inputs/outputs (+ scalar params)."""

    op: str
    inputs: List[HeteData]
    outputs: List[HeteData]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    pin: Optional[str] = None  # pin to a PE name (CPU-ACC style scenarios)
    name: str = ""
    # submitting session client (ISSUE 5): per-tenant accounting +
    # cross-client interference-aware placement key on it
    client: Optional[str] = None

    @property
    def in_bytes(self) -> int:
        return sum(hd.nbytes for hd in self.inputs)

    @property
    def out_bytes(self) -> int:
        return sum(hd.nbytes for hd in self.outputs)


class Runtime:
    """Dispatch loop: schedule → move (policy) → execute → flag update."""

    def __init__(
        self,
        pes: Sequence[PE],
        context: HeteContext,
        *,
        policy: str = "rimms",
        scheduler: str = "round_robin",
        cost_model: Optional[CostModel] = None,
        backend: Optional[str] = None,
    ) -> None:
        if policy not in ("rimms", "reference"):
            raise ValueError(f"unknown memory policy {policy!r}")
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}")
        #: "thread" (in-process kernels) or "process" (subprocess PE
        #: workers for host-payload PEs, ISSUE 7); "auto" resolves here.
        self.backend = resolve_backend(backend)
        self.pes = list(pes)
        self.by_name = {pe.name: pe for pe in self.pes}
        self.context = context
        self.policy = policy
        self.scheduler = scheduler
        self.cost_model = cost_model or CostModel()
        # Measured-vs-modeled divergence (ISSUE 8): every compute/stage
        # execution pairs its wall duration with the cost model's prior
        # into per-(op, PE kind, shape bucket) ratio cells — surfaced in
        # Session.qos_report()["divergence"] and bench JSON records.
        self.divergence = DivergenceMonitor()
        self._rr_state: Dict[str, int] = {}
        # kernels: (op, pe_kind) -> callable(list_of_arrays, **params) -> tuple
        self._kernels: Dict[tuple, Callable] = {}
        # tuned kernel variants (ISSUE 10): (op, pe_kind, variant name)
        # -> (callable, bound launch params) — dispatched when an
        # attached calibration table names a winner for the shape bucket
        self._variant_kernels: Dict[tuple, Tuple[Callable, dict]] = {}
        #: attached CalibrationTable (None = default dispatch + priors)
        self.calibration = None
        #: non-default variant dispatches, (op, pe kind, variant) per
        #: call — outputs are bit-identical by construction, so tests
        #: and benches assert selection through this log
        self.variant_log: List[tuple] = []
        self.task_log: List[tuple] = []  # (task name/op, pe name) for tests
        self.timeline = Timeline()  # replaced per run/run_graph
        self.last_makespan_model = 0.0
        self.last_report: Optional[Dict[str, Any]] = None  # set by run_graph
        # persistent per-PE worker pool, created lazily by run_graph and
        # reused across calls (ISSUE 2); close() releases it
        self._worker_pool = None
        # per-PE subprocess workers (ISSUE 7), created lazily on the
        # first process-dispatched kernel; close() reaps them
        self._process_pool = None

    def set_backend(self, backend: Optional[str]) -> str:
        """Re-resolve the execution backend (e.g. a Session adopting this
        runtime with an explicit ``backend=``).  Returns the resolved
        name; an unknown name raises listing the valid choices."""
        if backend is not None:
            self.backend = resolve_backend(backend)
        return self.backend

    def _get_worker_pool(self):
        from .executor import WorkerPool  # local import: avoids cycle

        if self._worker_pool is None:
            pool = WorkerPool(self.pes)
            self._worker_pool = pool
            # release the pool's threads when this Runtime is collected
            self._pool_finalizer = weakref.finalize(
                self, WorkerPool.shutdown, pool
            )
        return self._worker_pool

    def _get_process_pool(self):
        from .pworker import ProcessWorkerPool  # local import: avoids cycle

        if self._process_pool is None:
            pool = ProcessWorkerPool()
            self._process_pool = pool
            # reap subprocesses when this Runtime is collected
            self._ppool_finalizer = weakref.finalize(
                self, ProcessWorkerPool.shutdown, pool
            )
        return self._process_pool

    def close(self) -> None:
        """Release the persistent worker pool and reap every PE worker
        subprocess (idempotent)."""
        if self._worker_pool is not None:
            self._pool_finalizer.detach()
            self._worker_pool.shutdown()
            self._worker_pool = None
        if self._process_pool is not None:
            self._ppool_finalizer.detach()
            self._process_pool.shutdown()
            self._process_pool = None

    def reset_stats(self) -> None:
        """Clear per-run diagnostics and dispatch state: the task log,
        round-robin rotation, timeline, and last modeled makespan/report.
        Called at the start of every :meth:`run`/:meth:`run_graph`, so
        repeated batch runs neither accumulate log entries nor leak
        round-robin placement state across runs (ISSUE 4 satellite) —
        ``task_log`` after a run is exactly that run's placements, and
        identical task lists place identically on every run.  Streaming
        sessions deliberately do *not* reset between barriers: the
        stream is one continuous run."""
        self.task_log = []
        self.variant_log = []
        self._rr_state = {}
        self.timeline = Timeline()
        self.last_makespan_model = 0.0
        self.last_report = None

    # -- registration -------------------------------------------------------
    def register_kernel(self, op: str, pe_kind: str, fn: Callable, *,
                        variant: Optional[str] = None,
                        params: Optional[Dict[str, Any]] = None) -> None:
        """Register a kernel.  Without ``variant`` this is the op's
        default (reference) kernel — the historical behavior every call
        site relies on.  With ``variant`` it is a tuned candidate
        (ISSUE 10): ``params`` are its launch parameters, merged *under*
        per-task params at dispatch; it only runs when the attached
        calibration table names it the winner for the task's shape
        bucket."""
        if variant is None:
            self._kernels[(op, pe_kind)] = fn
        else:
            self._variant_kernels[(op, pe_kind, variant)] = (
                fn, dict(params or {}))

    def set_calibration(self, table) -> None:
        """Attach a :class:`~repro.core.calibrate.CalibrationTable`:
        the cost model prices from its measured cells
        (:meth:`CostModel.set_calibration
        <repro.core.graph.CostModel.set_calibration>`) and
        :meth:`_run_kernel` dispatches its winning variants.  ``None``
        detaches (default priors + default kernels)."""
        self.calibration = table
        self.cost_model.set_calibration(table)

    # -- scheduling -----------------------------------------------------------
    def _eligible(self, task: Task) -> List[PE]:
        pes = [
            pe
            for pe in self.pes
            if task.op in pe.supports and (task.op, pe.kind) in self._kernels
        ]
        if not pes:
            raise LookupError(f"no PE supports op {task.op!r}")
        return pes

    def _schedule(self, task: Task) -> PE:
        if task.pin is not None:
            return self.by_name[task.pin]
        pes = self._eligible(task)
        if self.scheduler == "round_robin":
            i = self._rr_state.get(task.op, 0)
            self._rr_state[task.op] = (i + 1) % len(pes)
            return pes[i % len(pes)]
        if self.scheduler == "heft":
            # Transfer-aware greedy pick: minimize modeled staging cost +
            # estimated compute (per-PE availability is the executor's
            # refinement; serial dispatch has no queues to account for).
            return min(pes, key=lambda pe: (sum(self._heft_costs(task, pe)),
                                            pe.name))
        # data_affinity (beyond-paper)
        return self._affinity_pick(task, pes)

    def _affinity_pick(self, task: Task, pes: Sequence[PE]) -> PE:
        """Most input bytes already valid at the PE; ties broken by stable
        PE-name ordering (deterministic).  Shared by serial dispatch and
        the graph executor."""
        def score(pe: PE) -> int:
            return sum(
                hd.nbytes for hd in task.inputs if hd.last_location == pe.location
            )
        return min(pes, key=lambda pe: (-score(pe), pe.name))

    def _heft_costs(self, task: Task, pe: PE) -> Tuple[float, float]:
        """(modeled input-transfer seconds, estimated compute seconds) for
        placing ``task`` on ``pe`` — the shared EFT cost basis for serial
        heft dispatch and the graph executor's placement."""
        bw = self.context.ledger.bandwidth_model
        tr = sum(
            bw.seconds(hd.last_location, pe.location, hd.nbytes)
            for hd in task.inputs
            if hd.last_location != pe.location
        )
        return tr, self.cost_model.estimate(task.op, pe.kind, task.in_bytes)

    # -- stage → execute → commit (shared by serial and graph modes) ---------
    def _pin_inputs(self, task: Task, loc: Location) -> None:
        """Hard-pin every input's root at ``loc`` so eviction triggered by
        a concurrent (or this task's own output) reservation can never
        spill bytes the kernel is about to read.  Balanced by
        :meth:`_unpin_inputs` after commit."""
        for hd in task.inputs:
            self.context.pin(hd, loc)

    def _unpin_inputs(self, task: Task, loc: Location) -> None:
        for hd in task.inputs:
            self.context.unpin(hd, loc)

    def _stage_inputs(
        self, task: Task, pe: PE, *, prefetch: bool = False
    ) -> Tuple[List[Any], float, float, List[tuple]]:
        """Materialize ``task``'s inputs at ``pe`` under the memory policy.
        Returns (input values, modeled transfer seconds, modeled seconds
        stalled on eviction write-backs, list of performed copies as
        ``(src, dst, nbytes)`` — the executor's topology replay re-prices
        these under per-link contention).

        Demand mode (default): inputs stay hard-pinned at ``pe`` until
        :meth:`_unpin_inputs` — callers release after commit.  Only one
        PE worker reserves per arena, so pinned bytes are bounded by one
        task's working set.

        Prefetch mode: *speculative warming* — runs under the context's
        prefetch guard (raises :class:`~repro.core.hete.PrefetchDeferred`
        instead of evicting pinned/protected bytes) and takes NO pins, so
        concurrent prefetches can never starve the demand path.  The PE
        worker re-stages authoritatively before executing: a free flag
        hit when the warmed bytes survived, a re-fetch if pressure
        evicted them in between."""
        ctx, loc = self.context, pe.location
        ins: List[Any] = []
        model_s = 0.0
        ctx.take_spill_seconds()  # clear this thread's residue
        ctx.take_moves()  # arm + clear this thread's move log
        moves: List[tuple] = []
        if not prefetch:
            self._pin_inputs(task, loc)
        try:
            if self.policy == "reference":
                # Host-owned: host is current (producer wrote host under
                # this policy); copy host→PE unconditionally.
                for hd in task.inputs:
                    with hd.lock:
                        host_val = hd.copies[HOST]
                        if loc != HOST:
                            moved = ctx.spaces[loc].ingest(host_val)
                            model_s += ctx.record_copy(HOST, loc, hd.nbytes)
                            moves.append((HOST, loc, hd.nbytes))
                            ins.append(moved)
                        else:
                            ins.append(host_val)
            else:  # rimms: flag check + direct src→PE copy when needed
                guard = (ctx.prefetch_guard() if prefetch
                         else contextlib.nullcontext())
                with guard:
                    for hd in task.inputs:
                        value, tr_s = ctx.stage(hd, loc)
                        ins.append(value)
                        model_s += tr_s
                moves = ctx.take_moves()
        except BaseException:
            if not prefetch:
                self._unpin_inputs(task, loc)
            raise
        return ins, model_s, ctx.take_spill_seconds(), moves

    def _proc_eligible(self, pe: PE) -> bool:
        """Whether ``pe``'s kernels may execute in a subprocess worker:
        its memory space must hold host-format payloads (see
        :attr:`~repro.core.hete.MemorySpace.proc_exec`) — PEs bound to a
        real JAX device keep in-process async dispatch."""
        space = self.context.spaces.get(pe.location)
        return space is not None and getattr(space, "proc_exec", False)

    def _run_kernel(self, task: Task, pe: PE, ins: List[Any]) -> Tuple[tuple, float]:
        """Execute the kernel; returns (outputs, measured seconds).  Blocks
        async (JAX) dispatch so timings feed the cost model honestly.

        Backend dispatch (ISSUE 7): under ``backend="process"`` the call
        runs on ``pe``'s subprocess worker — shared-memory inputs map
        zero-copy, the parent thread blocks GIL-free on the reply — for
        every PE whose space holds host payloads; other PEs (real JAX
        devices) execute in-process as before."""
        if self.backend == "process" and self._proc_eligible(pe):
            outs, dt = self._run_kernel_process(task, pe, ins)
        else:
            fn, params, _ = self._select_kernel(task, pe)
            t0 = time.perf_counter()
            outs = _as_tuple(fn(ins, **params))
            if pe.location != HOST:
                try:
                    import jax
                    outs = tuple(jax.block_until_ready(o) for o in outs)
                except ImportError:  # pragma: no cover - jax is baked in
                    pass
            dt = time.perf_counter() - t0
            self.cost_model.observe(task.op, pe.kind, task.in_bytes, dt)
        self.divergence.observe(
            "compute", task.op, pe.kind, task.in_bytes, dt,
            self.cost_model.prior_estimate(task.op, pe.kind, task.in_bytes))
        return outs, dt

    def _select_kernel(self, task: Task, pe: PE) -> Tuple[Callable, dict, str]:
        """Variant-aware kernel lookup (ISSUE 10): the attached
        calibration table's winning variant for the task's shape bucket
        when it is registered (bit-identical to the default by the
        autotuner's eligibility bar), else the default kernel.  Returns
        ``(fn, merged params, variant name)`` — per-task params override
        the variant's bound launch params.  Non-default selections are
        appended to :attr:`variant_log`."""
        if self.calibration is not None:
            vname = self.calibration.best_variant(task.op, pe.kind,
                                                  task.in_bytes)
            if vname is not None:
                entry = self._variant_kernels.get((task.op, pe.kind, vname))
                if entry is not None:
                    fn, vparams = entry
                    self.variant_log.append((task.op, pe.kind, vname))
                    return fn, {**vparams, **task.params}, vname
        return self._kernels[(task.op, pe.kind)], dict(task.params), "default"

    def _run_kernel_process(self, task: Task, pe: PE,
                            ins: List[Any]) -> Tuple[tuple, float]:
        """Process-backend kernel call: ship handles to ``pe``'s worker,
        forward the worker-measured compute span onto the trace (on the
        ``pe:{name}:worker`` track, clock-offset corrected and clamped to
        the parent-observed call window)."""
        fn, params, vname = self._select_kernel(task, pe)
        key = (task.op, pe.kind, vname)
        worker = self._get_process_pool().worker(pe.name)
        worker.ensure_kernel(key, fn)
        outs, w0, w1, k0, k1 = worker.run(key, ins, params)
        dt = w1 - w0
        self.cost_model.observe(task.op, pe.kind, task.in_bytes, dt)
        tracer = self.context.tracer
        if tracer is not None:
            tracer.forward_span(
                task.name or task.op, "compute", f"pe:{pe.name}:worker",
                k0, k1, lo=w0, hi=w1,
                args={"op": task.op, "backend": "process",
                      "worker_pid": worker.pid},
            )
        return outs, dt

    def _commit_outputs(self, task: Task, pe: PE, outs: tuple) -> Tuple[float, float]:
        """Flag updates (+ host writeback under reference). Returns
        (modeled output-transfer seconds, modeled eviction-stall seconds
        the output reservations caused)."""
        ctx, loc = self.context, pe.location
        model_s = 0.0
        ctx.take_spill_seconds()  # clear this thread's residue
        if self.policy == "reference":
            for hd, val in zip(task.outputs, outs):
                if loc != HOST:
                    host_val = ctx.spaces[loc].egress(val)
                    model_s += ctx.record_copy(loc, HOST, hd.nbytes)
                else:
                    host_val = np.asarray(val)
                ctx.mark_written(hd, HOST, host_val.reshape(hd.shape))
        else:
            for hd, val in zip(task.outputs, outs):
                ctx.mark_written(hd, loc, val)
        return model_s, ctx.take_spill_seconds()

    def _add_transfer_lanes(self, topo, task: Task, moves: Sequence[tuple],
                            start: float, node: int = -1) -> float:
        """Record per-link :class:`TransferEvent` lanes for ``moves``
        issued *concurrently* at modeled time ``start``, walking each
        copy's route through per-link busy-until contention (ISSUE 4
        satellite): copies on disjoint routes overlap, copies sharing a
        link queue behind each other — and behind earlier tasks' traffic,
        since link state persists across the run.  This is exactly the
        pricing the graph executor's replay applies, so serial vs graph
        topology comparisons are apples-to-apples (previously serial
        summed uncontended store-and-forward hop times).  Returns the
        modeled staging duration (last byte delivered − ``start``)."""
        from .instrument import TransferEvent

        end_max = start
        for src, dst, nbytes in moves:
            _, end, hops = topo.transfer(src, dst, nbytes, at=start,
                                         commit=True)
            for link, hs, he in hops:
                self.timeline.add_transfer(TransferEvent(
                    link=link.label, task=task.name or task.op,
                    nbytes=nbytes, model_start=hs, model_end=he,
                    node=node,
                ))
            end_max = max(end_max, end)
        return end_max - start

    # -- execution --------------------------------------------------------------
    def run(self, tasks: Sequence[Task]) -> float:
        """Execute tasks serially in submission order (data deps are
        submission-ordered by the apps, matching CEDR's API-level
        serialization).  Returns wall seconds; fills :attr:`timeline` and
        :attr:`last_makespan_model` for comparison against graph mode.

        .. deprecated:: ISSUE 7
           Compat wrapper — prefer the streaming session API
           (:class:`repro.core.api.Session`).  Emits one
           :class:`DeprecationWarning` per process; internal callers
           (the session, benchmarks' serial baselines) use
           :meth:`_run_impl` directly, so the warning always points at
           user code."""
        _warn_deprecated("run")
        return self._run_impl(tasks)

    def _run_impl(self, tasks: Sequence[Task]) -> float:
        """Serial dispatch body — the reference every equivalence/
        copy-count claim compares against (no deprecation warning)."""
        self.reset_stats()
        topo = getattr(self.context.ledger.bandwidth_model, "topology", None)
        if topo is not None:
            topo.reset_contention()
        tracer = self.context.tracer
        model_t = 0.0
        t0 = time.perf_counter()
        for node_i, task in enumerate(tasks):
            pe = self._schedule(task)
            w0 = time.perf_counter()
            ins, tr_s, sp_s, moves = self._stage_inputs(task, pe)
            w_staged = time.perf_counter()
            try:
                outs, comp_s = self._run_kernel(task, pe, ins)
                w_comp = time.perf_counter()
                out_s, sp2_s = self._commit_outputs(task, pe, outs)
            finally:
                self._unpin_inputs(task, pe.location)
            w1 = time.perf_counter()
            self.divergence.observe(
                "stage", task.op, pe.kind, task.in_bytes,
                w_staged - w0, tr_s + sp_s)
            if tracer is not None:
                tname = task.name or task.op
                targs = {"task": tname, "op": task.op, "node": node_i}
                tracer.span(tname, "stage", f"pe:{pe.name}:stage",
                            w0, w_staged, targs)
                tracer.span(tname, "compute", f"pe:{pe.name}",
                            w_staged, w_comp, targs)
                tracer.span(tname, "writeback", f"pe:{pe.name}",
                            w_comp, w1, targs)
            spill_s = sp_s + sp2_s
            stage_m = tr_s
            if topo is not None:
                # Routed transfer lanes over modeled time: this task's
                # copies issue concurrently at model_t and queue on
                # shared links (per-link contention, like graph replay).
                stage_m = self._add_transfer_lanes(topo, task, moves,
                                                   model_t, node=node_i)
            # Model simulation uses the static compute estimate so serial
            # and graph modeled makespans are directly comparable (see
            # CostModel.prior_estimate).  Spill stalls (eviction
            # write-backs under capacity pressure) extend the task's
            # modeled interval exactly like transfers do.
            comp_m = self.cost_model.prior_estimate(task.op, pe.kind, task.in_bytes)
            dur_m = stage_m + spill_s + comp_m + out_s
            self.timeline.add(TimelineEvent(
                task=task.name or task.op, pe=pe.name,
                wall_start=w0 - t0, wall_end=w1 - t0,
                model_start=model_t, model_end=model_t + dur_m,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
                spill_s=spill_s,
                compute_start_m=model_t + stage_m + spill_s, node=node_i,
            ))
            model_t += dur_m
            self.task_log.append((task.name or task.op, pe.name))
        self.last_makespan_model = model_t
        if tracer is not None:
            tracer.add_timeline(self.timeline, label="serial")
        return time.perf_counter() - t0

    def run_graph(
        self,
        tasks: Sequence[Task],
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
    ) -> float:
        """Execute ``tasks`` on the async task-graph executor: automatic
        RAW/WAR/WAW DAG, one worker per PE, input prefetch overlapping
        transfers with compute, and transfer-aware placement when
        ``scheduler='heft'``.  Same ledger and memory policies as
        :meth:`run`; under the ``rimms`` policy with static scheduling the
        copy counts and outputs are identical to serial execution.

        Returns wall seconds; :attr:`timeline`, :attr:`last_makespan_model`
        and :attr:`last_report` carry the schedule evidence.

        .. deprecated:: ISSUE 7
           Compat wrapper — prefer the streaming session API
           (:class:`repro.core.api.Session`), which drives the same
           worker pool continuously.  Emits one
           :class:`DeprecationWarning` per process; internal callers use
           :meth:`_run_graph_impl`.
        """
        _warn_deprecated("run_graph")
        return self._run_graph_impl(tasks, scheduler=scheduler,
                                    prefetch=prefetch)

    def _run_graph_impl(
        self,
        tasks: Sequence[Task],
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
    ) -> float:
        """Batch graph-executor body (no deprecation warning)."""
        from .executor import GraphExecutor  # local import: avoids cycle

        self.reset_stats()
        ex = GraphExecutor(self, scheduler=scheduler, prefetch=prefetch)
        report = ex.run(tasks)
        self.last_report = report
        return report["wall_s"]


def _as_tuple(x: Any) -> tuple:
    return x if isinstance(x, tuple) else (x,)


# One DeprecationWarning per process (ISSUE 7 satellite): the first
# Runtime.run / run_graph call warns, later ones stay quiet so batch
# loops don't flood stderr.
_deprecation_warned = False


def _warn_deprecated(which: str) -> None:
    global _deprecation_warned
    if _deprecation_warned:
        return
    _deprecation_warned = True
    warnings.warn(
        f"Runtime.{which}() is a compat wrapper and is deprecated; use the "
        f"streaming session API instead (repro.core.api.Session / "
        f"Session.emulated — see the README migration table).",
        DeprecationWarning,
        stacklevel=3,
    )


# ---------------------------------------------------------------------------
# Emulated heterogeneous SoC (§4.1 analogue) — built on the single CPU
# device: accelerator memory spaces hold jax.Arrays, host space numpy.
# ---------------------------------------------------------------------------


def make_emulated_soc(
    *,
    n_cpu: int = 1,
    accelerators: Sequence[str] = ("fft_acc0", "zip_acc0"),
    acc_ops: Optional[Dict[str, Sequence[str]]] = None,
    arena_bytes=64 << 20,  # 64 MiB UDMA buffer, as on the ZCU102
    allocator: str = "nextfit",
    block_size: int = 4096,
    context: Optional[HeteContext] = None,
    tracking: str = "flag",
    topology=None,
    backend: Optional[str] = None,
    host_arena_bytes: Optional[int] = None,
) -> tuple:
    """Build (runtime-ready PEs, HeteContext) for an emulated SoC.

    ``acc_ops`` maps accelerator name → ops it supports; defaults derive
    from the name prefix ("fft_acc*" → fft/ifft, "zip_acc*" → zip,
    "gpu*" → everything).

    ``arena_bytes`` is one capacity for every accelerator, or a dict
    ``{accelerator name: bytes}`` for asymmetric arenas (spill-to-peer
    scenarios need a roomy neighbour).

    ``topology`` opts into routed, contention-aware transfer modeling
    (ISSUE 3): a platform name from :func:`platform_names` (built-ins
    "emulated_soc", "pcie_tree", "nvlink_mesh", "host_bridged_fpga", plus
    anything the embedding app added via :func:`register_platform`), a
    :class:`~repro.core.topology.Topology`, or a ready
    :class:`~repro.core.topology.TopologyBandwidthModel`.  It replaces
    the context ledger's scalar bandwidth model; ``None`` (the default)
    keeps the scalar model, so existing baselines hold.

    ``backend`` (ISSUE 7): ``"thread"`` (default) keeps in-process
    kernels over per-device jax payloads.  ``"process"`` builds the SoC
    for subprocess PE workers: host buffers come from a
    :class:`~repro.core.shm.SharedHostArena` (``host_arena_bytes``
    capacity) that workers map zero-copy, and emulated accelerator
    spaces hold host-format numpy payloads (their arenas — capacity,
    eviction, the whole ledger — stay modeled exactly as before).  When
    ``jax.devices()`` exposes more than one real device, accelerators
    are spread round-robin across them and keep in-process async
    dispatch (real device parallelism beats a worker pipe).
    """
    import jax

    backend = resolve_backend(backend)
    ctx = context or HeteContext(tracking=tracking)
    devices = jax.devices()
    multi_device = len(devices) > 1
    if backend == "process" and ctx.host_arena is None:
        from .shm import SharedHostArena, default_arena_bytes

        ctx.attach_host_arena(SharedHostArena(
            host_arena_bytes or default_arena_bytes()))

    def _egress(value) -> np.ndarray:
        return np.asarray(value)

    pes: List[PE] = []
    for i in range(n_cpu):
        pes.append(
            PE(f"cpu{i}", "cpu", HOST, frozenset({"fft", "ifft", "zip", "generic"}))
        )

    default_ops = {"fft_acc": ("fft", "ifft"), "zip_acc": ("zip",),
                   "gpu": ("fft", "ifft", "zip", "generic")}
    dev_locs: List[Location] = []
    for idx, name in enumerate(accelerators):
        kind = next((k for k in default_ops if name.startswith(k)), "acc")
        ops = tuple((acc_ops or {}).get(name, default_ops.get(kind, ())))
        loc = Location("device", name)
        dev_locs.append(loc)
        capacity = (
            arena_bytes.get(name, 64 << 20)
            if isinstance(arena_bytes, dict) else arena_bytes
        )
        if backend == "process" and not multi_device:
            # Subprocess workers execute this PE's kernels: device copies
            # are host-format (distinct shared-memory buffers — the
            # host→device copy is real, the arena stays modeled).
            ingest = ctx.host_copy
            proc_exec = True
        else:
            device = devices[idx % len(devices)]
            ingest = (lambda v, _d=device: jax.device_put(v, _d))
            proc_exec = False
        ctx.register_space(
            MemorySpace(
                loc,
                capacity=capacity,
                allocator=allocator,
                block_size=block_size,
                ingest=ingest,
                egress=_egress,
                proc_exec=proc_exec,
            )
        )
        pes.append(PE(name, "gpu" if kind == "gpu" else "acc", loc, frozenset(ops)))

    if topology is not None:
        from .topology import Topology, TopologyBandwidthModel, build_preset

        if isinstance(topology, str):
            entry = _resolve_platform(topology)
            if entry is not None and entry[0] is not None:
                topology = entry[0](dev_locs)
            else:
                topology = build_preset(topology, dev_locs)
        if isinstance(topology, Topology):
            topology = TopologyBandwidthModel(topology)
        ctx.ledger.bandwidth_model = topology
    return pes, ctx
