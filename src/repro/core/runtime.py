"""Task runtime — the CEDR analogue RIMMS integrates with (§2, §3.2.2).

A small dynamic task runtime: applications submit *API calls* (tasks) over
:class:`~repro.core.hete.HeteData` buffers; a scheduler maps each task to a
processing element (PE) at dispatch time (round-robin, pinned,
data-affinity, or transfer-aware HEFT-lite); the memory policy decides
what data movement happens.

Two memory policies, both first-class so every experiment reports the pair:

* ``"reference"`` — the paper's baseline (host-owned data): every input is
  copied host→PE before execution and every output PE→host after, so the
  host always holds the valid copy (Fig 1a).
* ``"rimms"``     — the paper's contribution: per-input last-resource-flag
  check, direct src→PE copy only when the flag names another location,
  output flag update to the executing PE (Fig 1b).

The **primary public entry point is the streaming session API**
(:mod:`repro.core.api`, ISSUE 4): ``@rimms.op``-registered kernels,
``Session.malloc``/``Session.submit`` returning
:class:`~repro.core.api.BufferFuture` handles, and the persistent
:class:`~repro.core.executor.StreamExecutor` consuming the task stream
continuously.  This class is the **dispatch engine behind it** — the
session drives the same stage → execute → commit pipeline, scheduler
cost bases, and kernel registry defined here.

Two batch execution modes are kept as thin compat wrappers over that
pipeline:

* :meth:`Runtime.run` — serial, submission order (CEDR's API-level
  serialization);
* :meth:`Runtime.run_graph` — the batch task-graph executor
  (:class:`~repro.core.executor.GraphExecutor`): automatic DAG
  construction, one worker per PE, input prefetch overlapping transfers
  with compute.

PEs are emulated on this CPU-only box: a "cpu" PE executes numpy
callables against host memory; accelerator PEs ("fft_acc", "zip_acc",
"gpu") execute jitted JAX callables against their own
:class:`~repro.core.hete.MemorySpace`. Transfers between spaces are real
array movements and are recorded in the ledger (count, bytes, modeled
seconds under platform bandwidths).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import CostModel
from .hete import HeteContext, HeteData, MemorySpace
from .instrument import Timeline, TimelineEvent
from .locations import HOST, Location

__all__ = ["PE", "Task", "Runtime", "make_emulated_soc", "SCHEDULERS"]

SCHEDULERS = ("round_robin", "data_affinity", "heft")


@dataclasses.dataclass
class PE:
    """A processing element: name, kind, its memory location, supported ops."""

    name: str
    kind: str  # "cpu" | "acc" | "gpu" | ...
    location: Location
    supports: frozenset

    def __post_init__(self) -> None:
        self.supports = frozenset(self.supports)


@dataclasses.dataclass
class Task:
    """One API call: op over HeteData inputs/outputs (+ scalar params)."""

    op: str
    inputs: List[HeteData]
    outputs: List[HeteData]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    pin: Optional[str] = None  # pin to a PE name (CPU-ACC style scenarios)
    name: str = ""
    # submitting session client (ISSUE 5): per-tenant accounting +
    # cross-client interference-aware placement key on it
    client: Optional[str] = None

    @property
    def in_bytes(self) -> int:
        return sum(hd.nbytes for hd in self.inputs)

    @property
    def out_bytes(self) -> int:
        return sum(hd.nbytes for hd in self.outputs)


class Runtime:
    """Dispatch loop: schedule → move (policy) → execute → flag update."""

    def __init__(
        self,
        pes: Sequence[PE],
        context: HeteContext,
        *,
        policy: str = "rimms",
        scheduler: str = "round_robin",
        cost_model: Optional[CostModel] = None,
    ) -> None:
        if policy not in ("rimms", "reference"):
            raise ValueError(f"unknown memory policy {policy!r}")
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.pes = list(pes)
        self.by_name = {pe.name: pe for pe in self.pes}
        self.context = context
        self.policy = policy
        self.scheduler = scheduler
        self.cost_model = cost_model or CostModel()
        self._rr_state: Dict[str, int] = {}
        # kernels: (op, pe_kind) -> callable(list_of_arrays, **params) -> tuple
        self._kernels: Dict[tuple, Callable] = {}
        self.task_log: List[tuple] = []  # (task name/op, pe name) for tests
        self.timeline = Timeline()  # replaced per run/run_graph
        self.last_makespan_model = 0.0
        self.last_report: Optional[Dict[str, Any]] = None  # set by run_graph
        # persistent per-PE worker pool, created lazily by run_graph and
        # reused across calls (ISSUE 2); close() releases it
        self._worker_pool = None

    def _get_worker_pool(self):
        from .executor import WorkerPool  # local import: avoids cycle

        if self._worker_pool is None:
            pool = WorkerPool(self.pes)
            self._worker_pool = pool
            # release the pool's threads when this Runtime is collected
            self._pool_finalizer = weakref.finalize(
                self, WorkerPool.shutdown, pool
            )
        return self._worker_pool

    def close(self) -> None:
        """Release the persistent worker pool (idempotent)."""
        if self._worker_pool is not None:
            self._pool_finalizer.detach()
            self._worker_pool.shutdown()
            self._worker_pool = None

    def reset_stats(self) -> None:
        """Clear per-run diagnostics and dispatch state: the task log,
        round-robin rotation, timeline, and last modeled makespan/report.
        Called at the start of every :meth:`run`/:meth:`run_graph`, so
        repeated batch runs neither accumulate log entries nor leak
        round-robin placement state across runs (ISSUE 4 satellite) —
        ``task_log`` after a run is exactly that run's placements, and
        identical task lists place identically on every run.  Streaming
        sessions deliberately do *not* reset between barriers: the
        stream is one continuous run."""
        self.task_log = []
        self._rr_state = {}
        self.timeline = Timeline()
        self.last_makespan_model = 0.0
        self.last_report = None

    # -- registration -------------------------------------------------------
    def register_kernel(self, op: str, pe_kind: str, fn: Callable) -> None:
        self._kernels[(op, pe_kind)] = fn

    # -- scheduling -----------------------------------------------------------
    def _eligible(self, task: Task) -> List[PE]:
        pes = [
            pe
            for pe in self.pes
            if task.op in pe.supports and (task.op, pe.kind) in self._kernels
        ]
        if not pes:
            raise LookupError(f"no PE supports op {task.op!r}")
        return pes

    def _schedule(self, task: Task) -> PE:
        if task.pin is not None:
            return self.by_name[task.pin]
        pes = self._eligible(task)
        if self.scheduler == "round_robin":
            i = self._rr_state.get(task.op, 0)
            self._rr_state[task.op] = (i + 1) % len(pes)
            return pes[i % len(pes)]
        if self.scheduler == "heft":
            # Transfer-aware greedy pick: minimize modeled staging cost +
            # estimated compute (per-PE availability is the executor's
            # refinement; serial dispatch has no queues to account for).
            return min(pes, key=lambda pe: (sum(self._heft_costs(task, pe)),
                                            pe.name))
        # data_affinity (beyond-paper)
        return self._affinity_pick(task, pes)

    def _affinity_pick(self, task: Task, pes: Sequence[PE]) -> PE:
        """Most input bytes already valid at the PE; ties broken by stable
        PE-name ordering (deterministic).  Shared by serial dispatch and
        the graph executor."""
        def score(pe: PE) -> int:
            return sum(
                hd.nbytes for hd in task.inputs if hd.last_location == pe.location
            )
        return min(pes, key=lambda pe: (-score(pe), pe.name))

    def _heft_costs(self, task: Task, pe: PE) -> Tuple[float, float]:
        """(modeled input-transfer seconds, estimated compute seconds) for
        placing ``task`` on ``pe`` — the shared EFT cost basis for serial
        heft dispatch and the graph executor's placement."""
        bw = self.context.ledger.bandwidth_model
        tr = sum(
            bw.seconds(hd.last_location, pe.location, hd.nbytes)
            for hd in task.inputs
            if hd.last_location != pe.location
        )
        return tr, self.cost_model.estimate(task.op, pe.kind, task.in_bytes)

    # -- stage → execute → commit (shared by serial and graph modes) ---------
    def _pin_inputs(self, task: Task, loc: Location) -> None:
        """Hard-pin every input's root at ``loc`` so eviction triggered by
        a concurrent (or this task's own output) reservation can never
        spill bytes the kernel is about to read.  Balanced by
        :meth:`_unpin_inputs` after commit."""
        for hd in task.inputs:
            self.context.pin(hd, loc)

    def _unpin_inputs(self, task: Task, loc: Location) -> None:
        for hd in task.inputs:
            self.context.unpin(hd, loc)

    def _stage_inputs(
        self, task: Task, pe: PE, *, prefetch: bool = False
    ) -> Tuple[List[Any], float, float, List[tuple]]:
        """Materialize ``task``'s inputs at ``pe`` under the memory policy.
        Returns (input values, modeled transfer seconds, modeled seconds
        stalled on eviction write-backs, list of performed copies as
        ``(src, dst, nbytes)`` — the executor's topology replay re-prices
        these under per-link contention).

        Demand mode (default): inputs stay hard-pinned at ``pe`` until
        :meth:`_unpin_inputs` — callers release after commit.  Only one
        PE worker reserves per arena, so pinned bytes are bounded by one
        task's working set.

        Prefetch mode: *speculative warming* — runs under the context's
        prefetch guard (raises :class:`~repro.core.hete.PrefetchDeferred`
        instead of evicting pinned/protected bytes) and takes NO pins, so
        concurrent prefetches can never starve the demand path.  The PE
        worker re-stages authoritatively before executing: a free flag
        hit when the warmed bytes survived, a re-fetch if pressure
        evicted them in between."""
        ctx, loc = self.context, pe.location
        ins: List[Any] = []
        model_s = 0.0
        ctx.take_spill_seconds()  # clear this thread's residue
        ctx.take_moves()  # arm + clear this thread's move log
        moves: List[tuple] = []
        if not prefetch:
            self._pin_inputs(task, loc)
        try:
            if self.policy == "reference":
                # Host-owned: host is current (producer wrote host under
                # this policy); copy host→PE unconditionally.
                for hd in task.inputs:
                    with hd.lock:
                        host_val = hd.copies[HOST]
                        if loc != HOST:
                            moved = ctx.spaces[loc].ingest(host_val)
                            model_s += ctx.record_copy(HOST, loc, hd.nbytes)
                            moves.append((HOST, loc, hd.nbytes))
                            ins.append(moved)
                        else:
                            ins.append(host_val)
            else:  # rimms: flag check + direct src→PE copy when needed
                guard = (ctx.prefetch_guard() if prefetch
                         else contextlib.nullcontext())
                with guard:
                    for hd in task.inputs:
                        value, tr_s = ctx.stage(hd, loc)
                        ins.append(value)
                        model_s += tr_s
                moves = ctx.take_moves()
        except BaseException:
            if not prefetch:
                self._unpin_inputs(task, loc)
            raise
        return ins, model_s, ctx.take_spill_seconds(), moves

    def _run_kernel(self, task: Task, pe: PE, ins: List[Any]) -> Tuple[tuple, float]:
        """Execute the kernel; returns (outputs, measured seconds).  Blocks
        async (JAX) dispatch so timings feed the cost model honestly."""
        fn = self._kernels[(task.op, pe.kind)]
        t0 = time.perf_counter()
        outs = _as_tuple(fn(ins, **task.params))
        if pe.location != HOST:
            try:
                import jax
                outs = tuple(jax.block_until_ready(o) for o in outs)
            except ImportError:  # pragma: no cover - jax is baked in
                pass
        dt = time.perf_counter() - t0
        self.cost_model.observe(task.op, pe.kind, task.in_bytes, dt)
        return outs, dt

    def _commit_outputs(self, task: Task, pe: PE, outs: tuple) -> Tuple[float, float]:
        """Flag updates (+ host writeback under reference). Returns
        (modeled output-transfer seconds, modeled eviction-stall seconds
        the output reservations caused)."""
        ctx, loc = self.context, pe.location
        model_s = 0.0
        ctx.take_spill_seconds()  # clear this thread's residue
        if self.policy == "reference":
            for hd, val in zip(task.outputs, outs):
                if loc != HOST:
                    host_val = ctx.spaces[loc].egress(val)
                    model_s += ctx.record_copy(loc, HOST, hd.nbytes)
                else:
                    host_val = np.asarray(val)
                ctx.mark_written(hd, HOST, host_val.reshape(hd.shape))
        else:
            for hd, val in zip(task.outputs, outs):
                ctx.mark_written(hd, loc, val)
        return model_s, ctx.take_spill_seconds()

    def _add_transfer_lanes(self, topo, task: Task, moves: Sequence[tuple],
                            start: float, node: int = -1) -> float:
        """Record per-link :class:`TransferEvent` lanes for ``moves``
        issued *concurrently* at modeled time ``start``, walking each
        copy's route through per-link busy-until contention (ISSUE 4
        satellite): copies on disjoint routes overlap, copies sharing a
        link queue behind each other — and behind earlier tasks' traffic,
        since link state persists across the run.  This is exactly the
        pricing the graph executor's replay applies, so serial vs graph
        topology comparisons are apples-to-apples (previously serial
        summed uncontended store-and-forward hop times).  Returns the
        modeled staging duration (last byte delivered − ``start``)."""
        from .instrument import TransferEvent

        end_max = start
        for src, dst, nbytes in moves:
            _, end, hops = topo.transfer(src, dst, nbytes, at=start,
                                         commit=True)
            for link, hs, he in hops:
                self.timeline.add_transfer(TransferEvent(
                    link=link.label, task=task.name or task.op,
                    nbytes=nbytes, model_start=hs, model_end=he,
                    node=node,
                ))
            end_max = max(end_max, end)
        return end_max - start

    # -- execution --------------------------------------------------------------
    def run(self, tasks: Sequence[Task]) -> float:
        """Execute tasks serially in submission order (data deps are
        submission-ordered by the apps, matching CEDR's API-level
        serialization).  Returns wall seconds; fills :attr:`timeline` and
        :attr:`last_makespan_model` for comparison against graph mode.

        Compat wrapper: new code should prefer the streaming session API
        (:class:`repro.core.api.Session`); this remains the reference
        serial dispatch every equivalence/copy-count claim compares
        against."""
        self.reset_stats()
        topo = getattr(self.context.ledger.bandwidth_model, "topology", None)
        if topo is not None:
            topo.reset_contention()
        tracer = self.context.tracer
        model_t = 0.0
        t0 = time.perf_counter()
        for node_i, task in enumerate(tasks):
            pe = self._schedule(task)
            w0 = time.perf_counter()
            ins, tr_s, sp_s, moves = self._stage_inputs(task, pe)
            w_staged = time.perf_counter() if tracer is not None else w0
            try:
                outs, comp_s = self._run_kernel(task, pe, ins)
                w_comp = time.perf_counter() if tracer is not None else w_staged
                out_s, sp2_s = self._commit_outputs(task, pe, outs)
            finally:
                self._unpin_inputs(task, pe.location)
            w1 = time.perf_counter()
            if tracer is not None:
                tname = task.name or task.op
                targs = {"task": tname, "op": task.op, "node": node_i}
                tracer.span(tname, "stage", f"pe:{pe.name}:stage",
                            w0, w_staged, targs)
                tracer.span(tname, "compute", f"pe:{pe.name}",
                            w_staged, w_comp, targs)
                tracer.span(tname, "writeback", f"pe:{pe.name}",
                            w_comp, w1, targs)
            spill_s = sp_s + sp2_s
            stage_m = tr_s
            if topo is not None:
                # Routed transfer lanes over modeled time: this task's
                # copies issue concurrently at model_t and queue on
                # shared links (per-link contention, like graph replay).
                stage_m = self._add_transfer_lanes(topo, task, moves,
                                                   model_t, node=node_i)
            # Model simulation uses the static compute estimate so serial
            # and graph modeled makespans are directly comparable (see
            # CostModel.prior_estimate).  Spill stalls (eviction
            # write-backs under capacity pressure) extend the task's
            # modeled interval exactly like transfers do.
            comp_m = self.cost_model.prior_estimate(task.op, pe.kind, task.in_bytes)
            dur_m = stage_m + spill_s + comp_m + out_s
            self.timeline.add(TimelineEvent(
                task=task.name or task.op, pe=pe.name,
                wall_start=w0 - t0, wall_end=w1 - t0,
                model_start=model_t, model_end=model_t + dur_m,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
                spill_s=spill_s,
                compute_start_m=model_t + stage_m + spill_s, node=node_i,
            ))
            model_t += dur_m
            self.task_log.append((task.name or task.op, pe.name))
        self.last_makespan_model = model_t
        if tracer is not None:
            tracer.add_timeline(self.timeline, label="serial")
        return time.perf_counter() - t0

    def run_graph(
        self,
        tasks: Sequence[Task],
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
    ) -> float:
        """Execute ``tasks`` on the async task-graph executor: automatic
        RAW/WAR/WAW DAG, one worker per PE, input prefetch overlapping
        transfers with compute, and transfer-aware placement when
        ``scheduler='heft'``.  Same ledger and memory policies as
        :meth:`run`; under the ``rimms`` policy with static scheduling the
        copy counts and outputs are identical to serial execution.

        Returns wall seconds; :attr:`timeline`, :attr:`last_makespan_model`
        and :attr:`last_report` carry the schedule evidence.

        Compat wrapper: batch intake over the same worker pool the
        streaming session API (:class:`repro.core.api.Session`) drives
        continuously — prefer the session for new code.
        """
        from .executor import GraphExecutor  # local import: avoids cycle

        self.reset_stats()
        ex = GraphExecutor(self, scheduler=scheduler, prefetch=prefetch)
        report = ex.run(tasks)
        self.last_report = report
        return report["wall_s"]


def _as_tuple(x: Any) -> tuple:
    return x if isinstance(x, tuple) else (x,)


# ---------------------------------------------------------------------------
# Emulated heterogeneous SoC (§4.1 analogue) — built on the single CPU
# device: accelerator memory spaces hold jax.Arrays, host space numpy.
# ---------------------------------------------------------------------------


def make_emulated_soc(
    *,
    n_cpu: int = 1,
    accelerators: Sequence[str] = ("fft_acc0", "zip_acc0"),
    acc_ops: Optional[Dict[str, Sequence[str]]] = None,
    arena_bytes=64 << 20,  # 64 MiB UDMA buffer, as on the ZCU102
    allocator: str = "nextfit",
    block_size: int = 4096,
    context: Optional[HeteContext] = None,
    tracking: str = "flag",
    topology=None,
) -> tuple:
    """Build (runtime-ready PEs, HeteContext) for an emulated SoC.

    ``acc_ops`` maps accelerator name → ops it supports; defaults derive
    from the name prefix ("fft_acc*" → fft/ifft, "zip_acc*" → zip,
    "gpu*" → everything).

    ``arena_bytes`` is one capacity for every accelerator, or a dict
    ``{accelerator name: bytes}`` for asymmetric arenas (spill-to-peer
    scenarios need a roomy neighbour).

    ``topology`` opts into routed, contention-aware transfer modeling
    (ISSUE 3): a preset name from :data:`repro.core.topology.PRESETS`
    ("emulated_soc", "pcie_tree", "nvlink_mesh", "host_bridged_fpga"), a
    :class:`~repro.core.topology.Topology`, or a ready
    :class:`~repro.core.topology.TopologyBandwidthModel`.  It replaces
    the context ledger's scalar bandwidth model; ``None`` (the default)
    keeps the scalar model, so existing baselines hold.
    """
    import jax

    ctx = context or HeteContext(tracking=tracking)
    device = jax.devices()[0]

    def _ingest(host_value: np.ndarray):
        return jax.device_put(host_value, device)

    def _egress(value) -> np.ndarray:
        return np.asarray(value)

    pes: List[PE] = []
    for i in range(n_cpu):
        pes.append(
            PE(f"cpu{i}", "cpu", HOST, frozenset({"fft", "ifft", "zip", "generic"}))
        )

    default_ops = {"fft_acc": ("fft", "ifft"), "zip_acc": ("zip",),
                   "gpu": ("fft", "ifft", "zip", "generic")}
    dev_locs: List[Location] = []
    for name in accelerators:
        kind = next((k for k in default_ops if name.startswith(k)), "acc")
        ops = tuple((acc_ops or {}).get(name, default_ops.get(kind, ())))
        loc = Location("device", name)
        dev_locs.append(loc)
        capacity = (
            arena_bytes.get(name, 64 << 20)
            if isinstance(arena_bytes, dict) else arena_bytes
        )
        ctx.register_space(
            MemorySpace(
                loc,
                capacity=capacity,
                allocator=allocator,
                block_size=block_size,
                ingest=_ingest,
                egress=_egress,
            )
        )
        pes.append(PE(name, "gpu" if kind == "gpu" else "acc", loc, frozenset(ops)))

    if topology is not None:
        from .topology import Topology, TopologyBandwidthModel, build_preset

        if isinstance(topology, str):
            topology = build_preset(topology, dev_locs)
        if isinstance(topology, Topology):
            topology = TopologyBandwidthModel(topology)
        ctx.ledger.bandwidth_model = topology
    return pes, ctx
