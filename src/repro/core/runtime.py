"""Task runtime — the CEDR analogue RIMMS integrates with (§2, §3.2.2).

A small dynamic task runtime: applications submit *API calls* (tasks) over
:class:`~repro.core.hete.HeteData` buffers; a scheduler maps each task to a
processing element (PE) at dispatch time (round-robin, pinned, or
data-affinity); the memory policy decides what data movement happens.

Two memory policies, both first-class so every experiment reports the pair:

* ``"reference"`` — the paper's baseline (host-owned data): every input is
  copied host→PE before execution and every output PE→host after, so the
  host always holds the valid copy (Fig 1a).
* ``"rimms"``     — the paper's contribution: per-input last-resource-flag
  check, direct src→PE copy only when the flag names another location,
  output flag update to the executing PE (Fig 1b).

PEs are emulated on this CPU-only box: a "cpu" PE executes numpy
callables against host memory; accelerator PEs ("fft_acc", "zip_acc",
"gpu") execute jitted JAX callables against their own
:class:`~repro.core.hete.MemorySpace`. Transfers between spaces are real
array movements and are recorded in the ledger (count, bytes, modeled
seconds under platform bandwidths).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from .hete import HeteContext, HeteData, MemorySpace
from .locations import HOST, Location

__all__ = ["PE", "Task", "Runtime", "make_emulated_soc"]


@dataclasses.dataclass
class PE:
    """A processing element: name, kind, its memory location, supported ops."""

    name: str
    kind: str  # "cpu" | "acc" | "gpu" | ...
    location: Location
    supports: frozenset

    def __post_init__(self) -> None:
        self.supports = frozenset(self.supports)


@dataclasses.dataclass
class Task:
    """One API call: op over HeteData inputs/outputs (+ scalar params)."""

    op: str
    inputs: List[HeteData]
    outputs: List[HeteData]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    pin: Optional[str] = None  # pin to a PE name (CPU-ACC style scenarios)
    name: str = ""


class Runtime:
    """Dispatch loop: schedule → move (policy) → execute → flag update."""

    def __init__(
        self,
        pes: Sequence[PE],
        context: HeteContext,
        *,
        policy: str = "rimms",
        scheduler: str = "round_robin",
    ) -> None:
        if policy not in ("rimms", "reference"):
            raise ValueError(f"unknown memory policy {policy!r}")
        if scheduler not in ("round_robin", "data_affinity"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.pes = list(pes)
        self.by_name = {pe.name: pe for pe in self.pes}
        self.context = context
        self.policy = policy
        self.scheduler = scheduler
        self._rr_state: Dict[str, int] = {}
        # kernels: (op, pe_kind) -> callable(list_of_arrays, **params) -> tuple
        self._kernels: Dict[tuple, Callable] = {}
        self.task_log: List[tuple] = []  # (task name/op, pe name) for tests

    # -- registration -------------------------------------------------------
    def register_kernel(self, op: str, pe_kind: str, fn: Callable) -> None:
        self._kernels[(op, pe_kind)] = fn

    # -- scheduling -----------------------------------------------------------
    def _eligible(self, task: Task) -> List[PE]:
        pes = [
            pe
            for pe in self.pes
            if task.op in pe.supports and (task.op, pe.kind) in self._kernels
        ]
        if not pes:
            raise LookupError(f"no PE supports op {task.op!r}")
        return pes

    def _schedule(self, task: Task) -> PE:
        if task.pin is not None:
            return self.by_name[task.pin]
        pes = self._eligible(task)
        if self.scheduler == "round_robin":
            i = self._rr_state.get(task.op, 0)
            self._rr_state[task.op] = (i + 1) % len(pes)
            return pes[i % len(pes)]
        # data_affinity (beyond-paper): most input bytes already valid at PE
        def score(pe: PE) -> int:
            return sum(
                hd.nbytes for hd in task.inputs if hd.last_location == pe.location
            )
        return max(pes, key=score)

    # -- execution --------------------------------------------------------------
    def run(self, tasks: Sequence[Task]) -> float:
        """Execute tasks in submission order (data deps are submission-
        ordered by the apps, matching CEDR's API-level serialization).
        Returns wall seconds."""
        t0 = time.perf_counter()
        for task in tasks:
            self._dispatch(task)
        return time.perf_counter() - t0

    def _dispatch(self, task: Task) -> None:
        pe = self._schedule(task)
        fn = self._kernels[(task.op, pe.kind)]
        ctx = self.context
        loc = pe.location

        if self.policy == "reference":
            # Host-owned: host must be current first (producer wrote to
            # host already under this policy), then copy host→PE.
            ins = []
            for hd in task.inputs:
                host_val = hd.copies[HOST]
                if loc != HOST:
                    moved = ctx.spaces[loc].ingest(host_val)
                    ctx.ledger.record(HOST, loc, hd.nbytes)
                    ins.append(moved)
                else:
                    ins.append(host_val)
            outs = _as_tuple(fn(ins, **task.params))
            for hd, val in zip(task.outputs, outs):
                if loc != HOST:
                    host_val = ctx.spaces[loc].egress(val)
                    ctx.ledger.record(loc, HOST, hd.nbytes)
                else:
                    host_val = np.asarray(val)
                ctx.mark_written(hd, HOST, host_val.reshape(hd.shape))
        else:  # rimms
            ins = [ctx.ensure(hd, loc) for hd in task.inputs]
            outs = _as_tuple(fn(ins, **task.params))
            for hd, val in zip(task.outputs, outs):
                ctx.mark_written(hd, loc, val)

        self.task_log.append((task.name or task.op, pe.name))


def _as_tuple(x: Any) -> tuple:
    return x if isinstance(x, tuple) else (x,)


# ---------------------------------------------------------------------------
# Emulated heterogeneous SoC (§4.1 analogue) — built on the single CPU
# device: accelerator memory spaces hold jax.Arrays, host space numpy.
# ---------------------------------------------------------------------------


def make_emulated_soc(
    *,
    n_cpu: int = 1,
    accelerators: Sequence[str] = ("fft_acc0", "zip_acc0"),
    acc_ops: Optional[Dict[str, Sequence[str]]] = None,
    arena_bytes: int = 64 << 20,  # 64 MiB UDMA buffer, as on the ZCU102
    allocator: str = "nextfit",
    block_size: int = 4096,
    context: Optional[HeteContext] = None,
    tracking: str = "flag",
) -> tuple:
    """Build (runtime-ready PEs, HeteContext) for an emulated SoC.

    ``acc_ops`` maps accelerator name → ops it supports; defaults derive
    from the name prefix ("fft_acc*" → fft/ifft, "zip_acc*" → zip,
    "gpu*" → everything).
    """
    import jax
    import jax.numpy as jnp

    ctx = context or HeteContext(tracking=tracking)
    device = jax.devices()[0]

    def _ingest(host_value: np.ndarray):
        return jax.device_put(host_value, device)

    def _egress(value) -> np.ndarray:
        return np.asarray(value)

    pes: List[PE] = []
    for i in range(n_cpu):
        pes.append(
            PE(f"cpu{i}", "cpu", HOST, frozenset({"fft", "ifft", "zip", "generic"}))
        )

    default_ops = {"fft_acc": ("fft", "ifft"), "zip_acc": ("zip",),
                   "gpu": ("fft", "ifft", "zip", "generic")}
    for name in accelerators:
        kind = next((k for k in default_ops if name.startswith(k)), "acc")
        ops = tuple((acc_ops or {}).get(name, default_ops.get(kind, ())))
        loc = Location("device", name)
        ctx.register_space(
            MemorySpace(
                loc,
                capacity=arena_bytes,
                allocator=allocator,
                block_size=block_size,
                ingest=_ingest,
                egress=_egress,
            )
        )
        pes.append(PE(name, "gpu" if kind == "gpu" else "acc", loc, frozenset(ops)))
    return pes, ctx
