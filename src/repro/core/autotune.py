"""Pallas launch-parameter autotuning (ISSUE 10 tentpole, second half).

Every Pallas kernel in :mod:`repro.kernels` hard-coded its launch
geometry (``BLOCK_ROWS``, ``block_q``, ``chunk``, lane tiling).  This
module turns those constants into **measured choices**: each candidate
value registers as a named :class:`~repro.core.api.OpRegistry` variant
of a runtime op, :func:`~repro.core.calibrate.calibrate` races the
variants per (op, PE kind, shape bucket) and records the winner in the
:class:`~repro.core.calibrate.CalibrationTable`, and
:meth:`Runtime._select_kernel <repro.core.runtime.Runtime>` dispatches
the winning variant — **only** if its outputs measured bit-identical to
the default variant's (``mlstm``'s ``chunk`` changes accumulation
order, so its candidates are measured but can never win; ``fft``/
``zip`` row tiles, ``flash_attention``'s ``block_q`` and ``rg_lru``'s
lane tile are pure launch parameters and stay bit-exact).

The tuned ops register under their own names (``fft_pallas``,
``zip_pallas``, ``flash_attention``, ``mlstm``, ``rg_lru``) — they are
Pallas kernels with their own input layouts, not variants of the radar
app's XLA ``fft``/``zip`` ops.

Usage::

    session = rimms.Session.emulated(...)
    table = rimms.autotune(session)       # register + race + attach
    table.save("calib.json")              # later: Session(calibration=...)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .calibrate import DEFAULT_LADDER, CalibrationTable, calibrate

__all__ = ["Tunable", "tunables", "register_tunables", "autotune",
           "TUNED_KINDS"]

#: PE kinds the tuned Pallas ops register for — the kernels run in
#: interpret mode off-TPU, so any kind can host them; "acc" is where
#: emulated platforms put accelerator PEs.
TUNED_KINDS = ("cpu", "gpu", "acc")


@dataclasses.dataclass(frozen=True)
class Tunable:
    """One autotunable launch parameter of one runtime op."""

    op: str                      # registry op name ("fft_pallas", ...)
    param: str                   # kernel kwarg ("block_rows", ...)
    default: Any                 # value baked into the kernel today
    candidates: Tuple[Any, ...]  # non-default values to race
    fn: Callable                 # runtime kernel: fn(ins, **params)
    make_inputs: Callable        # (rng, nbytes) -> [np.ndarray, ...]
    bit_identical: bool = True   # expected — calibrate() verifies


def _variant_name(param: str, value: Any) -> str:
    return f"{param}{value}"


# -- runtime kernel wrappers (ins list -> outs tuple, like every other
# registered kernel; launch params arrive as kwargs from the variant) --


def _fft_pallas_kernel(ins, *, block_rows: int = 8):
    from repro.kernels.fft.ops import fft

    return (np.asarray(fft(ins[0], block_rows=block_rows)),)


def _zip_pallas_kernel(ins, *, block_rows: int = 256):
    from repro.kernels.zip.ops import zip_mul

    return (np.asarray(zip_mul(ins[0], ins[1], block_rows=block_rows)),)


def _flash_attention_kernel(ins, *, block_q: int = 256, block_k: int = 256):
    from repro.kernels.flash_attention.ops import flash_attention

    return (np.asarray(flash_attention(ins[0], ins[1], ins[2],
                                       block_q=block_q, block_k=block_k)),)


def _mlstm_kernel(ins, *, chunk: int = 64):
    from repro.kernels.mlstm.ops import mlstm_chunkwise

    return (np.asarray(mlstm_chunkwise(ins[0], ins[1], ins[2], ins[3],
                                       ins[4], chunk=chunk)),)


def _rg_lru_kernel(ins, *, block_lanes: int = 128):
    from repro.kernels.rg_lru.ops import rg_lru_scan

    hs, hn = rg_lru_scan(ins[0], ins[1], ins[2], block_lanes=block_lanes)
    return (np.asarray(hs), np.asarray(hn))


# -- input factories (rng, nbytes -> representative inputs) ------------


def _c64(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
            ).astype(np.complex64)


def _fft_inputs(rng, nbytes: int) -> List[np.ndarray]:
    rows = max(nbytes // (8 * 1024), 1)
    return [_c64(rng, (rows, 1024))]


def _zip_inputs(rng, nbytes: int) -> List[np.ndarray]:
    n = max(nbytes // 16, 128)
    return [_c64(rng, (n,)), _c64(rng, (n,))]


def _flash_inputs(rng, nbytes: int) -> List[np.ndarray]:
    # q,k,v: (1, S, 4, 64) f32 — S a multiple of 512 so every block_q
    # candidate tiles it exactly
    s = max((nbytes // (3 * 4 * 64 * 4)) // 512 * 512, 512)
    shape = (1, s, 4, 64)
    return [rng.standard_normal(shape).astype(np.float32) for _ in range(3)]


def _mlstm_inputs(rng, nbytes: int) -> List[np.ndarray]:
    # q,k,v: (1, S, 2, 64); gates (1, S, 2) — S a multiple of 128 so
    # every chunk candidate divides it
    s = max((nbytes // (3 * 2 * 64 * 4)) // 128 * 128, 128)
    qkv = [rng.standard_normal((1, s, 2, 64)).astype(np.float32)
           for _ in range(3)]
    i_gate = rng.standard_normal((1, s, 2)).astype(np.float32)
    log_f = -np.abs(rng.standard_normal((1, s, 2))).astype(np.float32)
    return qkv + [i_gate, log_f]


def _rg_lru_inputs(rng, nbytes: int) -> List[np.ndarray]:
    # a,b: (1, S, 512); h0: (1, 512) — D=512 admits every lane candidate
    d = 512
    s = max(nbytes // (2 * d * 4), 8)
    a = rng.uniform(0.5, 0.99, (1, s, d)).astype(np.float32)
    b = rng.standard_normal((1, s, d)).astype(np.float32)
    h0 = rng.standard_normal((1, d)).astype(np.float32)
    return [a, b, h0]


def tunables() -> List[Tunable]:
    """The autotuning search space: every Pallas launch parameter, its
    baked-in default, and the candidate values to race."""
    return [
        Tunable("fft_pallas", "block_rows", 8, (32, 128),
                _fft_pallas_kernel, _fft_inputs),
        Tunable("zip_pallas", "block_rows", 256, (1024, 4096),
                _zip_pallas_kernel, _zip_inputs),
        Tunable("flash_attention", "block_q", 256, (128, 512),
                _flash_attention_kernel, _flash_inputs),
        Tunable("mlstm", "chunk", 64, (32, 128),
                _mlstm_kernel, _mlstm_inputs, bit_identical=False),
        Tunable("rg_lru", "block_lanes", 128, (256, 512),
                _rg_lru_kernel, _rg_lru_inputs),
    ]


def register_tunables(registry=None, *, kinds: Sequence[str] = TUNED_KINDS,
                      replace: bool = False) -> List[str]:
    """Register every tunable op (default + candidate variants + calib
    input factory) on ``registry`` (default: the process registry).
    Returns the op names, for ``calibrate(ops=...)``.  Idempotent with
    ``replace=True``."""
    if registry is None:
        from .api import default_registry as registry  # noqa: N813
    names = []
    for t in tunables():
        names.append(t.op)
        for kind in kinds:
            registry.register(t.op, kind, t.fn, params={t.param: t.default},
                              calib=t.make_inputs, replace=replace)
            for value in t.candidates:
                registry.register(t.op, kind, t.fn,
                                  variant=_variant_name(t.param, value),
                                  params={t.param: value}, replace=replace)
    return names


def autotune(session, *, nbytes: Sequence[int] = DEFAULT_LADDER, k: int = 5,
             warmup: int = 2, seed: int = 0,
             table: Optional[CalibrationTable] = None,
             install: bool = True, verbose: bool = False,
             extra_ops: Sequence[str] = ()) -> CalibrationTable:
    """Race every Pallas launch-param candidate on ``session``'s
    runtime, record winners, and attach the resulting calibration table
    so subsequent dispatch uses them.

    ``install=True`` (default) also installs the tuned ops' kernels into
    the runtime (missing-only) so ``session.submit("fft_pallas", ...)``
    dispatches the measured winner.  ``extra_ops`` adds already-
    registered ops (e.g. the radar app's ``fft``/``zip``) to the same
    calibration pass.
    """
    reg = getattr(session, "registry", None)
    if reg is None:
        from .api import default_registry as reg  # noqa: N813
    ops = register_tunables(reg, replace=True)
    if install:
        reg.install(session.runtime, missing_only=True,
                    extend_supports=("cpu", "gpu"))
    tab = calibrate(session, registry=reg, ops=list(ops) + list(extra_ops),
                    nbytes=nbytes, k=k, warmup=warmup, seed=seed,
                    table=table, verbose=verbose)
    tab.meta.setdefault("autotuned_ops", sorted(ops))
    session.calibration = tab
    session.runtime.set_calibration(tab)
    return tab


def tuned_summary(table: CalibrationTable) -> Dict[str, Dict[str, Any]]:
    """Winner rows for the tuned ops only — ``{op/kind/bucket: winner}``
    (what ``bench_calibrate`` and the CLI report print)."""
    tuned = {t.op for t in tunables()}
    return {key: dict(win) for key, win in table.winners()
            if key.split("/", 1)[0] in tuned}
