"""Streaming session API — RIMMS's primary entry point (ISSUE 4).

The paper's promise (§3.2) is that application code names *work* and
*data* while the runtime owns placement, movement, and completion.  The
batch entry points (:meth:`Runtime.run` / :meth:`Runtime.run_graph`)
still made callers hand-assemble static ``Task`` lists, pick an
execution mode, and ``hete_sync`` by hand.  This module is the
redesigned front door:

* :func:`op` — decorator registering a kernel *variant per PE kind*
  into an :class:`OpRegistry` (``@rimms.op("fft", kinds=("cpu",))``);
  a session installs the registry into its runtime, so applications
  never call ``register_kernel`` directly;
* :class:`Session` — deferred execution over a **live task DAG**:
  :meth:`Session.malloc` and :meth:`Session.submit` return
  :class:`BufferFuture` handles, each submission incrementally extends
  the DAG (:class:`~repro.core.graph.GraphBuilder` resolves RAW/WAR/WAW
  ordering from the buffers' read/write intervals), and the persistent
  :class:`~repro.core.executor.StreamExecutor` consumes the stream
  continuously — windowed HEFT placement over the ready frontier, no
  global barrier;
* :class:`BufferFuture` — a handle over a ``hete_Data`` buffer version:
  ``future.result()`` / :meth:`Session.barrier` / ``with session:`` are
  the *only* sync points; kernel exceptions propagate through futures
  (a failure fails its dependent subtree, independent chains keep
  flowing); :meth:`BufferFuture.free` is ``hete_free`` deferred to
  after the stream's last use of the buffer.

Example::

    import numpy as np
    from repro.core import api as rimms
    import repro.apps.radar  # registers fft/ifft/zip kernel variants

    with rimms.Session.emulated(accelerators=("gpu0", "gpu1")) as s:
        x = s.malloc((1024,), np.complex64)
        x.data[:] = make_signal()
        f = s.submit("fft", [x])          # returns a BufferFuture
        y = s.submit("ifft", [f])         # chains without waiting
        out = y.result()                  # the only sync point

Threads may submit concurrently against one session (multi-tenant
streaming): submissions serialize at admission, placement and data
movement stay runtime-owned, and each client blocks only on its own
futures.

Multi-tenant QoS (ISSUE 5): every submission belongs to a *client* — an
explicit :meth:`Session.client` handle or an implicit per-thread one.
Each client has a bounded in-flight window (``submit`` blocks when it is
full, or raises :class:`~repro.core.qos.BackpressureFull` under
``nowait=True``), waiting submissions are admitted by a weighted
deficit-round-robin (:class:`~repro.core.qos.QoSManager`), device-arena
reservations can be quota'd per tenant
(:class:`~repro.core.qos.QuotaExceeded` fails only the offending
tenant), and :meth:`Session.qos_report` /
:meth:`Session.fairness_report` expose deterministic per-client latency
and Jain's-index fairness evidence.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .calibrate import (DEFAULT_VARIANT, CalibrationTable,  # noqa: F401
                        resolve_calibration)
from .executor import StreamExecutor
from .graph import GraphBuilder
from .hete import HeteContext, HeteData
from .locations import HOST
from .qos import DEFAULT_CLIENT, BackpressureFull, QoSManager, admission_cost
from .runtime import (BACKENDS, Runtime, Task,  # noqa: F401
                      make_emulated_soc, platform_names, register_platform,
                      resolve_backend)
from .telemetry import Sampler, metrics_text, serve_metrics, slo_eval
from .trace import (MetricsRegistry, TraceCollector, trace,  # noqa: F401
                    trace_lint)

__all__ = ["OpRegistry", "OpVariant", "op", "default_registry",
           "BufferFuture", "Session", "SessionClient", "SessionClosedError",
           "CalibrationTable", "DEFAULT_VARIANT", "TraceCollector",
           "MetricsRegistry", "Sampler", "trace", "trace_lint", "BACKENDS",
           "resolve_backend", "register_platform", "platform_names"]


class SessionClosedError(RuntimeError):
    """The session is closed (explicitly, or by ``with`` exit): it no
    longer accepts ``malloc``/``submit``.  Raised instead of silently
    enqueueing onto a drained stream or a dead worker pool."""


@dataclasses.dataclass(frozen=True)
class OpVariant:
    """One registered kernel variant: the callable plus the launch
    params bound at registration (merged *under* per-task params at
    dispatch) and an optional calibration input factory
    ``(rng, nbytes) -> list[ndarray]`` the measurement harness uses."""

    op: str
    kind: str
    variant: str
    fn: Callable
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    calib: Optional[Callable] = None


class OpRegistry:
    """Kernel variants keyed on ``(op, pe_kind, variant)`` — the
    dispatch table the :func:`op` decorator fills and a :class:`Session`
    installs into its :class:`~repro.core.runtime.Runtime`.

    A variant is ``fn(inputs: list, **params) -> array | tuple`` exactly
    like :meth:`Runtime.register_kernel` expects.  The **default**
    variant (no ``variant=`` at registration) keeps the historical
    single-registration behavior: registering the same ``(op, kind)``
    twice with a different function raises unless ``replace=True``
    (kernels are identity, not configuration) — and so does re-using a
    named variant.  Named variants (ISSUE 10) are tuning candidates:
    same math, different launch parameters; the autotuner races them and
    :meth:`select` answers which one a calibration table says to run.
    """

    def __init__(self) -> None:
        # (op, kind) -> {variant name -> OpVariant}; DEFAULT_VARIANT is
        # the reference registration every current call site resolves.
        self._variants: Dict[Tuple[str, str], Dict[str, OpVariant]] = {}

    def register(self, op_name: str, kind: str, fn: Callable, *,
                 variant: Optional[str] = None,
                 params: Optional[Dict[str, Any]] = None,
                 calib: Optional[Callable] = None,
                 replace: bool = False) -> None:
        vname = variant or DEFAULT_VARIANT
        key = (op_name, kind)
        group = self._variants.setdefault(key, {})
        prev = group.get(vname)
        if prev is not None and prev.fn is not fn and not replace:
            raise ValueError(
                f"op variant {key + (vname,)} already registered "
                f"({prev.fn.__name__}); pass replace=True to override"
            )
        group[vname] = OpVariant(op_name, kind, vname, fn,
                                 dict(params or {}), calib)

    # -- default-variant fast path (all pre-ISSUE-10 call sites) ------------
    def get(self, op_name: str, kind: str) -> Optional[Callable]:
        var = self._variants.get((op_name, kind), {}).get(DEFAULT_VARIANT)
        return var.fn if var is not None else None

    def kinds(self, op_name: str) -> List[str]:
        """PE kinds with a registered variant of ``op_name``."""
        return sorted(k for (o, k) in self._variants if o == op_name)

    def ops(self) -> List[str]:
        return sorted({o for o, _ in self._variants})

    def __len__(self) -> int:
        return len(self._variants)

    # -- variant surface (ISSUE 10) ------------------------------------------
    def variants(self, op_name: str, kind: str) -> List[str]:
        """Registered variant names for ``(op, kind)``, default first."""
        names = sorted(self._variants.get((op_name, kind), {}))
        if DEFAULT_VARIANT in names:
            names.remove(DEFAULT_VARIANT)
            names.insert(0, DEFAULT_VARIANT)
        return names

    def variant(self, op_name: str, kind: str, name: str) -> OpVariant:
        group = self._variants.get((op_name, kind), {})
        if name not in group:
            raise KeyError(
                f"no variant {name!r} of op {(op_name, kind)}; registered: "
                f"{self.variants(op_name, kind)}")
        return group[name]

    def select(self, op_name: str, kind: str, nbytes,
               table=None) -> OpVariant:
        """The variant to dispatch for ``nbytes`` of input (an int, or
        anything with ``.nbytes``): the calibration ``table``'s winner
        for this shape bucket when one is recorded and registered, else
        the default variant."""
        n = int(getattr(nbytes, "nbytes", nbytes))
        group = self._variants.get((op_name, kind), {})
        if table is not None:
            best = table.best_variant(op_name, kind, n)
            if best is not None and best in group:
                return group[best]
        var = group.get(DEFAULT_VARIANT)
        if var is None:
            raise KeyError(f"op {(op_name, kind)} has no default variant")
        return var

    def input_maker(self, op_name: str) -> Optional[Callable]:
        """The op's calibration input factory ``(rng, nbytes) ->
        list[ndarray]`` — taken from any variant that declared one
        (kind-independent: the same arrays feed every PE kind)."""
        for (o, _k), group in sorted(self._variants.items()):
            if o != op_name:
                continue
            for vname in sorted(group):
                if group[vname].calib is not None:
                    return group[vname].calib
        return None

    def install(self, rt: Runtime, *, missing_only: bool = False,
                extend_supports: Sequence[str] = ()) -> None:
        """Register every variant into ``rt``.  ``missing_only`` keeps
        kernels the runtime already has (so a session never clobbers a
        hand-registered override) — keyed on the default variant, with
        named variants of the op riding along.  ``extend_supports``
        names the *general-purpose* PE kinds (typically
        ``("cpu", "gpu")``) whose PEs additionally advertise every op
        they now have a kernel for — restricted accelerator kinds (a zip
        engine is a zip engine) keep the op sets their platform
        description declared."""
        for (op_name, kind), group in self._variants.items():
            if missing_only and (op_name, kind) in rt._kernels:
                continue
            for vname, var in group.items():
                if vname == DEFAULT_VARIANT:
                    rt.register_kernel(op_name, kind, var.fn)
                else:
                    rt.register_kernel(op_name, kind, var.fn,
                                       variant=vname, params=var.params)
        for pe in rt.pes:
            if pe.kind in extend_supports:
                extra = {o for (o, k) in self._variants if k == pe.kind}
                pe.supports = frozenset(pe.supports | extra)


#: process-default registry — the one bare ``@op`` fills and sessions
#: install unless given their own.
default_registry = OpRegistry()


def op(name: str, *, kinds: Union[str, Sequence[str]],
       registry: Optional[OpRegistry] = None,
       variant: Optional[str] = None,
       params: Optional[Dict[str, Any]] = None,
       calib: Optional[Callable] = None,
       replace: bool = False) -> Callable:
    """Decorator: register the function as op ``name``'s kernel variant
    for each PE kind in ``kinds``::

        @rimms.op("fft", kinds=("acc", "gpu"))
        def fft_device(ins):
            return _jfft(ins[0])

    Without ``variant=`` this is the op's **default** (reference)
    registration, with the historical duplicate-registration error.
    ``variant="block64", params={"block_rows": 64}`` registers a tuning
    candidate instead (ISSUE 10): same math as the default, launch
    ``params`` bound at dispatch, raced by the autotuner and selected
    per shape bucket from a calibration table.  ``calib`` attaches the
    op's calibration input factory ``(rng, nbytes) -> list[ndarray]`` so
    the measurement harness can synthesize representative inputs.

    The function is returned unchanged (still directly callable)."""
    kind_list = (kinds,) if isinstance(kinds, str) else tuple(kinds)
    if not kind_list:
        raise ValueError(f"op {name!r} needs at least one PE kind")

    def deco(fn: Callable) -> Callable:
        reg = registry if registry is not None else default_registry
        for k in kind_list:
            reg.register(name, k, fn, variant=variant, params=params,
                         calib=calib, replace=replace)
        return fn

    return deco


class BufferFuture:
    """A handle over a ``hete_Data`` buffer inside a streaming
    :class:`Session` — the session API's unit of data.

    Submitting a task that writes the buffer binds the returned future
    to the buffer's new *version* (:class:`~repro.core.graph.GraphBuilder`
    bumps it per write submission).  :meth:`result` synchronizes the
    buffer: it waits for the buffer's last submitted writer (so a
    resubmitted buffer resolves to its newest submitted content), then
    returns the host-synced array.  A failed producing task — or a
    failed transitive dependency — re-raises its exception here.
    """

    __slots__ = ("session", "hete", "version", "node")

    def __init__(self, session: "Session", hete: HeteData, *,
                 version: int = 0, node: Optional[int] = None) -> None:
        self.session = session
        self.hete = hete
        self.version = version
        #: index of the producing task's node in the stream (None for a
        #: fresh malloc) — keys into the per-task ``finish``/``release``
        #: times of :meth:`Session.qos_report`
        self.node = node

    # -- buffer surface ------------------------------------------------------
    @property
    def shape(self) -> tuple:
        return self.hete.shape

    @property
    def dtype(self) -> np.dtype:
        return self.hete.dtype

    @property
    def nbytes(self) -> int:
        return self.hete.nbytes

    @property
    def data(self) -> np.ndarray:
        """The raw host-resident field (paper semantics: reading it
        without :meth:`result` may observe stale bytes — use it to fill
        inputs before submission, :meth:`result` to read outputs)."""
        return self.hete.data

    # -- future surface ------------------------------------------------------
    def done(self) -> bool:
        """True when the buffer's last submitted writer completed or
        failed (trivially True for never-written buffers)."""
        target = self.session._last_writer(self.hete)
        return target is None or self.session._stream.done(target)

    def exception(self) -> Optional[BaseException]:
        """The failure of the buffer's last submitted writer, if any
        (non-blocking; None while pending or on success)."""
        target = self.session._last_writer(self.hete)
        if target is None:
            return None
        return self.session._stream.exception(target)

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Synchronize the buffer: wait for its last submitted writer,
        re-raise its failure if it (or a transitive dependency) failed,
        else ``hete_Sync`` and return the host array."""
        self.session._wait_node(self.session._last_writer(self.hete), timeout)
        return self.session.context.sync(self.hete)

    def free(self) -> bool:
        """``hete_free`` after the stream's last use (see
        :meth:`Session.free`)."""
        return self.session.free(self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self.done() else "pending"
        return (f"BufferFuture(shape={self.hete.shape}, "
                f"dtype={np.dtype(self.hete.dtype).name}, v{self.version}, "
                f"{state})")


class SessionClient:
    """A named tenant handle over a :class:`Session` (ISSUE 5).

    Carries the client's QoS state (weight, in-flight window, optional
    per-arena quota) and attributes every ``malloc``/``submit`` made
    through it.  Obtained from :meth:`Session.client`; threads that
    submit directly on the session get an implicit per-thread client
    with default QoS settings.
    """

    __slots__ = ("session", "state")

    def __init__(self, session: "Session", state) -> None:
        self.session = session
        self.state = state

    @property
    def name(self) -> str:
        return self.state.name

    def malloc(self, shape, dtype=np.uint8) -> BufferFuture:
        """:meth:`Session.malloc` with the allocation charged to this
        tenant's arena quota."""
        return self.session.malloc(shape, dtype, client=self)

    def submit(self, op_name: str, inputs=(), *, nowait: bool = False,
               **kwargs) -> Union[BufferFuture, Tuple[BufferFuture, ...]]:
        """:meth:`Session.submit` under this client's backpressure
        window and DRR weight.  ``nowait=True`` raises
        :class:`~repro.core.qos.BackpressureFull` instead of blocking
        when the window is full."""
        return self.session.submit(op_name, inputs, client=self,
                                   nowait=nowait, **kwargs)

    def free(self, buf) -> bool:
        return self.session.free(buf)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SessionClient({self.name!r}, weight={self.state.weight}, "
                f"window={self.state.window})")


class Session:
    """Deferred-execution session — the primary RIMMS entry point.

    ``Session(runtime)`` adopts an existing
    :class:`~repro.core.runtime.Runtime` (the dispatch engine);
    :meth:`Session.emulated` builds runtime + context over the emulated
    SoC in one call.  On creation the session installs ``registry``
    (default: :data:`default_registry`) into the runtime — kernels the
    runtime already has win — and starts a
    :class:`~repro.core.executor.StreamExecutor` on the runtime's
    persistent worker pool.

    Submission model: :meth:`submit` builds a task over
    :class:`BufferFuture`/:class:`~repro.core.hete.HeteData` operands,
    extends the live DAG, and admits it to the stream — returning output
    futures immediately.  Sync points are ``future.result()``,
    :meth:`barrier`, and ``with session:`` exit; nothing else blocks.
    Any thread may submit; admission is serialized internally.
    """

    def __init__(
        self,
        runtime: Runtime,
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
        window: int = 64,
        registry: Optional[OpRegistry] = None,
        qos: Optional[QoSManager] = None,
        client_window: int = 64,
        global_window: Optional[int] = None,
        trace: Union[bool, TraceCollector, None] = None,
        backend: Optional[str] = None,
        sampler_period: Optional[float] = None,
        calibration: Union[None, str, CalibrationTable] = None,
    ) -> None:
        self.runtime = runtime
        # Execution backend (ISSUE 7): None adopts the runtime's;
        # "thread" | "process" | "auto" re-resolves it (unknown names
        # raise listing the valid choices).
        self.backend = runtime.set_backend(backend)
        self.context: HeteContext = runtime.context
        # Measured calibration (ISSUE 10): a table — or a path to one,
        # or "auto" ($RIMMS_CALIBRATION) — attached at construction so
        # HEFT placement prices work from measured throughput and
        # _run_kernel dispatches tuned variants.  An embedded divergence
        # snapshot seeds the runtime's live EMAs.
        self.calibration = resolve_calibration(calibration)
        if self.calibration is not None:
            runtime.set_calibration(self.calibration)
            if self.calibration.divergence:
                runtime.divergence.merge(self.calibration.divergence)
        # Full-lifecycle tracing (ISSUE 6): off by default.  ``trace=True``
        # attaches a fresh TraceCollector to the context; pass an existing
        # collector to aggregate several sessions into one trace.
        if trace:
            tc = trace if isinstance(trace, TraceCollector) else TraceCollector()
            self.context.set_tracer(tc)
        self._trace_pushed = False
        #: session-lifetime metrics (counters/gauges); qos_report adds
        #: per-client latency histograms derived from the fair replay
        self.metrics = MetricsRegistry()
        reg = registry if registry is not None else default_registry
        reg.install(runtime, missing_only=True,
                    extend_supports=("cpu", "gpu"))
        self.registry = reg
        self.closed = False
        # Multi-tenant QoS (ISSUE 5): per-client backpressure windows +
        # weighted DRR admission.  ``client_window`` is the default
        # in-flight bound per client; ``global_window`` optionally caps
        # the whole admitted frontier.
        self.qos = qos if qos is not None else QoSManager(
            default_window=client_window, global_window=global_window)
        self._builder = GraphBuilder()
        self._events: Dict[int, threading.Event] = {}
        self._node_exc: Dict[int, BaseException] = {}
        self._uses: Dict[int, List[HeteData]] = {}  # node -> retained roots
        self._node_client: Dict[int, Any] = {}  # node -> ClientState
        self._tls = threading.local()  # .client: implicit per-thread client
        self._seq = itertools.count()
        self._client_seq = itertools.count()
        self._stream = StreamExecutor(
            runtime, scheduler=scheduler, prefetch=prefetch,
            on_done=self._node_done, window=window,
        )
        # Submissions mutate the builder's node linkage (deps/dependents)
        # that stream completion iterates: one reentrant lock serializes
        # both (admit() re-enters it).
        self._sublock = self._stream.state_lock
        # Background telemetry sampler (ISSUE 8): off by default;
        # ``sampler_period=0.0`` builds a manual-tick sampler without a
        # thread, > 0 starts the periodic background thread.
        self.sampler: Optional[Sampler] = None
        if sampler_period is not None:
            self.start_sampler(period=sampler_period)

    @classmethod
    def emulated(
        cls,
        platform: Optional[str] = None,
        *,
        policy: str = "rimms",
        scheduler: str = "heft",
        n_cpu: int = 1,
        accelerators: Sequence[str] = ("gpu0",),
        prefetch: bool = True,
        window: int = 64,
        registry: Optional[OpRegistry] = None,
        qos: Optional[QoSManager] = None,
        client_window: int = 64,
        global_window: Optional[int] = None,
        trace: Union[bool, TraceCollector, None] = None,
        backend: Optional[str] = None,
        sampler_period: Optional[float] = None,
        calibration: Union[None, str, CalibrationTable] = None,
        **soc_kwargs: Any,
    ) -> "Session":
        """Session over a fresh emulated SoC (see
        :func:`~repro.core.runtime.make_emulated_soc` for
        ``soc_kwargs``: ``arena_bytes``, ``topology``, ``acc_ops``, …).
        The default scheduler is the windowed ``heft`` — the streaming
        placement the session exists for; pass ``"round_robin"`` for
        bit-identical-to-serial static placement.

        ``platform`` names a preset from the shorthand registry
        (:func:`~repro.core.runtime.register_platform`; built-ins listed
        by :func:`~repro.core.runtime.platform_names`):
        ``Session.emulated("nvlink_mesh")`` applies the preset's routed
        topology and default arena capacity, with explicit keywords
        still winning.  ``backend`` selects kernel execution —
        ``"thread"`` | ``"process"`` | ``"auto"`` (ISSUE 7)."""
        if platform is not None:
            from .runtime import _resolve_platform

            entry = _resolve_platform(platform)
            if entry is None:
                raise ValueError(
                    f"unknown platform {platform!r}: registered presets "
                    f"are {platform_names()}")
            factory, preset_arena = entry
            if factory is not None:
                soc_kwargs.setdefault("topology", platform)
            if preset_arena is not None:
                soc_kwargs.setdefault("arena_bytes", preset_arena)
        pes, ctx = make_emulated_soc(
            n_cpu=n_cpu, accelerators=tuple(accelerators), backend=backend,
            **soc_kwargs
        )
        rt = Runtime(pes, ctx, policy=policy, scheduler=scheduler,
                     backend=backend)
        return cls(rt, prefetch=prefetch, window=window, registry=registry,
                   qos=qos, client_window=client_window,
                   global_window=global_window, trace=trace,
                   sampler_period=sampler_period, calibration=calibration)

    # -- tenants (ISSUE 5) ---------------------------------------------------
    def client(self, name: Optional[str] = None, *,
               weight: Optional[float] = None,
               window: Optional[int] = None,
               quota_bytes: Optional[int] = None,
               think_s: Optional[float] = None,
               slo_latency_s: Optional[float] = None,
               slo_target: Optional[float] = None) -> SessionClient:
        """A named tenant handle: its submissions run under ``weight``
        (DRR admission share), a bounded in-flight ``window``
        (backpressure), and an optional per-device-arena reservation
        ``quota_bytes``.  ``think_s`` declares the client's closed-loop
        think time so the deterministic QoS replay (``qos_report``)
        models its pacing instead of an open-loop burst.
        ``slo_latency_s`` declares a latency objective (ISSUE 8): tasks
        finishing later than it in the deterministic replay count as
        violations, ``qos_report()["slo"]`` reports the burn rate
        against ``slo_target`` (default 0.99), and violations emit
        ``slo_violation`` instants into the trace.  Calling again with
        the same name updates the passed settings and returns a handle
        to the same client."""
        if name is None:
            name = f"client{next(self._client_seq)}"
        state = self.qos.client(name, weight=weight, window=window,
                                quota_bytes=quota_bytes, think_s=think_s,
                                slo_latency_s=slo_latency_s,
                                slo_target=slo_target)
        if quota_bytes is not None:
            self.context.set_quota(name, quota_bytes)
        return SessionClient(self, state)

    def _thread_client(self) -> SessionClient:
        """The implicit per-thread client: threads that submit directly
        on the session are tenants too (named after the thread), so
        backpressure and fair admission apply uniformly."""
        cl = getattr(self._tls, "client", None)
        if cl is None or cl.session is not self:
            cl = self.client(threading.current_thread().name)
            self._tls.client = cl
        return cl

    def _resolve_client(self, client) -> SessionClient:
        if client is None:
            return self._thread_client()
        if isinstance(client, SessionClient):
            if client.session is not self:
                raise ValueError("SessionClient belongs to another session")
            return client
        return self.client(str(client))

    # -- allocation ----------------------------------------------------------
    def malloc(self, shape, dtype=np.uint8, *,
               client: Union[None, str, SessionClient] = None) -> BufferFuture:
        """``hete_Malloc`` returning a :class:`BufferFuture` (version 0:
        the fresh host bytes are immediately valid — ``.data`` is
        writable for input filling).  The allocation is charged to
        ``client`` (default: the calling thread's implicit client) for
        per-tenant arena quotas."""
        self._check_open()
        owner = self._resolve_client(client).name
        return BufferFuture(self, self.context.malloc(shape, dtype,
                                                      owner=owner))

    def wrap(self, hd: HeteData) -> BufferFuture:
        """Adopt an existing ``hete_Data`` buffer into the session (for
        incremental ports of Task-list code)."""
        return BufferFuture(self, hd)

    def free(self, buf: Union[BufferFuture, HeteData]) -> bool:
        """``hete_free`` with free-after-last-use semantics: frees the
        root allocation immediately when no submitted-but-incomplete
        task touches it, otherwise defers the free to the completion of
        the last such task.  Returns True when freed immediately."""
        hd = buf.hete if isinstance(buf, BufferFuture) else buf
        return self.context.free_when_unused(hd)

    # -- submission ----------------------------------------------------------
    def submit(
        self,
        op_name: str,
        inputs: Sequence[Union[BufferFuture, HeteData, np.ndarray]] = (),
        *,
        out: Union[None, BufferFuture, HeteData,
                   Sequence[Union[BufferFuture, HeteData]]] = None,
        out_shape: Optional[tuple] = None,
        out_dtype: Optional[Any] = None,
        n_out: int = 1,
        pin: Optional[str] = None,
        name: str = "",
        client: Union[None, str, SessionClient] = None,
        nowait: bool = False,
        **params: Any,
    ) -> Union[BufferFuture, Tuple[BufferFuture, ...]]:
        """Submit one op invocation to the stream; returns the output
        :class:`BufferFuture` (or a tuple when there are several).

        ``inputs`` may mix futures, raw ``hete_Data`` buffers, and numpy
        arrays (arrays are hete_malloc'ed and filled on the spot).
        Outputs default to one fresh buffer shaped like the first input
        (override with ``out_shape``/``out_dtype``/``n_out``, or pass
        existing buffers via ``out=`` to write in place).  ``pin`` names
        a PE for CPU-ACC style placement studies; ``params`` are
        forwarded to the kernel.

        Backpressure (ISSUE 5): the submission runs under ``client``'s
        QoS (default: the calling thread's implicit client).  When the
        client's in-flight window — or the stream's global window — is
        full, the call *blocks* until a completion frees a slot, with
        freed slots granted across waiting clients by weighted deficit
        round-robin; ``nowait=True`` raises
        :class:`~repro.core.qos.BackpressureFull` instead.

        Never blocks on data: dependencies are resolved from the
        buffers' read/write intervals and the task runs when its
        producers complete.  Scheduling and kernel failures surface
        through the returned futures, not here."""
        self._check_open()
        cl = self._resolve_client(client)
        ins_hd = [self._coerce(x, owner=cl.name) for x in inputs]
        outs_hd, single = self._normalize_outs(
            ins_hd, out, out_shape, out_dtype, n_out, owner=cl.name)
        task = Task(
            op_name, ins_hd, outs_hd, params=dict(params), pin=pin,
            name=name or f"{op_name}#{next(self._seq)}", client=cl.name,
        )
        self.metrics.counter("submits").inc()
        tracer = self.context.tracer
        if tracer is not None:
            tracer.instant("submit", "submit", f"tenant:{cl.name}",
                           {"task": task.name, "op": op_name,
                            "client": cl.name})
            t_adm = tracer.now()
        try:
            stall = self.qos.admit(cl.state, admission_cost(task),
                                   nowait=nowait)
        except BackpressureFull:
            self.metrics.counter("backpressure_rejections").inc()
            if tracer is not None:
                tracer.instant("backpressure_full", "qos",
                               f"tenant:{cl.name}",
                               {"task": task.name, "client": cl.name})
            raise
        if tracer is not None:
            tracer.span("qos_admit", "qos", f"tenant:{cl.name}",
                        t_adm, tracer.now(),
                        {"task": task.name, "client": cl.name,
                         "stall_s": stall})
        if stall > 0.0:
            self.metrics.counter("backpressure_blocks").inc()
            if tracer is not None:
                tracer.instant("backpressure_block", "qos",
                               f"tenant:{cl.name}",
                               {"task": task.name, "client": cl.name,
                                "stall_s": stall})
            self.ledger.record_client_stall(cl.name, stall)
        stream_owns_slot = False
        try:
            with self._sublock:
                # Re-check under the lock: close() marks the stream
                # closed under this same lock, so a submission that
                # slipped past _check_open cannot enqueue onto a drained
                # stream or a dead worker pool.
                if self.closed or self._stream.closed:
                    raise SessionClosedError("session is closed")
                node = self._builder.add(task)
                i = node.index
                self._events[i] = threading.Event()
                roots: List[HeteData] = []
                seen: set = set()
                for hd in ins_hd + outs_hd:
                    r = hd.root
                    if id(r) not in seen:
                        seen.add(id(r))
                        roots.append(r)
                        self.context.retain_use(r)
                self._uses[i] = roots
                self._node_client[i] = cl.state
                futures = tuple(
                    BufferFuture(self, hd,
                                 version=self._builder.version_of(hd), node=i)
                    for hd in outs_hd
                )
                # From here the completion callback owns the QoS slot
                # (it releases at task completion or failure).
                stream_owns_slot = True
                self._stream.admit(node)
        except BaseException:
            if not stream_owns_slot:
                self.qos.release(cl.state)
            raise
        return futures[0] if single else futures

    def _coerce(self, x, owner: Optional[str] = None) -> HeteData:
        if isinstance(x, BufferFuture):
            if x.session is not self:
                raise ValueError("BufferFuture belongs to another session")
            return x.hete
        if isinstance(x, HeteData):
            return x
        arr = np.asarray(x)
        hd = self.context.malloc(arr.shape, arr.dtype, owner=owner)
        hd.copies[HOST][...] = arr
        return hd

    def _normalize_outs(
        self, ins_hd, out, out_shape, out_dtype, n_out,
        owner: Optional[str] = None,
    ) -> Tuple[List[HeteData], bool]:
        if out is not None:
            outs = [out] if isinstance(out, (BufferFuture, HeteData)) else list(out)
            return [self._coerce(o) for o in outs], not isinstance(out, (list, tuple))
        if out_shape is None or out_dtype is None:
            if not ins_hd:
                raise ValueError(
                    "submit() with no inputs needs explicit out_shape "
                    "and out_dtype (nothing to infer the output from)"
                )
            # `is None`, not truthiness: shape () is a valid 0-d scalar
            if out_shape is None:
                out_shape = ins_hd[0].shape
            if out_dtype is None:
                out_dtype = ins_hd[0].dtype
        return (
            [self.context.malloc(out_shape, out_dtype, owner=owner)
             for _ in range(n_out)],
            n_out == 1,
        )

    # -- completion plumbing -------------------------------------------------
    def _node_done(self, index: int, exc: Optional[BaseException]) -> None:
        """StreamExecutor completion callback (under the stream lock):
        resolve the node's futures and release its buffer lifecycles —
        a deferred :meth:`free` fires here when this was the buffer's
        last in-flight use."""
        if exc is not None:
            self._node_exc[index] = exc
        for r in self._uses.pop(index, ()):
            self.context.release_use(r)
        state = self._node_client.pop(index, None)
        if state is not None:
            # Free the client's QoS window slot — this is what unblocks
            # a submitter waiting in backpressure (or admits the next
            # DRR grantee).
            self.qos.release(state)
        ev = self._events.get(index)
        if ev is not None:
            ev.set()

    def _last_writer(self, hd: HeteData) -> Optional[int]:
        with self._sublock:
            return self._builder.last_writer(hd)

    def _wait_node(self, index: Optional[int],
                   timeout: Optional[float] = None) -> None:
        if index is None:
            return
        ev = self._events[index]
        if not ev.wait(timeout):
            raise TimeoutError(f"task #{index} still pending after {timeout}s")
        exc = self._node_exc.get(index)
        if exc is not None:
            self._stream.mark_observed(index)
            raise exc

    # -- sync points ---------------------------------------------------------
    def barrier(self, timeout: Optional[float] = None) -> None:
        """Wait for every submitted task to complete; re-raise the first
        failure not already observed through a future's ``result()``."""
        self._stream.barrier(timeout)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        try:
            if exc_type is None:
                self.barrier()
        finally:
            self.close()

    def close(self) -> None:
        """Drain the stream and stop accepting submissions (idempotent).
        The runtime and its worker pool stay usable — call
        :meth:`Runtime.close` to release the threads.  On close the
        session also merges process-worker metrics into
        :attr:`metrics`, stops the telemetry sampler, and pushes the
        modeled track group (+ divergence table, SLO instants) into the
        tracer."""
        if not self.closed:
            self.closed = True
            self._stream.close()
            self._collect_worker_metrics()
            if self.sampler is not None:
                self.sampler.stop()
            self._push_trace()

    def _collect_worker_metrics(self) -> None:
        """Drain process-backend workers' local counters/histograms into
        this session's registry (ISSUE 8).  Dead or mid-restart workers
        are skipped — metric loss is acceptable, a hung close is not."""
        pool = getattr(self.runtime, "_process_pool", None)
        if pool is not None:
            try:
                pool.collect_metrics(self.metrics)
            except Exception:
                pass

    def _push_trace(self) -> None:
        """Derive the stream's modeled track group into the tracer —
        once (the trace shows one deterministic QoS replay of the
        stream).  No-op without a tracer."""
        tracer = self.context.tracer
        if tracer is None or self._trace_pushed:
            return
        self._trace_pushed = True
        timeline, _, finish, release = self._stream.replay(
            admission=self.qos)
        with self._sublock:
            nodes = list(self._builder.nodes)
        run = tracer.add_timeline(timeline, label="stream")
        tracer.add_edges(
            [(d, n.index) for n in nodes for d in sorted(n.deps)], run)
        tracer.add_tenant_spans(
            [(nodes[i].task.client or DEFAULT_CLIENT, release[i], end,
              nodes[i].name, i)
             for i, end in sorted(finish.items())],
            run,
        )
        tracer.set_divergence(self.runtime.divergence.table())
        # SLO alert instants (ISSUE 8): one per violating task, at its
        # modeled finish time on the owning tenant's track.
        slo_of = {name: cfg["slo_latency_s"]
                  for name, cfg in self.qos.params()["clients"].items()
                  if cfg.get("slo_latency_s") is not None}
        for i, end in sorted(finish.items()):
            client = nodes[i].task.client or DEFAULT_CLIENT
            objective = slo_of.get(client)
            if objective is None:
                continue
            latency = end - release[i]
            if latency > objective:
                tracer.add_model_instant(
                    "slo_violation", "slo", f"{run}/tenant:{client}", end,
                    args={"task": nodes[i].name, "node": i,
                          "latency_s": latency, "objective_s": objective})

    # -- calibration (ISSUE 10) ----------------------------------------------
    def calibrate(self, **kwargs) -> CalibrationTable:
        """Run the measurement harness over this session's registry and
        runtime (see :func:`repro.core.calibrate.calibrate`), attach the
        resulting table to the runtime (placement and variant dispatch
        use it immediately), and return it.  Extends the session's
        existing table when one is attached."""
        from .calibrate import calibrate as _calibrate

        table = _calibrate(self, table=self.calibration, **kwargs)
        self.calibration = table
        self.runtime.set_calibration(table)
        return table

    def save_calibration(self, path) -> CalibrationTable:
        """Snapshot this session's calibration table — plus the
        runtime's live divergence EMAs — to ``path`` (the one documented
        persistence entry point; the raw divergence-JSON path is
        deprecated).  A session without a table saves one holding just
        the divergence snapshot.  Returns the saved table."""
        table = self.calibration if self.calibration is not None \
            else CalibrationTable()
        table.divergence = self.runtime.divergence.state()
        table.save(path)
        return table

    # -- telemetry (ISSUE 8) -------------------------------------------------
    def start_sampler(self, *, period: float = 0.0,
                      max_samples: int = 4096) -> Sampler:
        """Attach (and start, when ``period > 0``) the background
        telemetry sampler: per-PE occupancy and queue depth, arena
        bytes, pressure counters, link busy fractions, and per-tenant
        window/DRR gauges recorded into :attr:`metrics` on every tick.
        ``period=0`` builds a manual-tick sampler (``sampler.tick()``),
        for deterministic tests.  Idempotent; returns the sampler."""
        if self.sampler is None:
            self.sampler = Sampler(self, period=period,
                                   max_samples=max_samples)
        self.sampler.start()
        return self.sampler

    def metrics_text(self) -> str:
        """This session's metrics in Prometheus text exposition format
        (version 0.0.4) — counters, gauges, and histogram summaries."""
        return metrics_text(self.metrics)

    def serve_metrics(self, *, host: str = "127.0.0.1", port: int = 0):
        """Serve :meth:`metrics_text` over a localhost HTTP endpoint
        (``GET /metrics``).  Returns a :class:`MetricsServer`; call
        ``.close()`` when done.  ``port=0`` picks a free port —
        ``server.url`` has the bound address."""
        return serve_metrics(self.metrics_text, host=host, port=port)

    def export_trace(self, path=None) -> Dict[str, Any]:
        """Export the session's trace as a Perfetto-loadable dict (JSON
        written to ``path`` when given — open it in ui.perfetto.dev).
        Requires the session to have a tracer (``Session(trace=...)``).
        Best called after :meth:`close`; calling earlier synchronizes
        (barrier) and freezes the modeled track group at this point."""
        tracer = self.context.tracer
        if tracer is None:
            raise RuntimeError(
                "session has no tracer — construct with Session(trace=True)"
            )
        if not self.closed:
            self.barrier()
            self._push_trace()
        return tracer.export(path)

    def _check_open(self) -> None:
        if self.closed:
            raise SessionClosedError("session is closed")

    # -- evidence ------------------------------------------------------------
    @property
    def ledger(self):
        """The context's transfer ledger (copy counts, modeled seconds)."""
        return self.context.ledger

    def report(self) -> Dict[str, Any]:
        """Schedule evidence for the stream so far.  ``makespan_model``
        and ``timeline`` come from the deterministic replay
        (:func:`~repro.core.executor.replay_schedule`) — call at a sync
        point (after :meth:`barrier`) for exact, machine-independent
        modeled metrics."""
        return self._stream.report()

    def fairness_report(self, clients: Optional[list] = None) -> Dict[str, Any]:
        """Per-client service/stall/eviction evidence + Jain's index
        over weight-normalized modeled service (see
        :meth:`~repro.core.instrument.TransferLedger.fairness_report`),
        using this session's configured client weights."""
        return self.ledger.fairness_report(weights=self.qos.weights(),
                                           clients=clients)

    def qos_report(self) -> Dict[str, Any]:
        """Deterministic multi-tenant schedule evidence (ISSUE 5).

        Re-simulates the completed stream through
        :func:`~repro.core.qos.fair_replay`: admission itself (windows +
        weighted DRR) is re-enacted in virtual time, so per-task
        ``release``/``finish`` times — and any latency derived from them
        — depend only on each client's own submission order, never on
        wall-clock thread interleaving.  Key per-task times by
        :attr:`BufferFuture.node`.  Call at a sync point (after
        :meth:`barrier`)."""
        timeline, makespan, finish, release = self._stream.replay(
            admission=self.qos)
        with self._sublock:
            client_of = {
                i: (self._builder.nodes[i].task.client or DEFAULT_CLIENT)
                for i in finish
            }
        # Fresh registry per call: qos_report() may be called repeatedly
        # and the replay is a full re-simulation each time — recording
        # into self.metrics would double-count latencies.
        reg = MetricsRegistry()
        lat_by_client: Dict[str, List[float]] = {}
        for i, end in finish.items():
            latency = end - release[i]
            reg.histogram(f"latency_model_s/{client_of[i]}").record(latency)
            lat_by_client.setdefault(client_of[i], []).append(latency)
        percentiles: Dict[str, Dict[str, float]] = {}
        for name, hist in reg.histograms():
            percentiles[name.split("/", 1)[1]] = {
                "p50": hist.percentile(50),
                "p95": hist.percentile(95),
                "p99": hist.percentile(99),
                "mean": hist.mean,
                "count": hist.count,
            }
        # SLO burn rates (ISSUE 8): evaluated over the same deterministic
        # replay latencies — burn > 1 means the error budget is being
        # spent faster than the objective allows.
        qos_params = self.qos.params()
        slo: Dict[str, Dict[str, Any]] = {}
        for name, cfg in qos_params["clients"].items():
            if cfg.get("slo_latency_s") is None:
                continue
            slo[name] = slo_eval(lat_by_client.get(name, []),
                                 cfg["slo_latency_s"],
                                 cfg.get("slo_target") or 0.99)
        return {
            "makespan_model": makespan,
            "timeline": timeline,
            "finish_model": finish,
            "release_model": release,
            "qos": qos_params,
            "fairness": self.fairness_report(),
            "latency_percentiles": percentiles,
            "metrics": self.metrics.snapshot(),
            "divergence": self.runtime.divergence.table(),
            "slo": slo,
        }
