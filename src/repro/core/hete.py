"""``hete_Data`` and the hardware-agnostic memory API (RIMMS §3.2).

This is the paper's contribution, ported to JAX:

* :class:`HeteData` — a logical buffer that owns one materialization per
  :class:`~repro.core.locations.Location` ("resource pointers") and a
  *last-resource flag* naming the location holding the valid bytes.
* :func:`hete_malloc` / :func:`hete_free` / :func:`hete_sync` — the
  hardware-agnostic allocation API.  ``hete_malloc`` reserves an extent in
  the target resource arena through a marking system
  (:mod:`repro.core.allocator`) and exposes a host-resident data field;
  device materializations are created lazily by the runtime at task
  dispatch — and *reserve an arena extent at that point*, so a space's
  ``capacity`` is enforced whenever bytes actually land there.
* :meth:`HeteData.fragment` — O(n) subdivision of one allocation into n
  sub-buffers, each with its *own* last-resource flag, without touching
  the arena (RIMMS §3.2.3). ``hd[i]`` indexes the i-th fragment.

Consistency model (faithful to §3.2.2): a single resource owns each
buffer per API call; the flag is updated only when a task *writes* the
buffer; a task reading a buffer whose flag names another location pulls a
copy directly from that location (no host bounce).  ``tracking="cached"``
additionally remembers read-replicas (a beyond-paper optimization,
benchmarked separately; default is the paper's flag-only behaviour).

Thread safety: each :class:`HeteData` carries a lock serializing
``ensure``/``mark_written`` on that buffer, and arena reservations go
through a context-wide lock — the graph executor stages inputs from a
transfer pool concurrently with PE workers committing outputs.

Capacity pressure (ISSUE 2): device arenas behave like a managed cache
over host memory.  When a reservation cannot be satisfied, the context
selects victims among the space's resident buffers — cost-aware LRU over
an access clock touched on every flag check, never a pinned buffer —
writes dirty bytes back to host *through the existing coherence paths*
(fragment aliasing preserved), frees their extents and retries.
``AllocError`` surfaces only when the pinned working set genuinely
exceeds capacity.  ``pin``/``unpin`` (and the ``pinned`` context
manager) bound eviction; the graph executor additionally *protects*
bytes that queued tasks still read so prefetch never spills them
(prefetch under pressure defers instead — :class:`PrefetchDeferred`).

Buffer↔future lifecycle (ISSUE 4): the streaming session API
(:mod:`repro.core.api`) hands out :class:`BufferFuture` handles over
``hete_Data`` buffers.  ``retain_use``/``release_use`` refcount
submitted-but-incomplete tasks per root allocation, and
``free_when_unused`` is ``hete_free`` deferred to after the last such
use — the session frees buffers the moment the stream no longer touches
them, without the application ever synchronizing.

Per-tenant arena quotas (ISSUE 5): a buffer may carry an ``owner`` (the
session client that allocated it), and :meth:`HeteContext.set_quota`
bounds each tenant's total reserved bytes *per device arena*.  A
reservation that would push its owner over budget first evicts the
owner's own least-valuable resident bytes; when nothing of the tenant's
is evictable the failure is :class:`~repro.core.qos.QuotaExceeded` — an
``AllocError`` scoped to that tenant, leaving the arena (and every other
tenant) untouched.  Because pinned buffers hold arena extents, the quota
is also a pin budget: one tenant can never pin a whole arena.  General
capacity eviction prefers victims whose owner is over quota.

Interconnect topology (ISSUE 3): when the ledger's bandwidth model is a
:class:`~repro.core.topology.TopologyBandwidthModel`, every copy
``stage`` performs is priced and recorded along its *route* — one ledger
entry per hop (store-and-forward), so a device↔device transfer on a
host-bridged platform shows up as two link crossings.  Eviction
write-back likewise chooses the cheapest destination: host, or a **peer
device arena** with free capacity when the interconnect makes the peer
link strictly cheaper (spill-to-peer) — the flag moves to the peer, host
bytes stay stale until synced, and fragment aliasing is preserved
because fragments' host views are never rebound.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import trace as trace_mod
from .allocator import AllocError, Extent, make_allocator
from .instrument import TransferLedger
from .locations import HOST, Location
from .qos import QuotaExceeded

__all__ = [
    "HeteData",
    "MemorySpace",
    "HeteContext",
    "PrefetchDeferred",
    "default_context",
    "hete_malloc",
    "hete_free",
    "hete_sync",
]


class PrefetchDeferred(Exception):
    """Raised inside a :meth:`HeteContext.prefetch_guard` scope when a
    reservation would have to evict pinned or *protected* bytes (bytes a
    queued task still reads).  The graph executor catches it and falls
    back to staging on the PE worker at execute time, when earlier tasks
    have released their claims."""


class MemorySpace:
    """One resource memory region: placement rule + optional arena.

    ``ingest``: host-format (numpy) → this location's representation.
    ``egress``: this location's representation → host numpy.
    For emulated accelerator PEs both are real array movements on this
    box; for mesh locations they are ``jax.device_put`` with a sharding.
    """

    def __init__(
        self,
        location: Location,
        *,
        capacity: Optional[int] = None,
        allocator: str = "nextfit",
        block_size: int = 4096,
        ingest: Optional[Callable[[np.ndarray], Any]] = None,
        egress: Optional[Callable[[Any], np.ndarray]] = None,
        proc_exec: Optional[bool] = None,
    ) -> None:
        self.location = location
        self.arena = (
            make_allocator(allocator, capacity, block_size) if capacity else None
        )
        # id(root) -> root HeteData holding an extent here (eviction pool)
        self.residents: Dict[int, "HeteData"] = {}
        self._ingest = ingest
        self._egress = egress
        # Process-backend eligibility (ISSUE 7): kernels for PEs of this
        # space may run in a subprocess worker only when the space holds
        # host-format (numpy) payloads a worker can map or receive.  A
        # space with a real device ingest (jax.device_put) keeps
        # in-process execution — real devices already run async off the
        # GIL.  Default: eligible iff no custom ingest is installed.
        self.proc_exec = (ingest is None) if proc_exec is None else bool(proc_exec)

    def ingest(self, host_value: np.ndarray) -> Any:
        if self._ingest is None:  # host space: identity
            return host_value
        return self._ingest(host_value)

    def egress(self, value: Any) -> np.ndarray:
        if self._egress is None:
            return np.asarray(value)
        return self._egress(value)


@dataclasses.dataclass
class HeteData:
    """The paper's ``hete_Data``: per-location copies + last-resource flag."""

    shape: tuple
    dtype: np.dtype
    context: "HeteContext"
    last_location: Location = HOST
    # "resource pointers": location -> materialized value
    copies: Dict[Location, Any] = dataclasses.field(default_factory=dict)
    # arena bookkeeping: location -> Extent reserved in that space's arena
    extents: Dict[Location, Extent] = dataclasses.field(default_factory=dict)
    # fragmentation (§3.2.3)
    parent: Optional["HeteData"] = None
    frag_offset: int = 0
    fragments: Optional[List["HeteData"]] = None
    # beyond-paper read-replica cache; faithful mode ignores it
    valid_at: set = dataclasses.field(default_factory=set)
    # capacity-pressure state (kept on the ROOT allocation; fragments
    # delegate): eviction refcounts + access clock per location, and a
    # monotonic eviction epoch (prefetched stagings revalidate against it)
    pins: Dict[Location, int] = dataclasses.field(default_factory=dict)
    last_touch: Dict[Location, int] = dataclasses.field(default_factory=dict)
    eviction_epoch: int = 0
    freed: bool = False
    # buffer↔future lifecycle (ISSUE 4, kept on the ROOT): number of
    # submitted-but-incomplete tasks touching this allocation, and
    # whether a deferred hete_free fires when that count drains
    pending_uses: int = 0
    free_pending: bool = False
    # owning tenant (ISSUE 5): the session client that allocated this
    # buffer — quota accounting and eviction preference key on it
    owner: Optional[str] = None
    # set when a fragment was written since the parent's copy was last
    # coherent — a whole-parent read gathers fragments first (see
    # HeteContext._gather_fragments)
    frag_dirty: bool = False
    lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False, compare=False
    )

    # -- basics -----------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize

    @property
    def data(self) -> np.ndarray:
        """Host-resident data field (transparent access, as in the paper).

        NOTE: reading it without :func:`hete_sync` may observe stale bytes
        if an accelerator holds the valid copy — exactly the hazard
        ``hete_Sync`` exists to resolve.
        """
        return self.copies[HOST]

    def __getitem__(self, i: int) -> "HeteData":
        """Overloaded indexing: after ``fragment()``, ``hd[i]`` is the
        i-th fragment (paper §3.2.3)."""
        if self.fragments is None:
            raise IndexError(
                "hete_Data is not fragmented; call .fragment(nbytes) first"
            )
        return self.fragments[i]

    def __len__(self) -> int:
        return 0 if self.fragments is None else len(self.fragments)

    # -- aliasing (used by the task-graph builder) -------------------------
    @property
    def root(self) -> "HeteData":
        """The top-level allocation this buffer belongs to (self if not a
        fragment)."""
        return self.parent if self.parent is not None else self

    # -- capacity pressure (ISSUE 2) ---------------------------------------
    def pin(self, loc: Location) -> None:
        """Make this buffer's root allocation non-evictable at ``loc``
        (refcounted).  Pinning does not force residency — it only bounds
        eviction while the count is non-zero."""
        self.context.pin(self, loc)

    def unpin(self, loc: Location) -> None:
        self.context.unpin(self, loc)

    def pin_count(self, loc: Location) -> int:
        return self.root.pins.get(loc, 0)

    @contextlib.contextmanager
    def pinned(self, loc: Location):
        """``with hd.pinned(dev): ...`` — eviction-safe scope at ``loc``."""
        self.pin(loc)
        try:
            yield self
        finally:
            self.unpin(loc)

    def byte_interval(self) -> Tuple[int, int]:
        """``[lo, hi)`` byte range inside :attr:`root`'s allocation —
        fragments alias their parent over this interval."""
        if self.parent is None:
            return (0, self.nbytes)
        per_elem = self.nbytes // int(self.shape[0])
        lo = self.frag_offset * per_elem
        return (lo, lo + self.nbytes)

    # -- fragmentation (§3.2.3) --------------------------------------------
    def fragment(self, frag_elems: int) -> List["HeteData"]:
        """Subdivide into fragments of ``frag_elems`` leading elements.

        O(n) in the number of fragments; does NOT touch the arenas (the
        parent's reserved extents simply get logically partitioned), which
        is the paper's point: one search, n usable buffers.

        Each fragment inherits the parent's last-resource flag.  When the
        parent's valid copy lives on a device, fragments also receive a
        sliced view of that device copy, so ``ensure``/``sync`` on a
        fragment resolves to the *current* bytes — never the stale host
        view (see tests/test_hete.py::test_fragment_of_device_parent).
        """
        if self.parent is not None:
            raise ValueError("cannot fragment a fragment")
        total = int(self.shape[0])
        if frag_elems <= 0 or total % frag_elems:
            raise ValueError(
                f"fragment size {frag_elems} must divide leading dim {total}"
            )
        n = total // frag_elems
        host_buf = self.copies[HOST]
        dev_buf = (
            self.copies.get(self.last_location)
            if self.last_location != HOST
            else None
        )
        frags: List[HeteData] = []
        for i in range(n):
            sub = HeteData(
                shape=(frag_elems,) + tuple(self.shape[1:]),
                dtype=self.dtype,
                context=self.context,
                last_location=self.last_location,
                parent=self,
                frag_offset=i * frag_elems,
            )
            # zero-copy host view into the parent buffer
            sub.copies[HOST] = host_buf[i * frag_elems : (i + 1) * frag_elems]
            if dev_buf is not None:
                sub.copies[self.last_location] = dev_buf[
                    i * frag_elems : (i + 1) * frag_elems
                ]
            sub.valid_at = {self.last_location}
            frags.append(sub)
        self.fragments = frags
        self.frag_dirty = False
        return frags


class HeteContext:
    """A RIMMS instance: memory-space registry + ledger + the three APIs."""

    def __init__(
        self,
        ledger: Optional[TransferLedger] = None,
        tracking: str = "flag",  # "flag" (paper-faithful) | "cached" (beyond-paper)
    ) -> None:
        if tracking not in ("flag", "cached"):
            raise ValueError(f"unknown tracking mode {tracking!r}")
        self.tracking = tracking
        # Each context gets an isolated ledger by default so concurrent
        # experiments (reference vs rimms) never share counters.
        self.ledger = ledger if ledger is not None else TransferLedger()
        self.spaces: Dict[Location, MemorySpace] = {HOST: MemorySpace(HOST)}
        self._arena_lock = threading.RLock()
        # -- capacity pressure (ISSUE 2) --
        self._clock = 0  # monotonic access clock (approximate under races)
        # (id(root), loc) -> refcount of queued graph tasks reading those
        # bytes; prefetch staging must not evict them (executor-managed)
        self._protected: Dict[Tuple[int, Location], int] = {}
        # -- per-tenant quotas (ISSUE 5) --
        self._quotas: Dict[str, int] = {}  # owner -> bytes per device arena
        # (owner, loc) -> bytes that owner currently reserves in loc's arena
        self._tenant_bytes: Dict[Tuple[str, Location], int] = {}
        self._tls = threading.local()  # .strict, .spill_s
        # -- shared-memory host arena (ISSUE 7): when attached, malloc
        # places host buffers in a multiprocessing.shared_memory segment
        # so process PE workers map them zero-copy.  None -> heap numpy.
        self.host_arena = None
        # -- tracing (ISSUE 6): off by default; a process-global collector
        # (benchmarks/run.py --trace-dir) captures contexts at creation.
        self.tracer = None
        _global_tracer = trace_mod.global_collector()
        if _global_tracer is not None:
            self.set_tracer(_global_tracer)

    def set_tracer(self, tracer) -> None:
        """Attach a :class:`~repro.core.trace.TraceCollector` (or None to
        detach).  Registers this context with the collector and wires the
        ledger so every recorded copy emits a matching trace event."""
        self.tracer = tracer
        if tracer is None:
            self.ledger.tracer = None
            return
        label = tracer.register_context(self)
        baseline = self.ledger.attach_tracer(tracer, label)
        tracer.set_ledger_baseline(label, baseline)

    def attach_host_arena(self, arena) -> None:
        """Attach a :class:`~repro.core.shm.SharedHostArena`: host buffers
        from :meth:`malloc` (and staging copies routed through
        :meth:`host_zeros`/:meth:`host_copy`) are carved out of the shared
        segment while it has room, falling back to heap numpy when full.
        The arena's lifetime follows this context (GC finalizer unlinks
        the segment); extents free when their arrays are collected."""
        self.host_arena = arena
        if arena is not None:
            self._arena_finalizer = weakref.finalize(self, arena.destroy)

    def host_zeros(self, shape, dtype) -> np.ndarray:
        """A zeroed host buffer — shared-memory backed when possible."""
        if self.host_arena is not None:
            arr = self.host_arena.zeros(shape, dtype)
            if arr is not None:
                return arr
        return np.zeros(shape, dtype=dtype)

    def host_copy(self, value: np.ndarray) -> np.ndarray:
        """A fresh host copy of ``value`` — shared-memory backed when
        possible (the process backend's modeled-device ingest)."""
        if self.host_arena is not None:
            arr = self.host_arena.copy_in(value)
            if arr is not None:
                return arr
        return np.array(value)

    # -- registry ----------------------------------------------------------
    def register_space(self, space: MemorySpace) -> MemorySpace:
        self.spaces[space.location] = space
        return space

    # -- pins / protection (ISSUE 2) ----------------------------------------
    def pin(self, hd: HeteData, loc: Location) -> None:
        root = hd.root
        with self._arena_lock:
            root.pins[loc] = root.pins.get(loc, 0) + 1

    def unpin(self, hd: HeteData, loc: Location) -> None:
        root = hd.root
        with self._arena_lock:
            n = root.pins.get(loc, 0)
            if n <= 0:
                raise ValueError(f"unpin without matching pin at {loc}")
            if n == 1:
                root.pins.pop(loc)
            else:
                root.pins[loc] = n - 1

    # -- per-tenant quotas (ISSUE 5) -----------------------------------------
    def set_quota(self, owner: str, nbytes: Optional[int]) -> None:
        """Bound ``owner``'s reserved bytes in *each* device arena to
        ``nbytes`` (None lifts the bound).  Applies to future
        reservations; bytes already resident are not evicted eagerly, but
        an over-quota tenant becomes the preferred eviction victim."""
        with self._arena_lock:
            if nbytes is None:
                self._quotas.pop(owner, None)
            else:
                self._quotas[owner] = int(nbytes)

    def quota_of(self, owner: str) -> Optional[int]:
        with self._arena_lock:
            return self._quotas.get(owner)

    def tenant_bytes(self, owner: str, loc: Location) -> int:
        """Bytes ``owner`` currently reserves in ``loc``'s arena."""
        with self._arena_lock:
            return self._tenant_bytes.get((owner, loc), 0)

    def _tenant_charge(self, root: HeteData, loc: Location,
                       sign: int) -> None:
        """Track per-tenant reserved bytes at extent create (+1) /
        release (-1).  Called under the arena lock."""
        if root.owner is None:
            return
        key = (root.owner, loc)
        n = self._tenant_bytes.get(key, 0) + sign * root.nbytes
        if n <= 0:
            self._tenant_bytes.pop(key, None)
        else:
            self._tenant_bytes[key] = n

    def _over_quota(self, owner: Optional[str], loc: Location) -> bool:
        if owner is None:
            return False
        q = self._quotas.get(owner)
        return (q is not None
                and self._tenant_bytes.get((owner, loc), 0) > q)

    # -- buffer↔future lifecycle (ISSUE 4) -----------------------------------
    def retain_use(self, hd: HeteData) -> None:
        """Count one submitted-but-incomplete task touching ``hd``'s root
        allocation.  The streaming session retains every distinct input/
        output root at submission and releases it at task completion, so
        a deferred free (:meth:`free_when_unused`) can never reclaim
        bytes an in-flight task still reads or writes."""
        with self._arena_lock:
            hd.root.pending_uses += 1

    def release_use(self, hd: HeteData) -> None:
        """Balance one :meth:`retain_use`; fires the deferred free when
        this was the last in-flight use of a buffer already marked via
        :meth:`free_when_unused`."""
        root = hd.root
        with self._arena_lock:
            if root.pending_uses <= 0:
                raise ValueError("release_use without matching retain_use")
            root.pending_uses -= 1
            if (root.free_pending and root.pending_uses == 0
                    and not root.freed):
                root.free_pending = False
                self.free(root)

    def free_when_unused(self, hd: HeteData) -> bool:
        """``hete_Free`` deferred to after the last in-flight use: frees
        immediately (returning True) when no submitted task still touches
        the root allocation, otherwise arms a deferred free that the
        final :meth:`release_use` performs (returning False)."""
        root = hd.root
        with self._arena_lock:
            if root.freed:
                raise AllocError("double hete_free")
            if root.pending_uses > 0:
                root.free_pending = True
                return False
            self.free(root)
            return True

    def protect(self, hd: HeteData, loc: Location) -> None:
        """Refcounted *soft* claim: a queued task still reads these bytes
        at ``loc``.  Prefetch-triggered eviction (inside
        :meth:`prefetch_guard`) refuses protected victims; demand staging
        on a PE worker may still evict them (the reader re-fetches)."""
        key = (id(hd.root), loc)
        with self._arena_lock:
            self._protected[key] = self._protected.get(key, 0) + 1

    def unprotect(self, hd: HeteData, loc: Location) -> None:
        key = (id(hd.root), loc)
        with self._arena_lock:
            n = self._protected.get(key, 0)
            if n <= 1:
                self._protected.pop(key, None)
            else:
                self._protected[key] = n - 1

    @contextlib.contextmanager
    def prefetch_guard(self):
        """Scope for speculative staging (the executor's transfer pool):
        a reservation that would have to evict pinned or protected bytes
        raises :class:`PrefetchDeferred` instead of spilling them."""
        prev = getattr(self._tls, "strict", False)
        self._tls.strict = True
        try:
            yield self
        finally:
            self._tls.strict = prev

    def take_spill_seconds(self) -> float:
        """Modeled eviction write-back seconds accumulated by THIS thread
        since the last call (spill-stall attribution for the Timeline)."""
        s = getattr(self._tls, "spill_s", 0.0)
        self._tls.spill_s = 0.0
        return s

    def _spill_add(self, seconds: float) -> None:
        self._tls.spill_s = getattr(self._tls, "spill_s", 0.0) + seconds

    # -- routed copy accounting (ISSUE 3) ------------------------------------
    def record_copy(self, src: Location, dst: Location, nbytes: int) -> float:
        """Ledger-record one logical copy along its route and return the
        modeled seconds it costs.  Scalar bandwidth model: one direct
        (src, dst) entry.  Topology model: one entry per hop of the
        cheapest route (store-and-forward), each priced at that link's
        service time — the per-link traffic matrix falls out of the
        ledger's (src, dst) counters."""
        bw = self.ledger.bandwidth_model
        hops = bw.hops(src, dst)
        if hops is None:
            self.ledger.record(src, dst, nbytes)
            return bw.seconds(src, dst, nbytes)
        total = 0.0
        for link in hops:
            s = link.seconds(nbytes)
            self.ledger.record(link.src, link.dst, nbytes, seconds=s)
            total += s
        return total

    def _log_move(self, src: Location, dst: Location, nbytes: int) -> None:
        """Append one performed copy to THIS thread's move log (drained
        by :meth:`take_moves`) — the executor feeds these into the
        contention-aware schedule replay."""
        moves = getattr(self._tls, "moves", None)
        if moves is not None:
            moves.append((src, dst, nbytes))

    def take_moves(self) -> List[Tuple[Location, Location, int]]:
        """Drain (and re-arm) this thread's move log."""
        out = getattr(self._tls, "moves", None) or []
        self._tls.moves = []
        return out

    def _touch(self, root: HeteData, loc: Location) -> None:
        # Approximate LRU clock: racy increments lose ticks, which only
        # coarsens victim order — never correctness.
        self._clock += 1
        root.last_touch[loc] = self._clock

    # -- the three hardware-agnostic APIs (§3.2.1) ---------------------------
    def malloc(
        self,
        shape: Union[int, Sequence[int]],
        dtype: Any = np.uint8,
        *,
        spaces: Sequence[Location] = (),
        owner: Optional[str] = None,
    ) -> HeteData:
        """``hete_Malloc``: host buffer + arena reservations in ``spaces``.

        The user only names a size; which resource memories get extents is
        decided by the runtime (here: the ``spaces`` the embedding runtime
        passes — app code never does).  ``owner`` names the tenant the
        allocation is charged to (per-tenant quotas, ISSUE 5).
        """
        if isinstance(shape, (int, np.integer)):
            shape = (int(shape),)
        shape = tuple(int(s) for s in shape)
        hd = HeteData(shape=shape, dtype=np.dtype(dtype), context=self,
                      owner=owner)
        hd.copies[HOST] = self.host_zeros(shape, dtype)
        hd.valid_at = {HOST}
        for loc in spaces:
            self._reserve(hd, loc)
        return hd

    def free(self, hd: HeteData) -> None:
        """``hete_Free``: release every resource pointer + arena extent."""
        if hd.freed:
            raise AllocError("double hete_free")
        if hd.parent is not None:
            raise ValueError("free the parent allocation, not a fragment")
        if hd.fragments:
            for f in hd.fragments:
                f.copies.clear()
                f.freed = True
            hd.fragments = None
        with self._arena_lock:
            for loc, ext in hd.extents.items():
                space = self.spaces[loc]
                if space.arena is not None:
                    space.arena.free(ext)
                    self._tenant_charge(hd, loc, -1)
                space.residents.pop(id(hd), None)
            hd.extents.clear()
            hd.pins.clear()
        hd.copies.clear()
        hd.valid_at.clear()
        hd.freed = True

    def sync(self, hd: HeteData) -> np.ndarray:
        """``hete_Sync``: make the host copy current; return it."""
        return self.ensure(hd, HOST)

    # -- arena accounting ---------------------------------------------------
    def _reserve(self, hd: HeteData, loc: Location) -> None:
        """Reserve an extent for ``hd``'s root allocation in ``loc``'s
        arena on first materialization there (no-op for spaces without a
        capacity arena).  Fragments charge their parent's full extent —
        one arena search covers all n fragments (§3.2.3).

        Under pressure this is the evict-retry loop (ISSUE 2): each
        failed allocation evicts one victim (cost-aware LRU) and retries;
        ``AllocError`` surfaces only when nothing is evictable — i.e. the
        pinned (or, inside :meth:`prefetch_guard`, pinned+protected)
        working set genuinely exceeds capacity.

        Per-tenant quotas (ISSUE 5): a reservation that would push the
        owner over its arena budget first evicts the owner's *own*
        resident buffers; with nothing of the tenant's evictable it
        raises :class:`~repro.core.qos.QuotaExceeded` — scoped to the
        tenant, other tenants keep allocating."""
        root = hd.root
        space = self.spaces[loc]
        if space.arena is None:
            return
        with self._arena_lock:
            if loc in root.extents:
                return
            stalled = False
            skip: set = set()  # victims whose eviction failed (in use)
            owner = root.owner
            quota = self._quotas.get(owner) if owner is not None else None
            while True:
                if (quota is not None
                        and self._tenant_bytes.get((owner, loc), 0)
                        + root.nbytes > quota):
                    victim = self._select_victim(space, loc, exclude=root,
                                                 skip=skip, tenant=owner)
                    if victim is None:
                        if getattr(self._tls, "strict", False):
                            self.ledger.record_prefetch_deferral()
                            if self.tracer is not None:
                                self.tracer.instant(
                                    "prefetch_deferred", "memory",
                                    f"mem:{loc}",
                                    {"reason": "quota", "owner": owner,
                                     "nbytes": root.nbytes})
                            raise PrefetchDeferred(
                                f"prefetch to {loc} deferred: tenant "
                                f"{owner!r} is at quota with no evictable "
                                f"bytes of its own"
                            )
                        raise QuotaExceeded(
                            f"tenant {owner!r} quota exhausted at {loc}: "
                            f"{self._tenant_bytes.get((owner, loc), 0)} B "
                            f"reserved of {quota} B budget, cannot add "
                            f"{root.nbytes} B (shape={root.shape}); other "
                            f"tenants are unaffected",
                            tenant=owner, location=loc,
                        )
                    if not stalled:
                        stalled = True
                        self.ledger.record_spill_stall()
                    if not self._evict_locked(victim, loc):
                        skip.add(id(victim))  # in active use; try others
                    continue
                try:
                    ext = space.arena.alloc(root.nbytes, tag=id(root))
                except AllocError as e:
                    victim = self._select_victim(space, loc, exclude=root,
                                                 skip=skip)
                    if victim is None:
                        if getattr(self._tls, "strict", False):
                            self.ledger.record_prefetch_deferral()
                            if self.tracer is not None:
                                self.tracer.instant(
                                    "prefetch_deferred", "memory",
                                    f"mem:{loc}",
                                    {"reason": "capacity",
                                     "nbytes": root.nbytes})
                            raise PrefetchDeferred(
                                f"prefetch to {loc} deferred: reserving "
                                f"{root.nbytes} B would evict pinned or "
                                f"still-queued bytes"
                            ) from e
                        pinned = sum(
                            r.nbytes for r in space.residents.values()
                            if r.pins.get(loc, 0) > 0
                        )
                        raise AllocError(
                            f"memory space {loc} exhausted: cannot reserve "
                            f"{root.nbytes} B for buffer shape={root.shape} "
                            f"({space.arena.free_bytes} B free of "
                            f"{space.arena.capacity} B, {pinned} B pinned, "
                            f"nothing evictable): {e}"
                        ) from e
                    if not stalled:
                        stalled = True
                        self.ledger.record_spill_stall()
                    if not self._evict_locked(victim, loc):
                        skip.add(id(victim))  # in active use; try others
                    continue
                root.extents[loc] = ext
                space.residents[id(root)] = root
                self._tenant_charge(root, loc, +1)
                self._touch(root, loc)
                return

    # -- eviction engine (ISSUE 2) -------------------------------------------
    def _select_victim(self, space: MemorySpace, loc: Location,
                       exclude: HeteData,
                       skip: frozenset = frozenset(),
                       tenant: Optional[str] = None) -> Optional[HeteData]:
        """Cost-aware LRU victim pick, called under the arena lock.

        Candidates: resident roots that are not the buffer being
        reserved, not pinned, and — inside :meth:`prefetch_guard` — not
        protected by a queued reader.  A candidate whose lock is held by
        another thread is in active use and skipped (non-blocking probe,
        which also makes eviction deadlock-free).  Order: buffers whose
        owner is over its tenant quota first (ISSUE 5), then least
        recent access; ties broken by the modeled cost of the round trip
        the eviction causes (write-back now if dirty + re-fetch later),
        normalized per byte freed, then by id for determinism.

        ``tenant`` restricts candidates to that owner's buffers — the
        quota-enforcement path evicts only the over-budget tenant's own
        bytes, never another tenant's.
        """
        strict = getattr(self._tls, "strict", False)
        bw = self.ledger.bandwidth_model
        best, best_key = None, None
        for rid, cand in space.residents.items():
            if cand is exclude.root or rid in skip or cand.pins.get(loc, 0) > 0:
                continue
            if tenant is not None and cand.owner != tenant:
                continue
            if strict and self._protected.get((rid, loc), 0) > 0:
                continue
            dirty = self._dirty_bytes(cand, loc)
            cost_s = bw.seconds(HOST, loc, cand.nbytes)
            if dirty:
                # Write-back goes to the *cheapest* destination this
                # victim could spill to (host, or a peer arena with
                # room) — rank victims by the cost eviction really pays.
                _, wb_s = self._writeback_target(cand, loc, dirty)
                cost_s += wb_s
            key = (0 if self._over_quota(cand.owner, loc) else 1,
                   cand.last_touch.get(loc, 0), cost_s / max(cand.nbytes, 1),
                   rid)
            if best_key is None or key < best_key:
                best, best_key = cand, key
        return best

    def _writeback_target(
        self, root: HeteData, loc: Location, dirty: int
    ) -> Tuple[Location, float]:
        """Cheapest destination for ``root``'s dirty bytes when evicted
        from ``loc``: host, or a peer device arena that (a) the
        interconnect reaches strictly cheaper than host and (b) can take
        the root's full extent *without evicting anything itself* (no
        cascades).  Peers are considered only when a topology is active
        — under the scalar default model eviction stays host-bound, so
        pre-topology baselines and semantics hold exactly.  Called under
        the arena lock.  Returns ``(target, modeled write-back
        seconds)``."""
        bw = self.ledger.bandwidth_model
        best, best_s = HOST, bw.seconds(loc, HOST, dirty)
        if getattr(bw, "topology", None) is None:
            return best, best_s
        from .topology import TopologyError

        quota = (self._quotas.get(root.owner)
                 if root.owner is not None else None)
        for ploc, pspace in self.spaces.items():
            if ploc == loc or ploc == HOST or pspace.arena is None:
                continue
            if ploc not in root.extents:
                if pspace.arena.largest_free() < root.nbytes:
                    continue
                # Never let the runtime's own eviction path push the
                # owner over its budget in the peer arena (ISSUE 5):
                # spilling there would reserve a fresh extent.
                if (quota is not None
                        and self._tenant_bytes.get((root.owner, ploc), 0)
                        + root.nbytes > quota):
                    continue
            try:
                s = bw.seconds(loc, ploc, dirty)
            except TopologyError:  # unreachable in this topology
                continue
            if s < best_s:
                best, best_s = ploc, s
        return best, best_s

    def _spill_to_peer(
        self, root: HeteData, loc: Location, peer: Location
    ) -> Optional[float]:
        """Move ``root``'s dirty bytes from ``loc`` directly to ``peer``
        (device→device spill, ISSUE 3): reserve the root's extent in the
        peer arena (never evicting — pre-checked by
        :meth:`_writeback_target`), copy each dirty owner's bytes across
        the peer link, and move its flag to ``peer``.  Host bytes are
        untouched (still stale) and fragments' zero-copy host views stay
        aliased.  Called under the arena lock with every owner lock
        held.  Returns modeled write-back seconds, or ``None`` when the
        spill cannot proceed (caller falls back to host write-back)."""
        space, pspace = self.spaces[loc], self.spaces[peer]
        owners = [root] + list(root.fragments or ())
        dirty_owners = [o for o in owners if o.last_location == loc]
        if not dirty_owners or any(loc not in o.copies for o in dirty_owners):
            return None
        if peer not in root.extents:
            try:
                ext = pspace.arena.alloc(root.nbytes, tag=id(root))
            except AllocError:
                return None
            root.extents[peer] = ext
            pspace.residents[id(root)] = root
            self._tenant_charge(root, peer, +1)
        wb_s = 0.0
        if root.last_location == loc:
            # The parent's loc copy is current for every loc-flagged
            # interval: ONE whole-parent transfer covers root and
            # fragments alike; fragments get zero-copy slices of the
            # peer buffer (the shape _propagate_to_fragments produces).
            moved = pspace.ingest(space.egress(root.copies[loc]))
            root.copies[peer] = moved
            root.last_location = peer
            root.valid_at.add(peer)
            wb_s += self.record_copy(loc, peer, root.nbytes)
            if root.fragments:
                step = int(root.fragments[0].shape[0])
                for i, frag in enumerate(root.fragments):
                    if frag.last_location == loc:
                        frag.copies[peer] = moved[i * step:(i + 1) * step]
                        frag.last_location = peer
                        frag.valid_at.add(peer)
        else:
            # Fragments own the flag and hold their own device arrays:
            # spill each dirty fragment individually.
            for o in dirty_owners:
                o.copies[peer] = pspace.ingest(space.egress(o.copies[loc]))
                o.last_location = peer
                o.valid_at.add(peer)
                wb_s += self.record_copy(loc, peer, o.nbytes)
        self._touch(root, peer)
        return wb_s

    @staticmethod
    def _dirty_bytes(root: HeteData, loc: Location) -> int:
        """Bytes at ``loc`` not yet reflected in the host copy."""
        if root.fragments:
            return sum(f.nbytes for f in root.fragments
                       if f.last_location == loc)
        return root.nbytes if root.last_location == loc else 0

    def _evict_locked(self, root: HeteData, loc: Location) -> bool:
        """Evict ``root`` from ``loc``: write dirty bytes back to the
        cheapest destination — host through the normal coherence paths,
        or directly into a peer device arena when the interconnect makes
        that strictly cheaper and the peer has room (spill-to-peer,
        ISSUE 3) — then drop the materializations and free the extent.
        Fragment aliasing is preserved on both paths.  Called under the
        arena lock; probes the buffer locks (root + every fragment)
        without blocking — a contended lock means the buffer is in
        active use by another thread, so the caller skips this victim.
        The probe is what keeps eviction deadlock-free: no thread ever
        blocks on a buffer lock while holding the arena lock."""
        held = []
        for owner in [root] + list(root.fragments or ()):
            if not owner.lock.acquire(blocking=False):
                for h in held:
                    h.lock.release()
                return False
            held.append(owner)
        try:
            space = self.spaces[loc]
            ext = root.extents.get(loc)
            if ext is None:
                space.residents.pop(id(root), None)
                return False
            dirty = self._dirty_bytes(root, loc)
            wb_s, target = 0.0, HOST
            if dirty:
                target, _ = self._writeback_target(root, loc, dirty)
                # Write-back copies are spill cost, not staging traffic:
                # keep them out of this thread's move log (they are
                # accounted through spill_s / the ledger instead).
                moves = getattr(self._tls, "moves", None)
                mark = len(moves) if moves is not None else 0
                if target != HOST:
                    spilled = self._spill_to_peer(root, loc, target)
                    if spilled is None:  # peer filled up meanwhile
                        target = HOST
                    else:
                        wb_s = spilled
                if target == HOST:
                    # stage() makes the host bytes current — a direct
                    # loc→host copy, or a per-fragment gather when
                    # fragments own the flag — recording the copies in
                    # the ledger as usual.
                    self.stage(root, HOST)
                    wb_s = self.ledger.bandwidth_model.seconds(loc, HOST, dirty)
                if moves is not None:
                    del moves[mark:]
                self._spill_add(wb_s)
            # Move flags off the doomed materialization (eviction is the
            # one sanctioned flag move outside mark_written — the
            # write-back target becomes the owning resource; peer-spilled
            # owners were re-flagged inside _spill_to_peer).  HOST joins
            # valid_at only when a host write-back actually made it
            # current: a clean replica evicted while a *third* location
            # owns the flag must not resurrect a stale host copy
            # (cached tracking).
            if root.last_location == loc:
                root.last_location = HOST
            root.valid_at.discard(loc)
            if dirty and target == HOST:
                root.valid_at.add(HOST)
            root.copies.pop(loc, None)
            for frag in root.fragments or ():
                if frag.last_location == loc:
                    frag.last_location = HOST
                frag.valid_at.discard(loc)
                if dirty and target == HOST:
                    frag.valid_at.add(HOST)
                frag.copies.pop(loc, None)
            space.arena.free(ext)
            del root.extents[loc]
            space.residents.pop(id(root), None)
            self._tenant_charge(root, loc, -1)
            root.eviction_epoch += 1
            self.ledger.record_eviction(loc, root.nbytes, dirty, wb_s,
                                        target=target, owner=root.owner)
            if self.tracer is not None:
                spilled = (target is not None and target.kind != "host"
                           and dirty > 0)
                self.tracer.instant(
                    "spill_to_peer" if spilled else "evict", "memory",
                    f"mem:{loc}",
                    {"nbytes": root.nbytes, "dirty_bytes": dirty,
                     "writeback_s": wb_s, "target": str(target),
                     "owner": root.owner})
            return True
        finally:
            for h in held:
                h.lock.release()

    def evict(self, hd: HeteData, loc: Location) -> bool:
        """Explicitly evict ``hd``'s root allocation from ``loc`` (tests /
        manual spill).  Returns False if not resident, pinned, or in use."""
        root = hd.root
        with self._arena_lock:
            if root.pins.get(loc, 0) > 0 or loc not in root.extents:
                return False
            return self._evict_locked(root, loc)

    # -- runtime-internal protocol (§3.2.2) ----------------------------------
    def ensure(self, hd: HeteData, dst: Location) -> Any:
        """Last-resource-flag check + (only if needed) a direct copy.

        This is the 1–2 cycle check the paper measures: one flag compare
        per input. A copy is issued only when the flag names another
        location, and it goes *directly* src→dst (Fig 1b), never via host.
        """
        return self.stage(hd, dst)[0]

    def stage(self, hd: HeteData, dst: Location) -> Tuple[Any, float]:
        """:meth:`ensure` + report of the modeled seconds of the copy it
        performed (0.0 on a flag hit).  The graph executor uses the
        second element for schedule simulation."""
        self.ledger.record_flag_check()
        if hd.freed:
            raise AllocError("use after hete_free")
        # Lock-free fast path for the flag hit — the 1–2 cycle check the
        # paper measures (§5.2.2) must not pay a lock.  Safe because the
        # task graph orders writers against readers: the flag cannot move
        # concurrently with this read.
        if hd.last_location == dst and not (hd.fragments and hd.frag_dirty):
            # .get(): eviction (which holds hd.lock, not taken here) may
            # have moved the flag between the check and the read — fall
            # through to the locked slow path, which re-stages.
            value = hd.copies.get(dst)
            if value is not None:
                if dst != HOST:
                    self._touch(hd.root, dst)  # access clock: LRU evidence
                return value, 0.0
        with hd.lock:
            if hd.fragments and hd.frag_dirty:
                self._gather_fragments(hd)
            src = hd.last_location
            if dst == src:
                if dst != HOST:
                    self._touch(hd.root, dst)
                return hd.copies[dst], 0.0
            if self.tracking == "cached" and dst in hd.valid_at and dst in hd.copies:
                if dst != HOST:
                    self._touch(hd.root, dst)
                return hd.copies[dst], 0.0
            if dst != HOST:
                self._reserve(hd, dst)
            value = hd.copies[src]
            host_np = self.spaces[src].egress(value) if src != HOST else value
            if dst == HOST and (hd.parent is not None or hd.fragments):
                # preserve the zero-copy host views linking parent and
                # fragments (rebinding would orphan them)
                np.copyto(hd.copies[HOST], np.asarray(host_np).reshape(hd.shape))
                moved = hd.copies[HOST]
            else:
                moved = self.spaces[dst].ingest(host_np) if dst != HOST else host_np
                hd.copies[dst] = moved
            hd.valid_at.add(dst)
            if dst != HOST:
                self._touch(hd.root, dst)
            tr_s = self.record_copy(src, dst, hd.nbytes)
            self._log_move(src, dst, hd.nbytes)
            return moved, tr_s

    def mark_written(self, hd: HeteData, loc: Location, value: Any) -> None:
        """A task on ``loc`` produced ``value`` into ``hd`` (output flag
        update, §3.2.2 — the *only* place the flag moves).

        Parent/fragment coherence: writing a fragmented parent propagates
        sliced copies + the flag to every fragment; writing a fragment
        marks its parent dirty, so a later whole-parent read gathers the
        fragments' bytes first (the task graph supplies the ordering,
        this supplies the data).
        """
        if hd.freed:
            raise AllocError("use after hete_free")
        with hd.lock:
            if loc == HOST and (hd.parent is not None or hd.fragments):
                # preserve the zero-copy host views linking parent and
                # fragments (rebinding would orphan them)
                np.copyto(hd.copies[HOST], np.asarray(value).reshape(hd.shape))
            else:
                if loc != HOST:
                    self._reserve(hd, loc)
                hd.copies[loc] = value
            hd.last_location = loc
            hd.valid_at = {loc}
            if loc != HOST:
                self._touch(hd.root, loc)
            if hd.parent is not None:
                hd.parent.frag_dirty = True
            if hd.fragments:
                self._propagate_to_fragments(hd, loc)
                hd.frag_dirty = False

    def _propagate_to_fragments(self, hd: HeteData, loc: Location) -> None:
        """A whole-parent write supersedes every fragment: move their
        flags to ``loc`` and hand each a slice of the new value (host
        views already alias the parent buffer)."""
        value = hd.copies[loc]
        step = int(hd.fragments[0].shape[0])
        for i, frag in enumerate(hd.fragments):
            with frag.lock:
                frag.last_location = loc
                frag.valid_at = {loc}
                if loc != HOST:
                    frag.copies[loc] = value[i * step : (i + 1) * step]

    def _gather_fragments(self, hd: HeteData) -> None:
        """Make a fragmented parent's host copy current by syncing every
        fragment through its zero-copy host view (direct device→host
        copies, recorded in the ledger), then flag the parent at HOST.
        Called under ``hd.lock`` before a whole-parent read."""
        for frag in hd.fragments:
            self.ensure(frag, HOST)
        hd.last_location = HOST
        hd.valid_at = {HOST}
        hd.frag_dirty = False


#: default module-level context, mirroring the paper's single-runtime setup
default_context = HeteContext()


def hete_malloc(shape, dtype=np.uint8, *, context: Optional[HeteContext] = None,
                spaces: Sequence[Location] = ()) -> HeteData:
    return (context or default_context).malloc(shape, dtype, spaces=spaces)


def hete_free(hd: HeteData, *, context: Optional[HeteContext] = None) -> None:
    (context or hd.context or default_context).free(hd)


def hete_sync(hd: HeteData, *, context: Optional[HeteContext] = None) -> np.ndarray:
    return (context or hd.context or default_context).sync(hd)
