"""Async task executors: persistent per-PE workers, prefetch, HEFT-lite.

Two engines share one persistent :class:`WorkerPool` (one worker thread
per PE plus a transfer pool, owned by the
:class:`~repro.core.runtime.Runtime`, reused across runs — ISSUE 2):

* :class:`StreamExecutor` — **streaming admission** (ISSUE 4): the
  engine behind the primary :class:`repro.core.api.Session` API.  Tasks
  are admitted one at a time as the application submits them and the
  pool consumes the stream continuously — a task dispatches the moment
  its dependencies complete, placement is a **windowed HEFT** over the
  ready frontier (upward ranks recomputed over the admitted, incomplete
  window), there is no global barrier, and a failing task fails only its
  dependent subtree (futures carry the cause) while independent chains
  keep flowing.
* :class:`GraphExecutor` — batch intake for the
  :meth:`~repro.core.runtime.Runtime.run_graph` compat wrapper: takes a
  whole task list, runs it to completion, and tears the run down on the
  first failure (nothing commits after an error).

Shared mechanics (both engines):

* **input prefetch**: the moment a task's dependencies complete, its
  input staging (``hete_Data`` flag checks + src→PE copies) is submitted
  to the transfer pool, so the copy overlaps whatever the target PE is
  still computing — the paper's §3.2.2 premise (the runtime knows where
  valid bytes live) finally buys wall-clock, not just copy counts;
* **topology-aware prefetch ordering** (ISSUE 4 satellite): when a
  batch of tasks becomes ready together under an interconnect topology,
  their prefetch stagings are issued least-contended-route-first —
  transfers whose routes are free start warming immediately instead of
  queueing behind a busy shared link;
* **capacity-aware prefetch** (ISSUE 2): inputs of every scheduled-but-
  incomplete task are *protected* in the :class:`HeteContext`; prefetch
  staging runs under the context's prefetch guard, so it never evicts
  bytes a queued task still reads — if a reservation would require that,
  the prefetch defers (:class:`~repro.core.hete.PrefetchDeferred`).
  Prefetch is pin-free *speculative warming*: the PE worker re-stages
  authoritatively (with hard pins) before executing — a free flag hit
  when the warmed bytes survived, a demand fetch otherwise — so
  concurrent prefetches can never pin an arena full and starve a
  worker's reservation;
* scheduling: ``round_robin`` (static, bit-identical to serial dispatch),
  ``data_affinity`` (dynamic, flag-aware), or ``heft`` — a HEFT
  list scheduler that ranks ready tasks by upward rank and places each
  with an **insertion-based slot search** (ISSUE 3): a task may slide
  into an idle gap on a PE's modeled timeline left by earlier
  placements, not just append after the last one.  Costs come from the
  bandwidth model — routed and **contention-aware** when the context
  uses a :class:`~repro.core.topology.TopologyBandwidthModel` — and the
  online :class:`~repro.core.graph.CostModel`;
* **deterministic replay** (:func:`replay_schedule`): modeled makespans
  and Gantt lanes are produced by re-simulating the executed schedule in
  (ready-time, submission-index) order — per-link busy-until contention
  applied when a topology is active — so gated metrics stay exact across
  runs even though worker wall-clock interleaving varies.

Because every PE here is emulated on one physical CPU, the *measured*
wall clock understates the win; the executors therefore also simulate
the schedule they actually executed (modeled transfer + spill-stall
seconds + static compute estimates) and report a modeled makespan,
directly comparable to the serial :meth:`Runtime.run` modeled makespan.
"""

from __future__ import annotations

import bisect
import heapq
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import (Any, Callable, Dict, List, Optional, Sequence, Set,
                    Tuple, TYPE_CHECKING)

from .graph import TaskGraph, TaskNode, build_graph
from .hete import PrefetchDeferred
from .instrument import Timeline, TimelineEvent, TransferEvent

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids import cycle
    from .runtime import PE, Runtime, Task

__all__ = ["GraphExecutor", "StreamExecutor", "WorkerPool", "insert_slot",
           "replay_schedule"]

_SHUTDOWN = None


def insert_slot(busy: List[Tuple[float, float]], earliest: float,
                duration: float) -> float:
    """HEFT insertion-based slot search: the earliest start ≥ ``earliest``
    at which ``duration`` fits into the sorted busy-interval list — an
    idle gap between existing placements, or after the last one.
    ``busy`` intervals may abut but never overlap (they are produced by
    :func:`commit_slot`)."""
    t = earliest
    for s, e in busy:
        if t + duration <= s:
            break  # fits entirely in the gap before this interval
        t = max(t, e)
    return t


def commit_slot(busy: List[Tuple[float, float]], start: float,
                duration: float) -> None:
    """Reserve ``[start, start+duration)`` in the sorted interval list."""
    bisect.insort(busy, (start, start + duration))


class WorkerPool:
    """Persistent per-PE worker threads + transfer pool (ISSUE 2).

    Lives on the :class:`Runtime` and is reused by every run —
    batch ``run_graph`` calls and streaming sessions alike; each queue
    item is ``(executor_run, payload)`` so the same threads serve
    successive runs.  ``shutdown`` is only needed for explicit teardown —
    threads are daemons.
    """

    def __init__(self, pes: Sequence["PE"]) -> None:
        self.pe_names = tuple(pe.name for pe in pes)
        self.closed = False
        self.queues: Dict[str, "queue.Queue"] = {
            pe.name: queue.Queue() for pe in pes
        }
        # Per-PE busy flags (ISSUE 8): set by the worker loop around each
        # payload so the telemetry sampler can read occupancy without
        # touching the queues.  Plain dict writes — sampling tolerates a
        # stale read; the hot path takes no lock.
        self.active: Dict[str, bool] = {pe.name: False for pe in pes}
        self.transfer = ThreadPoolExecutor(
            max_workers=max(2, len(pes)), thread_name_prefix="rimms-xfer",
        )
        self.runs_served = 0
        self._threads = [
            threading.Thread(
                target=self._loop, args=(pe,), name=f"rimms-{pe.name}",
                daemon=True,
            )
            for pe in pes
        ]
        for t in self._threads:
            t.start()

    def submit(self, run, pe_name: str, payload) -> None:
        self.queues[pe_name].put((run, payload))

    def _loop(self, pe: "PE") -> None:
        q = self.queues[pe.name]
        while True:
            item = q.get()
            if item is _SHUTDOWN:
                return
            run, payload = item
            self.active[pe.name] = True
            try:
                run._process(pe, payload)
            finally:
                self.active[pe.name] = False

    def drain(self, run) -> list:
        """Pop every queued payload belonging to ``run`` (run teardown;
        no other run is active on this pool by construction)."""
        out = []
        for q in self.queues.values():
            while True:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    break
                if item is _SHUTDOWN:
                    q.put(item)  # preserve shutdown signal
                    break
                if item[0] is run:
                    out.append(item[1])
                else:  # pragma: no cover - defensive; runs never overlap
                    q.put(item)
                    break
        return out

    def shutdown(self) -> None:
        self.closed = True
        for q in self.queues.values():
            q.put(_SHUTDOWN)
        # Join so no daemon thread is left inside a JAX/XLA call at
        # interpreter teardown (std::terminate on some builds).
        for t in self._threads:
            t.join(timeout=5.0)
        self.transfer.shutdown(wait=True)


def _reap_future(fut: Optional[Future]) -> None:
    """Cancel an abandoned prefetch future, or — if it already started —
    wait and swallow its outcome so staging errors are never left
    unretrieved.  Prefetch staging is pin-free speculative warming, so
    there is nothing else to release."""
    if fut is not None and not fut.cancel():
        try:
            fut.exception()
        except BaseException:
            pass


def _execute_task(rt: "Runtime", task: "Task", pe: "PE",
                  fut: Optional[Future]) -> tuple:
    """Authoritative execution of one task on its PE worker thread:
    validate/reuse the speculative prefetch staging (pin first, then
    check eviction epochs), fall back to pinned demand staging, run the
    kernel, commit outputs, release pins.  Returns
    ``(w0, w1, tr_s, spill_s, comp_s, out_s, moves)`` — wall bounds plus
    the modeled accounting both executors feed their schedule
    simulations."""
    tracer = rt.context.tracer
    w0 = time.perf_counter()
    pre = fut.result() if fut is not None else None
    loc = pe.location
    staged = None
    if pre is not None:
        # Pin first, then validate: once pinned the inputs cannot be
        # evicted, so unchanged eviction epochs prove the prefetched
        # staging is still current.
        pre_staged, epochs = pre
        rt._pin_inputs(task, loc)
        if all(hd.root.eviction_epoch == ep
               for hd, ep in zip(task.inputs, epochs)):
            staged = pre_staged
        else:  # pressure evicted warmed bytes: stage on demand
            rt._unpin_inputs(task, loc)
    if staged is None:
        # no prefetch, prefetch deferred, or warmed bytes evicted —
        # authoritative pinned staging
        staged = rt._stage_inputs(task, pe)
        if pre is not None:  # account the wasted warm-up too
            staged = (staged[0], staged[1] + pre[0][1],
                      staged[2] + pre[0][2], pre[0][3] + staged[3])
    ins, tr_s, sp_s, moves = staged
    w_staged = time.perf_counter()
    try:
        outs, comp_s = rt._run_kernel(task, pe, ins)
        w_comp = time.perf_counter()
        out_s, sp2_s = rt._commit_outputs(task, pe, outs)
    finally:
        rt._unpin_inputs(task, pe.location)
    w1 = time.perf_counter()
    rt.divergence.observe("stage", task.op, pe.kind, task.in_bytes,
                          w_staged - w0, tr_s + sp_s)
    if tracer is not None:
        tname = task.name or task.op
        targs = {"task": tname, "op": task.op, "client": task.client}
        tracer.span(tname, "stage", f"pe:{pe.name}:stage", w0, w_staged, targs)
        tracer.span(tname, "compute", f"pe:{pe.name}", w_staged, w_comp, targs)
        tracer.span(tname, "writeback", f"pe:{pe.name}", w_comp, w1, targs)
    return w0, w1, tr_s, sp_s + sp2_s, comp_s, out_s, moves


def replay_schedule(rt: "Runtime", nodes: Sequence[TaskNode],
                    records: Dict[int, tuple],
                    topo=None) -> Tuple[Timeline, float]:
    """Deterministically re-simulate an executed schedule.

    The executors' online accounting runs in worker completion order,
    which varies run to run — fine for scalar sums but not for gated
    metrics.  This replay processes the recorded placements, transfers
    and compute estimates in (ready-time, submission-index) order: a
    task's input copies are issued the moment its dependencies finish,
    its compute starts when both the staged bytes and the PE are free.
    With a :class:`~repro.core.topology.Topology` the copies walk their
    routes through per-link busy-until contention (a shared bridge
    serializes them) and per-link Gantt transfer lanes are emitted;
    without one, staging is the recorded store-and-forward seconds.

    ``records`` may cover a *subset* of ``nodes`` (a stream replays only
    completed tasks); a recorded task's dependencies are always recorded
    too, because it could not have run before them.  Returns
    ``(timeline, modeled makespan)``."""
    if topo is not None:
        topo.reset_contention()
    timeline = Timeline()
    pe_free: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
    finish: Dict[int, float] = {}
    remaining = {i: len(nodes[i].deps) for i in records}
    heap: List[Tuple[float, int]] = [
        (0.0, i) for i, r in remaining.items() if r == 0
    ]
    heapq.heapify(heap)
    while heap:
        ready_m, i = heapq.heappop(heap)
        node = nodes[i]
        (pe_name, moves, comp_m, spill_s, out_s, tr_s, comp_s,
         w0, w1) = records[i]
        if topo is not None:
            stage_end = ready_m
            for src, dst, nbytes in moves:
                _, end, hops = topo.transfer(src, dst, nbytes, at=ready_m,
                                             commit=True)
                for link, hs, he in hops:
                    timeline.add_transfer(TransferEvent(
                        link=link.label, task=node.name, nbytes=nbytes,
                        model_start=hs, model_end=he, node=i,
                    ))
                stage_end = max(stage_end, end)
        else:
            stage_end = ready_m + tr_s
        start = max(pe_free[pe_name], stage_end + spill_s)
        end = start + comp_m + out_s
        pe_free[pe_name] = end
        finish[i] = end
        stage_s = (stage_end - ready_m) + spill_s
        timeline.add(TimelineEvent(
            task=node.name, pe=pe_name, wall_start=w0, wall_end=w1,
            model_start=max(ready_m, start - stage_s), model_end=end,
            transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
            spill_s=spill_s, compute_start_m=start, node=i,
        ))
        for s in list(node.dependents):
            if s in remaining:
                remaining[s] -= 1
                if remaining[s] == 0:
                    heapq.heappush(heap, (
                        max(finish[d] for d in nodes[s].deps), s
                    ))
    return timeline, max(finish.values(), default=0.0)


class _ExecutorBase:
    """Scheduling + prefetch machinery shared by the batch and streaming
    engines.  Subclasses own run lifecycle and completion bookkeeping;
    they must provide ``_nodes`` (admitted :class:`TaskNode` list),
    ``_model_finish``, ``_pe_slots`` and ``_pool``."""

    def __init__(self, rt: "Runtime", *, scheduler: Optional[str] = None,
                 prefetch: bool = True) -> None:
        from .runtime import SCHEDULERS  # local: no cycle at module load

        self.rt = rt
        self.scheduler = scheduler or rt.scheduler
        if self.scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        self.prefetch = prefetch
        # interconnect topology, when the context routes transfers
        self._topo = getattr(
            rt.context.ledger.bandwidth_model, "topology", None
        )
        # cross-client interference (ISSUE 5): ready-but-unplaced tasks of
        # the current dispatch batch, index -> (client, eligible PE names).
        # The streaming engine fills it so heft placement can charge a
        # candidate the delay it imposes on *other* clients' ready tasks.
        self._copending: Dict[int, Tuple[Optional[str], frozenset]] = {}

    # -- placement ----------------------------------------------------------
    def _staging_delay(self, task: "Task", pe: "PE", at: float) -> float:
        """Extra modeled wait the task's input transfers would queue on
        busy interconnect links if issued at ``at`` (0 without a
        topology) — the contention term of HEFT placement."""
        if self._topo is None:
            return 0.0
        delay = 0.0
        for hd in task.inputs:
            src = hd.last_location
            if src != pe.location:
                delay = max(delay, self._topo.queue_delay(
                    src, pe.location, hd.nbytes, at=at))
        return delay

    def _ready_m(self, node: TaskNode) -> float:
        return max(
            (self._model_finish.get(d, 0.0) for d in node.deps), default=0.0
        )

    def _interference(self, task: "Task", pe: "PE", est: float) -> float:
        """Modeled delay placing ``task`` on ``pe`` imposes on *other
        clients'* ready-but-unplaced tasks (ISSUE 5): occupying ``pe``
        for ``est`` seconds delays each co-pending task that could use
        this PE, prorated by 1/|its eligible PEs| (the chance it needs
        exactly this one).  Zero without client attribution — the batch
        engine and single-tenant streams place exactly as before."""
        if not self._copending or task.client is None:
            return 0.0
        pen = 0.0
        for client, names in self._copending.values():
            if client is not None and client != task.client and pe.name in names:
                pen += est / len(names)
        return pen

    def _eligible_names(self, task: "Task") -> frozenset:
        if task.pin is not None:
            return frozenset((task.pin,))
        try:
            return frozenset(pe.name for pe in self.rt._eligible(task))
        except LookupError:
            return frozenset()

    def _pick_pe(self, node: TaskNode) -> "PE":
        """Dynamic placement for a ready node (deps complete ⇒ input flags
        are final). Called under the run's state lock."""
        rt, task = self.rt, node.task
        if task.pin is not None:
            return rt.by_name[task.pin]
        pes = rt._eligible(task)
        if self.scheduler == "data_affinity":
            return rt._affinity_pick(task, pes)
        # heft: earliest-estimated-finish-time placement, on the same
        # cost basis as serial heft dispatch (Runtime._heft_costs) plus
        # input-readiness, link-contention, an insertion-based slot
        # search over each PE's modeled busy intervals (ISSUE 3), and a
        # cross-client interference charge (ISSUE 5) — the comparison key
        # adds the delay this placement imposes on other clients' ready
        # tasks, while the committed slot stays the physical [start, est).
        ready_m = self._ready_m(node)

        def placement(pe: "PE") -> Tuple[float, float, float]:
            tr, est = rt._heft_costs(task, pe)
            earliest = ready_m + tr + self._staging_delay(task, pe, ready_m)
            start = insert_slot(self._pe_slots[pe.name], earliest, est)
            return start + est + self._interference(task, pe, est), start, est

        efts = {pe.name: placement(pe) for pe in pes}
        best = min(pes, key=lambda pe: (efts[pe.name][0], pe.name))
        _, start, est = efts[best.name]
        commit_slot(self._pe_slots[best.name], start, est)
        if self._topo is not None:
            # Commit this task's expected link traffic so later
            # placements see the shared links as busy.
            for hd in task.inputs:
                src = hd.last_location
                if src != best.location:
                    self._topo.transfer(src, best.location, hd.nbytes,
                                        at=ready_m, commit=True)
        return best

    # -- prefetch -----------------------------------------------------------
    def _prefetch_order(
        self, assigned: List[Tuple[int, "PE"]]
    ) -> List[Tuple[int, "PE"]]:
        """Topology-aware prefetch issue order (ISSUE 4 satellite): when
        several tasks become ready together, warm the ones whose input
        routes are currently *least contended* first — a transfer with a
        free route starts moving bytes immediately, while one that would
        queue on a busy shared link yields its transfer-pool slot.
        Order is (modeled queue delay, submission index); without a
        topology the submission order is kept unchanged."""
        if self._topo is None or len(assigned) < 2:
            return assigned

        def delay(item: Tuple[int, "PE"]) -> float:
            i, pe = item
            node = self._nodes[i]
            at = self._ready_m(node)
            return max(
                (self._topo.queue_delay(hd.last_location, pe.location,
                                        hd.nbytes, at=at)
                 for hd in node.task.inputs
                 if hd.last_location != pe.location),
                default=0.0,
            )

        return sorted(assigned, key=lambda item: (delay(item), item[0]))

    def _prefetch_stage(self, task: "Task", pe: "PE"):
        """Speculative pin-free staging on the transfer pool.  Returns
        ``(staged, eviction_epochs)`` — the worker reuses ``staged`` only
        if every input root's eviction epoch is unchanged once pinned —
        or None when capacity pressure defers to demand staging (never
        evicting bytes another queued task still reads)."""
        tracer = self.rt.context.tracer
        t0 = time.perf_counter() if tracer is not None else 0.0
        try:
            staged = self.rt._stage_inputs(task, pe, prefetch=True)
        except PrefetchDeferred:
            return None
        if tracer is not None:
            tname = task.name or task.op
            tracer.span(tname, "stage", f"pe:{pe.name}:stage",
                        t0, time.perf_counter(),
                        {"task": tname, "prefetch": True})
        return staged, tuple(hd.root.eviction_epoch for hd in task.inputs)

    # -- claims -------------------------------------------------------------
    def _unprotect(self, node: TaskNode, pe: "PE") -> None:
        for hd in node.task.inputs:
            self.rt.context.unprotect(hd, pe.location)

    def _abandon(self, payload: tuple) -> None:
        """Release claims of a payload that will never execute: reap its
        prefetch future and drop the queued-reader protection."""
        i, pe, fut = payload
        _reap_future(fut)
        self._unprotect(self._nodes[i], pe)


class GraphExecutor(_ExecutorBase):
    """Executes one task list as a DAG on a :class:`Runtime`'s PEs
    (batch intake — the engine behind the ``run_graph`` compat wrapper;
    the streaming :class:`StreamExecutor` is the primary entry point)."""

    # -- public entry -------------------------------------------------------
    def run(self, tasks: Sequence["Task"]) -> Dict[str, Any]:
        rt = self.rt
        rt.timeline = Timeline()
        graph = build_graph(tasks)
        if not len(graph):
            rt.last_makespan_model = 0.0
            return self._report(graph, 0.0)

        self._graph = graph
        self._nodes = graph.nodes
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self._remaining = [len(n.deps) for n in graph.nodes]
        self._completed = 0
        self._model_finish: Dict[int, float] = {}
        self._pe_model: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
        # HEFT insertion-based slot search (ISSUE 3): per-PE sorted busy
        # intervals on the scheduler's modeled timeline.
        self._pe_slots: Dict[str, List[Tuple[float, float]]] = {
            pe.name: [] for pe in rt.pes
        }
        if self._topo is not None:
            self._topo.reset_contention()
        # per-task execution records feeding the deterministic replay:
        # (pe name, moves, comp_m, spill_s, out_s, tr_s, comp_s, w0, w1)
        self._records: Dict[int, tuple] = {}
        # run lifecycle: late items (after teardown) are abandoned, and
        # teardown waits until in-flight items leave the workers
        self._finished = False
        self._inflight = 0
        self._quiet = threading.Condition()

        if self.scheduler == "heft":
            self._rank(graph)
        # Static policies assign in submission order so placement (and
        # therefore rimms copy counts) is bit-identical to serial run().
        self._static: Optional[List["PE"]] = None
        if self.scheduler == "round_robin":
            self._static = [rt._schedule(n.task) for n in graph.nodes]

        pool = rt._get_worker_pool()
        pool.runs_served += 1
        self._pool = pool

        self._t0 = time.perf_counter()
        try:
            with self._lock:
                ready = [n.index for n in graph.nodes if not n.deps]
                self._schedule_ready(ready)
            self._done.wait()
        finally:
            with self._quiet:
                self._finished = True
                # Wait out in-flight workers FIRST: a completing peer can
                # still enqueue dependents and prefetch futures (failure
                # teardown); only after quiescence is the queue content
                # final.
                while self._inflight:
                    self._quiet.wait()
            # Reap items abandoned on any queue: cancel their prefetch
            # futures — or wait out started ones — and release their pins
            # and protection, so no staging outlives the run unaccounted.
            # (Workers popping later see _finished and abandon likewise.)
            for payload in pool.drain(self):
                self._abandon(payload)
        wall = time.perf_counter() - self._t0
        if self._error is not None:
            raise self._error
        if self._topo is not None:
            rt.timeline, rt.last_makespan_model = replay_schedule(
                rt, graph.nodes, self._records, self._topo
            )
        else:
            rt.last_makespan_model = max(
                self._model_finish.values(), default=0.0
            )
        tracer = rt.context.tracer
        if tracer is not None:
            run_label = tracer.add_timeline(rt.timeline, label="graph")
            tracer.add_edges(graph.edges(), run_label)
        return self._report(graph, wall)

    # -- scheduling ---------------------------------------------------------
    def _rank(self, graph: TaskGraph) -> None:
        rt, cm = self.rt, self.rt.cost_model
        bw = rt.context.ledger.bandwidth_model

        def compute_cost(task: "Task") -> float:
            kinds = sorted({pe.kind for pe in rt._eligible(task)})
            return cm.mean_estimate(task.op, kinds, task.in_bytes)

        def comm_cost(task: "Task") -> float:
            return bw.typical(task.in_bytes)

        graph.compute_ranks(compute_cost, comm_cost)

    def _schedule_ready(self, indices: List[int]) -> None:
        """Assign + enqueue newly-ready nodes (under the state lock).
        HEFT processes the batch highest-upward-rank first.  Each node's
        inputs are protected at its PE until completion — the contract
        behind capacity-aware prefetch.  Prefetch stagings are issued
        least-contended-route-first (ISSUE 4 satellite); PE queue order
        keeps the assignment order."""
        nodes = self._graph.nodes
        ctx = self.rt.context
        if self.scheduler == "heft":
            indices = sorted(indices, key=lambda i: -nodes[i].rank)
        assigned: List[Tuple[int, "PE"]] = []
        for i in indices:
            node = nodes[i]
            pe = self._static[i] if self._static is not None else self._pick_pe(node)
            for hd in node.task.inputs:
                ctx.protect(hd, pe.location)
            assigned.append((i, pe))
        futs: Dict[int, Future] = {}
        if self.prefetch:
            # Prefetch: stage inputs now, possibly while the PE is still
            # busy with an earlier task — transfer/compute overlap.
            for i, pe in self._prefetch_order(assigned):
                futs[i] = self._pool.transfer.submit(
                    self._prefetch_stage, nodes[i].task, pe
                )
        for i, pe in assigned:
            self._pool.submit(self, pe.name, (i, pe, futs.get(i)))

    # -- workers ------------------------------------------------------------
    def _process(self, pe: "PE", payload: tuple) -> None:
        """Execute one queued payload on its PE worker thread.  Called by
        the persistent pool; must never kill the worker thread."""
        with self._quiet:
            if self._finished:
                live = False
            else:
                live = True
                self._inflight += 1
        if not live:
            self._abandon(payload)
            return
        try:
            if self._error is not None:
                # A peer already failed: drain without executing.
                self._abandon(payload)
                return
            i, pe_assigned, fut = payload
            node = self._graph.nodes[i]
            unprotected = False
            try:
                (w0, w1, tr_s, spill_s, comp_s, out_s, moves) = _execute_task(
                    self.rt, node.task, pe_assigned, fut
                )
                # This task no longer reads its inputs: release the
                # queued-reader claim exactly once, before dependents are
                # scheduled (inside _complete).
                self._unprotect(node, pe_assigned)
                unprotected = True
                # _complete can itself raise while scheduling newly-ready
                # dependents (unknown pin, op with no eligible PE) — it
                # must stay inside the except so the run never hangs.
                self._complete(node, pe_assigned, w0, w1, tr_s,
                               spill_s, comp_s, out_s, moves)
            except BaseException as e:  # surface to the caller, stop the run
                with self._lock:
                    if self._error is None:
                        self._error = e
                if not unprotected:
                    self._unprotect(node, pe_assigned)
                self._done.set()
        finally:
            with self._quiet:
                self._inflight -= 1
                self._quiet.notify_all()

    def _complete(
        self,
        node: TaskNode,
        pe: "PE",
        w0: float,
        w1: float,
        tr_s: float,
        spill_s: float,
        comp_s: float,
        out_s: float,
        moves: Sequence[tuple] = (),
    ) -> None:
        rt = self.rt
        with self._lock:
            # Schedule simulation: this task's transfers could start once
            # its inputs existed (ready_m), overlapping the PE's previous
            # compute; its compute starts when both the PE and the staged
            # inputs are available.  Spill stalls extend staging.
            ready_m = self._ready_m(node)
            # Static compute estimate, not contended measured seconds —
            # keeps the simulation comparable to serial run() (see
            # CostModel.prior_estimate).
            comp_m = rt.cost_model.prior_estimate(
                node.task.op, pe.kind, node.task.in_bytes
            )
            stage_s = tr_s + spill_s
            compute_start_m = max(self._pe_model[pe.name], ready_m + stage_s)
            finish_m = compute_start_m + comp_m + out_s
            self._pe_model[pe.name] = finish_m
            self._model_finish[node.index] = finish_m
            rt.timeline.add(TimelineEvent(
                task=node.name, pe=pe.name,
                wall_start=w0 - self._t0, wall_end=w1 - self._t0,
                model_start=max(ready_m, compute_start_m - stage_s),
                model_end=finish_m,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
                spill_s=spill_s, compute_start_m=compute_start_m,
                node=node.index,
            ))
            rt.task_log.append((node.name, pe.name))
            self._records[node.index] = (
                pe.name, tuple(moves), comp_m, spill_s, out_s, tr_s,
                comp_s, w0 - self._t0, w1 - self._t0,
            )
            self._completed += 1
            newly_ready: List[int] = []
            for s in node.dependents:
                self._remaining[s] -= 1
                if self._remaining[s] == 0:
                    newly_ready.append(s)
            # A peer failed: the run is tearing down — don't feed new
            # work (or prefetch staging) into a dying run.
            if newly_ready and self._error is None:
                self._schedule_ready(newly_ready)
            if self._completed == len(self._graph):
                self._done.set()

    # -- reporting ----------------------------------------------------------
    def _report(self, graph: TaskGraph, wall: float) -> Dict[str, Any]:
        rt = self.rt
        per_pe: Dict[str, float] = {}
        for ev in rt.timeline.events():
            per_pe[ev.pe] = per_pe.get(ev.pe, 0.0) + (ev.model_end - ev.model_start)
        ledger = rt.context.ledger
        return {
            "wall_s": wall,
            "makespan_model": rt.last_makespan_model,
            "n_tasks": len(graph),
            "n_edges": graph.n_edges,
            "critical_path": graph.critical_path_len,
            "scheduler": self.scheduler,
            "policy": rt.policy,
            "prefetch": self.prefetch,
            "topology": self._topo.name if self._topo is not None else None,
            "per_pe_busy_model_s": per_pe,
            "timeline": rt.timeline,
            "spill_stall_model_s": rt.timeline.total_spill_s,
            "evictions": ledger.total_evictions,
            "prefetch_deferrals": ledger.prefetch_deferrals,
        }


class StreamExecutor(_ExecutorBase):
    """Continuous task-stream engine (ISSUE 4) — the execution half of
    the primary :class:`repro.core.api.Session` API.

    Where :class:`GraphExecutor` takes a whole task list and runs it to
    completion, this engine **admits** tasks one at a time as the
    session submits them, and the persistent :class:`WorkerPool`
    consumes the stream continuously:

    * :meth:`admit` wires a freshly built
      :class:`~repro.core.graph.TaskNode` into the live run — it
      dispatches immediately when its dependencies are already complete,
      otherwise the completion of its last dependency dispatches it.
      There is **no global barrier**: the ready frontier flows straight
      onto the PE queues;
    * **windowed HEFT**: ``heft`` placement ranks only the admitted,
      incomplete window of the DAG (upward ranks recomputed over what is
      known *now*, bounded by ``window`` admissions), then places each
      ready task with the shared contention-aware insertion-based slot
      search;
    * **per-subtree failure**: a failing task fails its dependent
      subtree — every transitively dependent node is marked failed with
      the same root cause, surfaced through
      :class:`~repro.core.api.BufferFuture` results — while independent
      chains keep flowing.  :meth:`barrier` re-raises the first
      *unobserved* root failure;
    * an ``on_done`` callback (index, exception-or-None), invoked under
      the stream lock at every completion or failure, lets the session
      resolve futures and release buffer lifecycles out of order, as
      tasks actually finish.

    Modeled evidence: online accounting mirrors the batch engine
    (per-PE model clocks, task log, timeline events); :meth:`report`
    re-simulates everything completed so far with the deterministic
    :func:`replay_schedule` — call it at a sync point for exact,
    machine-independent makespans (the bench_stream CI gate does).
    """

    def __init__(
        self,
        rt: "Runtime",
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
        on_done: Optional[Callable[[int, Optional[BaseException]], None]] = None,
        window: int = 64,
    ) -> None:
        super().__init__(rt, scheduler=scheduler, prefetch=prefetch)
        self.window = window
        self._on_done = on_done
        # Reentrant: the session serializes GraphBuilder mutations under
        # this same lock (see state_lock) and admit() re-enters it.
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._nodes: List[TaskNode] = []
        self._admitted = 0
        self._completed: Set[int] = set()
        self._failed: Dict[int, BaseException] = {}
        # root failures no barrier/result() raised yet (dependents
        # cascade-fail with the same exception but count as observed —
        # the root cause is what the caller must see exactly once)
        self._unobserved: List[int] = []
        self._remaining: Dict[int, int] = {}
        self._static_pe: Dict[int, "PE"] = {}
        self._model_finish: Dict[int, float] = {}
        self._pe_model: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
        self._pe_slots: Dict[str, List[Tuple[float, float]]] = {
            pe.name: [] for pe in rt.pes
        }
        self._records: Dict[int, tuple] = {}
        self.timeline = Timeline()
        self._closed = False
        if self._topo is not None:
            self._topo.reset_contention()
        self._pool = rt._get_worker_pool()
        self._pool.runs_served += 1
        self._t0 = time.perf_counter()

    # -- admission ----------------------------------------------------------
    @property
    def state_lock(self) -> threading.Condition:
        """The stream's (reentrant) state lock.  The session holds it
        across ``GraphBuilder.add`` + :meth:`admit`: node linkage
        (``deps``/``dependents`` sets) is mutated by admission and
        iterated by completion, so both must serialize here — admission
        order also stays equal to node order for free."""
        return self._cv

    def admit(self, node: TaskNode) -> None:
        """Wire one freshly built node into the live stream.  The caller
        (the session) serializes builder ``add`` + ``admit`` so node
        indices equal admission order.  Scheduling errors (unknown pin,
        op with no eligible PE) fail the node — they surface through its
        futures, like every other failure."""
        with self._cv:
            if self._closed:
                raise RuntimeError("stream executor is closed")
            assert node.index == self._admitted, "admission out of order"
            self._nodes.append(node)
            self._admitted += 1
            if self.scheduler == "round_robin":
                # Static placement at admission (submission order), so a
                # single-threaded stream is bit-identical to serial
                # dispatch — same contract as batch round_robin.
                try:
                    self._static_pe[node.index] = self.rt._schedule(node.task)
                except BaseException as e:
                    self._fail_node(node.index, e, root=True)
                    return
            failed_dep = next(
                (d for d in node.deps if d in self._failed), None)
            if failed_dep is not None:
                self._fail_node(node.index, self._failed[failed_dep],
                                root=False)
                return
            live = sum(1 for d in node.deps if d not in self._completed)
            self._remaining[node.index] = live
            if live == 0:
                self._dispatch([node.index])

    def _dispatch(self, indices: List[int]) -> None:
        """Assign + enqueue ready nodes (under the stream lock).  HEFT
        ranks the batch over the admitted-incomplete window first;
        prefetch stagings are issued least-contended-route-first."""
        nodes, ctx = self._nodes, self.rt.context
        if self.scheduler == "heft" and len(indices) > 1:
            self._rank_window()
            indices = sorted(indices, key=lambda i: -nodes[i].rank)
        if self.scheduler == "heft":
            # Cross-client interference (ISSUE 5): expose the batch's
            # still-unplaced tasks (with client attribution) so each
            # placement can charge the delay it imposes on other
            # clients' ready work.
            self._copending = {
                i: (nodes[i].task.client,
                    self._eligible_names(nodes[i].task))
                for i in indices if nodes[i].task.client is not None
            }
        assigned: List[Tuple[int, "PE"]] = []
        cap = 4 * max(self.window, 16)
        for i in indices:
            node = nodes[i]
            self._copending.pop(i, None)  # never charge a task for itself
            try:
                pe = self._static_pe.pop(i, None) or self._pick_pe(node)
            except BaseException as e:
                self._fail_node(i, e, root=True)
                continue
            # Bound the slot-search state for unbounded streams: drop the
            # oldest committed intervals once the list outgrows the
            # scheduling window.  Exposed "past" gaps only loosen the EFT
            # heuristic for late-admitted roots — placement quality, not
            # correctness — and keep per-placement cost O(window), not
            # O(stream length).
            busy = self._pe_slots[pe.name]
            if len(busy) > cap:
                del busy[: len(busy) - cap // 2]
            for hd in node.task.inputs:
                ctx.protect(hd, pe.location)
            assigned.append((i, pe))
        self._copending = {}
        futs: Dict[int, Future] = {}
        if self.prefetch:
            for i, pe in self._prefetch_order(assigned):
                futs[i] = self._pool.transfer.submit(
                    self._prefetch_stage, nodes[i].task, pe
                )
        for i, pe in assigned:
            self._pool.submit(self, pe.name, (i, pe, futs.get(i)))

    def _rank_window(self) -> None:
        """Recompute HEFT upward ranks over the admitted, incomplete
        window — the streaming analogue of whole-graph ranking: later
        admissions extend the DAG, so ranks are re-derived from what is
        known now.  ``window`` bounds the scan to the most recent
        admissions (older incomplete stragglers keep their last rank)."""
        rt, cm = self.rt, self.rt.cost_model
        bw = rt.context.ledger.bandwidth_model
        lo = max(0, self._admitted - self.window) if self.window else 0
        live = [
            n for n in self._nodes[lo:]
            if n.index not in self._completed and n.index not in self._failed
        ]
        for n in reversed(live):  # deps point backward: reverse = leaves first
            succ = max(
                (bw.typical(self._nodes[s].task.in_bytes)
                 + self._nodes[s].rank
                 for s in n.dependents if s not in self._completed),
                default=0.0,
            )
            try:
                kinds = sorted({pe.kind for pe in rt._eligible(n.task)})
            except LookupError:
                kinds = []
            n.rank = cm.mean_estimate(n.task.op, kinds, n.task.in_bytes) + succ

    # -- workers ------------------------------------------------------------
    def _process(self, pe: "PE", payload: tuple) -> None:
        """Execute one payload on its PE worker thread.  Unlike the
        batch engine, a peer's failure does not drain the stream — only
        the failing task's dependent subtree is failed."""
        i, pe_assigned, fut = payload
        if self._closed:
            self._abandon(payload)
            return
        node = self._nodes[i]
        try:
            result = _execute_task(self.rt, node.task, pe_assigned, fut)
        except BaseException as e:
            self._unprotect(node, pe_assigned)
            with self._cv:
                self._fail_node(i, e, root=True)
            return
        self._unprotect(node, pe_assigned)
        self._complete(node, pe_assigned, *result)

    def _fail_node(self, i: int, exc: BaseException, *, root: bool) -> None:
        """Mark node ``i`` failed and cascade to its admitted dependent
        subtree (same root cause) — iterative worklist, so an arbitrarily
        deep chain cannot overflow the stack on a worker thread.  Called
        under the stream lock."""
        if i in self._failed or i in self._completed:
            return
        self._failed[i] = exc
        if root:
            self._unobserved.append(i)
        ledger = self.rt.context.ledger
        tracer = self.rt.context.tracer
        work = [i]
        while work:
            j = work.pop()
            self._remaining.pop(j, None)
            ledger.record_client_failure(self._nodes[j].task.client)
            if tracer is not None:
                client = self._nodes[j].task.client
                tracer.instant(
                    "task_failed", "error",
                    f"tenant:{client}" if client else "stream",
                    {"node": j, "task": self._nodes[j].name,
                     "root": j == i, "error": type(exc).__name__})
            if self._on_done is not None:
                self._on_done(j, exc)
            for s in sorted(self._nodes[j].dependents):
                if s not in self._failed and s not in self._completed:
                    self._failed[s] = exc
                    work.append(s)
        self._cv.notify_all()

    def _complete(self, node: TaskNode, pe: "PE", w0: float, w1: float,
                  tr_s: float, spill_s: float, comp_s: float, out_s: float,
                  moves: Sequence[tuple]) -> None:
        rt = self.rt
        with self._cv:
            # Online schedule simulation — same arithmetic as the batch
            # engine, so modeled makespans stay directly comparable.
            ready_m = self._ready_m(node)
            comp_m = rt.cost_model.prior_estimate(
                node.task.op, pe.kind, node.task.in_bytes
            )
            stage_s = tr_s + spill_s
            compute_start_m = max(self._pe_model[pe.name], ready_m + stage_s)
            finish_m = compute_start_m + comp_m + out_s
            self._pe_model[pe.name] = finish_m
            self._model_finish[node.index] = finish_m
            self.timeline.add(TimelineEvent(
                task=node.name, pe=pe.name,
                wall_start=w0 - self._t0, wall_end=w1 - self._t0,
                model_start=max(ready_m, compute_start_m - stage_s),
                model_end=finish_m,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
                spill_s=spill_s, compute_start_m=compute_start_m,
                node=node.index,
            ))
            rt.task_log.append((node.name, pe.name))
            self._records[node.index] = (
                pe.name, tuple(moves), comp_m, spill_s, out_s, tr_s,
                comp_s, w0 - self._t0, w1 - self._t0,
            )
            # Per-tenant service accounting (ISSUE 5): the modeled
            # seconds this task consumed, on the same basis as the
            # makespan simulation — fairness_report sums these.
            rt.context.ledger.record_client_task(
                node.task.client, node.task.in_bytes,
                tr_s + spill_s + comp_m + out_s,
            )
            self._completed.add(node.index)
            self._remaining.pop(node.index, None)
            newly_ready: List[int] = []
            for s in node.dependents:
                if s in self._remaining:
                    self._remaining[s] -= 1
                    if self._remaining[s] == 0:
                        newly_ready.append(s)
            if self._on_done is not None:
                self._on_done(node.index, None)
            if newly_ready:
                self._dispatch(sorted(newly_ready))
            self._cv.notify_all()

    # -- sync points --------------------------------------------------------
    def _quiesced(self) -> bool:
        return len(self._completed) + len(self._failed) >= self._admitted

    def barrier(self, timeout: Optional[float] = None) -> None:
        """Wait until every admitted task completed or failed, then
        re-raise the first unobserved root failure (submission order).
        Failures already raised through a future's ``result()`` are not
        raised again."""
        with self._cv:
            if not self._cv.wait_for(self._quiesced, timeout):
                raise TimeoutError(
                    f"stream barrier timed out after {timeout}s with "
                    f"{self._admitted - len(self._completed) - len(self._failed)}"
                    f" tasks in flight"
                )
            if self._unobserved:
                first = min(self._unobserved)
                self._unobserved.clear()
                raise self._failed[first]

    def wait(self, index: int, timeout: Optional[float] = None) -> None:
        """Block until node ``index`` completes or fails; raise its
        failure (marking it observed)."""
        with self._cv:
            ok = self._cv.wait_for(
                lambda: index in self._completed or index in self._failed,
                timeout,
            )
            if not ok:
                raise TimeoutError(f"task #{index} still pending "
                                   f"after {timeout}s")
            exc = self._failed.get(index)
        if exc is not None:
            self.mark_observed(index)
            raise exc

    def done(self, index: int) -> bool:
        with self._cv:
            return index in self._completed or index in self._failed

    def exception(self, index: int) -> Optional[BaseException]:
        with self._cv:
            return self._failed.get(index)

    def mark_observed(self, index: int) -> None:
        """The caller saw this node's failure (e.g. via a future's
        ``result()``): a later barrier will not re-raise it.  Observing
        a cascaded failure observes its root cause too — the exception
        object is the same one."""
        with self._cv:
            exc = self._failed.get(index)
            self._unobserved = [
                i for i in self._unobserved
                if i != index and self._failed[i] is not exc
            ]

    def close(self) -> None:
        """Drain the stream (wait for quiescence), then stop accepting
        admissions and reap any abandoned queue items.  Idempotent; does
        not raise pending failures — :meth:`barrier` does."""
        with self._cv:
            if self._closed:
                return
            self._cv.wait_for(self._quiesced)
            self._closed = True
        for payload in self._pool.drain(self):
            self._abandon(payload)

    @property
    def closed(self) -> bool:
        """The stream no longer accepts admissions — explicitly closed,
        or its worker pool was shut down (a task enqueued onto a dead
        pool would hang forever; the session raises
        ``SessionClosedError`` instead)."""
        return self._closed or self._pool.closed

    # -- reporting ----------------------------------------------------------
    def replay(self, admission=None):
        """Deterministic re-simulation of everything completed so far —
        call at a sync point for exact, machine-independent modeled
        metrics.  Without ``admission`` this is :func:`replay_schedule`
        (returns ``(timeline, makespan)``); with a
        :class:`~repro.core.qos.QoSManager` (or its ``params()`` dict)
        it is the QoS-aware :func:`~repro.core.qos.fair_replay`, which
        re-enacts per-client windows and DRR admission in virtual time
        and returns ``(timeline, makespan, finish, release)``."""
        with self._cv:
            records = dict(self._records)
            # Snapshot node linkage: later admissions keep mutating the
            # live nodes' dependent sets while the replay walks them.
            snap = [
                TaskNode(n.index, n.task, set(n.deps), set(n.dependents))
                for n in self._nodes
            ]
        if admission is None:
            return replay_schedule(self.rt, snap, records, self._topo)
        from .qos import fair_replay  # local import: hete imports qos

        return fair_replay(self.rt, snap, records, self._topo, admission)

    def report(self) -> Dict[str, Any]:
        """Schedule evidence for the stream so far.  ``makespan_model``
        and ``timeline`` come from the deterministic replay."""
        timeline, makespan = self.replay()
        per_pe: Dict[str, float] = {}
        for ev in timeline.events():
            per_pe[ev.pe] = per_pe.get(ev.pe, 0.0) + (
                ev.model_end - ev.model_start)
        with self._cv:
            admitted, completed = self._admitted, len(self._completed)
            failed = len(self._failed)
        ledger = self.rt.context.ledger
        return {
            "wall_s": time.perf_counter() - self._t0,
            "makespan_model": makespan,
            "n_tasks": admitted,
            "n_completed": completed,
            "n_failed": failed,
            "scheduler": self.scheduler,
            "policy": self.rt.policy,
            "backend": self.rt.backend,
            # placement cost source: measured calibration cells when a
            # table is attached, BASE_THROUGHPUT priors otherwise
            "calibrated": self.rt.calibration is not None,
            "calibration_cells": (
                len(self.rt.calibration)
                if self.rt.calibration is not None else 0),
            "prefetch": self.prefetch,
            "topology": self._topo.name if self._topo is not None else None,
            "per_pe_busy_model_s": per_pe,
            "timeline": timeline,
            "spill_stall_model_s": timeline.total_spill_s,
            "evictions": ledger.total_evictions,
            "prefetch_deferrals": ledger.prefetch_deferrals,
        }
