"""Async task-graph executor: per-PE workers, prefetch, HEFT-lite.

This is the runtime half of the ISSUE-1 subsystem (the DAG half lives in
:mod:`repro.core.graph`).  Execution model:

* one worker thread per PE, fed by a FIFO queue — same-PE tasks
  serialize, different PEs run concurrently;
* **input prefetch**: the moment a task's dependencies complete, its
  input staging (``hete_Data`` flag checks + src→PE copies) is submitted
  to a transfer pool, so the copy overlaps whatever the target PE is
  still computing — the paper's §3.2.2 premise (the runtime knows where
  valid bytes live) finally buys wall-clock, not just copy counts;
* scheduling: ``round_robin`` (static, bit-identical to serial dispatch),
  ``data_affinity`` (dynamic, flag-aware), or ``heft`` — a HEFT-lite
  list scheduler that ranks ready tasks by upward rank and places each on
  the PE minimizing estimated finish time under the
  :class:`~repro.core.locations.BandwidthModel` and the online
  :class:`~repro.core.graph.CostModel`.

Because every PE here is emulated on one physical CPU, the *measured*
wall clock understates the win; the executor therefore also simulates
the schedule it actually executed (modeled transfer seconds + measured
kernel seconds) and reports a modeled makespan, directly comparable to
the serial :meth:`Runtime.run` modeled makespan.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, TYPE_CHECKING

from .graph import TaskGraph, TaskNode, build_graph
from .instrument import Timeline, TimelineEvent

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids import cycle
    from .runtime import PE, Runtime, Task

__all__ = ["GraphExecutor"]

_SENTINEL = None


def _reap_future(fut: Optional[Future]) -> None:
    """Cancel an abandoned prefetch future, or — if it already started —
    wait and swallow its outcome so staging errors are never left
    unretrieved."""
    if fut is not None and not fut.cancel():
        try:
            fut.exception()
        except BaseException:
            pass


class GraphExecutor:
    """Executes one task list as a DAG on a :class:`Runtime`'s PEs."""

    def __init__(
        self,
        rt: "Runtime",
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
    ) -> None:
        from .runtime import SCHEDULERS  # local: no cycle at module load

        self.rt = rt
        self.scheduler = scheduler or rt.scheduler
        if self.scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        self.prefetch = prefetch

    # -- public entry -------------------------------------------------------
    def run(self, tasks: Sequence["Task"]) -> Dict[str, Any]:
        rt = self.rt
        rt.timeline = Timeline()
        graph = build_graph(tasks)
        if not len(graph):
            rt.last_makespan_model = 0.0
            return self._report(graph, 0.0)

        self._graph = graph
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self._remaining = [len(n.deps) for n in graph.nodes]
        self._completed = 0
        self._model_finish: Dict[int, float] = {}
        self._pe_model: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
        self._sched_avail: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
        self._queues: Dict[str, "queue.Queue"] = {
            pe.name: queue.Queue() for pe in rt.pes
        }

        if self.scheduler == "heft":
            self._rank(graph)
        # Static policies assign in submission order so placement (and
        # therefore rimms copy counts) is bit-identical to serial run().
        self._static: Optional[List["PE"]] = None
        if self.scheduler == "round_robin":
            self._static = [rt._schedule(n.task) for n in graph.nodes]

        self._pool = (
            ThreadPoolExecutor(
                max_workers=max(2, len(rt.pes)),
                thread_name_prefix="rimms-xfer",
            )
            if self.prefetch
            else None
        )
        workers = [
            threading.Thread(
                target=self._worker, args=(pe,), name=f"rimms-{pe.name}",
                daemon=True,
            )
            for pe in rt.pes
        ]

        self._t0 = time.perf_counter()
        for w in workers:
            w.start()
        try:
            with self._lock:
                ready = [n.index for n in graph.nodes if not n.deps]
                self._schedule_ready(ready)
            self._done.wait()
        finally:
            for q in self._queues.values():
                q.put(_SENTINEL)
            for w in workers:
                w.join()
            # Reap items abandoned on any queue (a failing worker exits
            # without draining; racing completions can enqueue behind the
            # sentinel): cancel their prefetch futures so no staging runs
            # — or leaves an unretrieved error — after the run ended.
            for q in self._queues.values():
                while True:
                    try:
                        item = q.get_nowait()
                    except queue.Empty:
                        break
                    if item is _SENTINEL:
                        continue
                    _reap_future(item[2])
            if self._pool is not None:
                self._pool.shutdown(wait=True)
        wall = time.perf_counter() - self._t0
        if self._error is not None:
            raise self._error
        rt.last_makespan_model = max(self._model_finish.values(), default=0.0)
        return self._report(graph, wall)

    # -- scheduling ---------------------------------------------------------
    def _rank(self, graph: TaskGraph) -> None:
        rt, cm = self.rt, self.rt.cost_model
        bw = rt.context.ledger.bandwidth_model

        def compute_cost(task: "Task") -> float:
            kinds = sorted({pe.kind for pe in rt._eligible(task)})
            return cm.mean_estimate(task.op, kinds, task.in_bytes)

        def comm_cost(task: "Task") -> float:
            return bw.latency_s + task.in_bytes / bw.host_device_bw

        graph.compute_ranks(compute_cost, comm_cost)

    def _pick_pe(self, node: TaskNode) -> "PE":
        """Dynamic placement for a ready node (deps complete ⇒ input flags
        are final). Called under the state lock."""
        rt, task = self.rt, node.task
        if task.pin is not None:
            return rt.by_name[task.pin]
        pes = rt._eligible(task)
        if self.scheduler == "data_affinity":
            return rt._affinity_pick(task, pes)
        # heft: earliest-estimated-finish-time placement, on the same
        # cost basis as serial heft dispatch (Runtime._heft_costs) plus
        # per-PE availability and input-readiness terms.
        ready_m = max(
            (self._model_finish.get(d, 0.0) for d in node.deps), default=0.0
        )

        def eft(pe: "PE") -> float:
            tr, est = rt._heft_costs(task, pe)
            return max(self._sched_avail[pe.name], ready_m + tr) + est

        efts = {pe.name: eft(pe) for pe in pes}
        best = min(pes, key=lambda pe: (efts[pe.name], pe.name))
        self._sched_avail[best.name] = efts[best.name]
        return best

    def _schedule_ready(self, indices: List[int]) -> None:
        """Assign + enqueue newly-ready nodes (under the state lock).
        HEFT processes the batch highest-upward-rank first."""
        nodes = self._graph.nodes
        if self.scheduler == "heft":
            indices = sorted(indices, key=lambda i: -nodes[i].rank)
        for i in indices:
            node = nodes[i]
            pe = self._static[i] if self._static is not None else self._pick_pe(node)
            fut: Optional[Future] = None
            if self._pool is not None:
                # Prefetch: stage inputs now, possibly while `pe` is still
                # busy with an earlier task — transfer/compute overlap.
                fut = self._pool.submit(self.rt._stage_inputs, node.task, pe)
            self._queues[pe.name].put((i, pe, fut))

    # -- workers ------------------------------------------------------------
    def _worker(self, pe: "PE") -> None:
        rt, q = self.rt, self._queues[pe.name]
        while True:
            item = q.get()
            if item is _SENTINEL:
                return
            if self._error is not None:
                # Drain without executing: a peer already failed.
                _reap_future(item[2])
                continue
            i, pe_assigned, fut = item
            node = self._graph.nodes[i]
            try:
                w0 = time.perf_counter()
                if fut is not None:
                    ins, tr_s = fut.result()
                else:
                    ins, tr_s = rt._stage_inputs(node.task, pe_assigned)
                outs, comp_s = rt._run_kernel(node.task, pe_assigned, ins)
                out_s = rt._commit_outputs(node.task, pe_assigned, outs)
                w1 = time.perf_counter()
                # _complete can itself raise while scheduling newly-ready
                # dependents (unknown pin, op with no eligible PE) — it
                # must stay inside the except so the run never hangs.
                self._complete(node, pe_assigned, w0, w1, tr_s, comp_s, out_s)
            except BaseException as e:  # surface to the caller, stop the run
                with self._lock:
                    if self._error is None:
                        self._error = e
                self._done.set()
                return

    def _complete(
        self,
        node: TaskNode,
        pe: "PE",
        w0: float,
        w1: float,
        tr_s: float,
        comp_s: float,
        out_s: float,
    ) -> None:
        rt = self.rt
        with self._lock:
            # Schedule simulation: this task's transfers could start once
            # its inputs existed (ready_m), overlapping the PE's previous
            # compute; its compute starts when both the PE and the staged
            # inputs are available.
            ready_m = max(
                (self._model_finish.get(d, 0.0) for d in node.deps), default=0.0
            )
            # Static compute estimate, not contended measured seconds —
            # keeps the simulation comparable to serial run() (see
            # CostModel.prior_estimate).
            comp_m = rt.cost_model.prior_estimate(
                node.task.op, pe.kind, node.task.in_bytes
            )
            compute_start_m = max(self._pe_model[pe.name], ready_m + tr_s)
            finish_m = compute_start_m + comp_m + out_s
            self._pe_model[pe.name] = finish_m
            self._model_finish[node.index] = finish_m
            rt.timeline.add(TimelineEvent(
                task=node.name, pe=pe.name,
                wall_start=w0 - self._t0, wall_end=w1 - self._t0,
                model_start=max(ready_m, compute_start_m - tr_s),
                model_end=finish_m,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
            ))
            rt.task_log.append((node.name, pe.name))
            self._completed += 1
            newly_ready: List[int] = []
            for s in node.dependents:
                self._remaining[s] -= 1
                if self._remaining[s] == 0:
                    newly_ready.append(s)
            if newly_ready:
                self._schedule_ready(newly_ready)
            if self._completed == len(self._graph):
                self._done.set()

    # -- reporting ----------------------------------------------------------
    def _report(self, graph: TaskGraph, wall: float) -> Dict[str, Any]:
        rt = self.rt
        per_pe: Dict[str, float] = {}
        for ev in rt.timeline.events():
            per_pe[ev.pe] = per_pe.get(ev.pe, 0.0) + (ev.model_end - ev.model_start)
        return {
            "wall_s": wall,
            "makespan_model": rt.last_makespan_model,
            "n_tasks": len(graph),
            "n_edges": graph.n_edges,
            "critical_path": graph.critical_path_len,
            "scheduler": self.scheduler,
            "policy": rt.policy,
            "prefetch": self.prefetch,
            "per_pe_busy_model_s": per_pe,
            "timeline": rt.timeline,
        }
