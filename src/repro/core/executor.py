"""Async task-graph executor: persistent per-PE workers, prefetch, HEFT-lite.

This is the runtime half of the ISSUE-1 subsystem (the DAG half lives in
:mod:`repro.core.graph`).  Execution model:

* a **persistent** :class:`WorkerPool` — one worker thread per PE plus a
  transfer pool — owned by the :class:`~repro.core.runtime.Runtime` and
  reused across ``run_graph`` calls (ISSUE 2): repeated graph launches
  pay no thread setup/teardown;
* **input prefetch**: the moment a task's dependencies complete, its
  input staging (``hete_Data`` flag checks + src→PE copies) is submitted
  to the transfer pool, so the copy overlaps whatever the target PE is
  still computing — the paper's §3.2.2 premise (the runtime knows where
  valid bytes live) finally buys wall-clock, not just copy counts;
* **capacity-aware prefetch** (ISSUE 2): inputs of every scheduled-but-
  incomplete task are *protected* in the :class:`HeteContext`; prefetch
  staging runs under the context's prefetch guard, so it never evicts
  bytes a queued task still reads — if a reservation would require that,
  the prefetch defers (:class:`~repro.core.hete.PrefetchDeferred`).
  Prefetch is pin-free *speculative warming*: the PE worker re-stages
  authoritatively (with hard pins) before executing — a free flag hit
  when the warmed bytes survived, a demand fetch otherwise — so
  concurrent prefetches can never pin an arena full and starve a
  worker's reservation;
* scheduling: ``round_robin`` (static, bit-identical to serial dispatch),
  ``data_affinity`` (dynamic, flag-aware), or ``heft`` — a HEFT
  list scheduler that ranks ready tasks by upward rank and places each
  with an **insertion-based slot search** (ISSUE 3): a task may slide
  into an idle gap on a PE's modeled timeline left by earlier
  placements, not just append after the last one.  Costs come from the
  bandwidth model — routed and **contention-aware** when the context
  uses a :class:`~repro.core.topology.TopologyBandwidthModel`: a
  transfer that would queue on a busy shared link is priced with that
  wait, so placement reacts to link sharing — and the online
  :class:`~repro.core.graph.CostModel`;
* **topology replay** (ISSUE 3): when a topology is active, the modeled
  makespan and Gantt are produced by a deterministic post-run replay of
  the executed schedule — per-link busy-until contention applied in
  (ready-time, submission-index) order — so gated metrics stay exact
  across runs even though worker wall-clock interleaving varies.

Because every PE here is emulated on one physical CPU, the *measured*
wall clock understates the win; the executor therefore also simulates
the schedule it actually executed (modeled transfer + spill-stall
seconds + static compute estimates) and reports a modeled makespan,
directly comparable to the serial :meth:`Runtime.run` modeled makespan.
"""

from __future__ import annotations

import bisect
import heapq
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

from .graph import TaskGraph, TaskNode, build_graph
from .hete import PrefetchDeferred
from .instrument import Timeline, TimelineEvent, TransferEvent

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids import cycle
    from .runtime import PE, Runtime, Task

__all__ = ["GraphExecutor", "WorkerPool", "insert_slot"]

_SHUTDOWN = None


def insert_slot(busy: List[Tuple[float, float]], earliest: float,
                duration: float) -> float:
    """HEFT insertion-based slot search: the earliest start ≥ ``earliest``
    at which ``duration`` fits into the sorted busy-interval list — an
    idle gap between existing placements, or after the last one.
    ``busy`` intervals may abut but never overlap (they are produced by
    :func:`commit_slot`)."""
    t = earliest
    for s, e in busy:
        if t + duration <= s:
            break  # fits entirely in the gap before this interval
        t = max(t, e)
    return t


def commit_slot(busy: List[Tuple[float, float]], start: float,
                duration: float) -> None:
    """Reserve ``[start, start+duration)`` in the sorted interval list."""
    bisect.insort(busy, (start, start + duration))


class WorkerPool:
    """Persistent per-PE worker threads + transfer pool (ISSUE 2).

    Lives on the :class:`Runtime` and is reused by every ``run_graph``
    call; each queue item is ``(executor_run, payload)`` so the same
    threads serve successive runs.  ``shutdown`` is only needed for
    explicit teardown — threads are daemons.
    """

    def __init__(self, pes: Sequence["PE"]) -> None:
        self.pe_names = tuple(pe.name for pe in pes)
        self.queues: Dict[str, "queue.Queue"] = {
            pe.name: queue.Queue() for pe in pes
        }
        self.transfer = ThreadPoolExecutor(
            max_workers=max(2, len(pes)), thread_name_prefix="rimms-xfer",
        )
        self.runs_served = 0
        self._threads = [
            threading.Thread(
                target=self._loop, args=(pe,), name=f"rimms-{pe.name}",
                daemon=True,
            )
            for pe in pes
        ]
        for t in self._threads:
            t.start()

    def submit(self, run: "GraphExecutor", pe_name: str, payload) -> None:
        self.queues[pe_name].put((run, payload))

    def _loop(self, pe: "PE") -> None:
        q = self.queues[pe.name]
        while True:
            item = q.get()
            if item is _SHUTDOWN:
                return
            run, payload = item
            run._process(pe, payload)

    def drain(self, run: "GraphExecutor") -> list:
        """Pop every queued payload belonging to ``run`` (run teardown;
        no other run is active on this pool by construction)."""
        out = []
        for q in self.queues.values():
            while True:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    break
                if item is _SHUTDOWN:
                    q.put(item)  # preserve shutdown signal
                    break
                if item[0] is run:
                    out.append(item[1])
                else:  # pragma: no cover - defensive; runs never overlap
                    q.put(item)
                    break
        return out

    def shutdown(self) -> None:
        for q in self.queues.values():
            q.put(_SHUTDOWN)
        # Join so no daemon thread is left inside a JAX/XLA call at
        # interpreter teardown (std::terminate on some builds).
        for t in self._threads:
            t.join(timeout=5.0)
        self.transfer.shutdown(wait=True)


def _reap_future(fut: Optional[Future]) -> None:
    """Cancel an abandoned prefetch future, or — if it already started —
    wait and swallow its outcome so staging errors are never left
    unretrieved.  Prefetch staging is pin-free speculative warming, so
    there is nothing else to release."""
    if fut is not None and not fut.cancel():
        try:
            fut.exception()
        except BaseException:
            pass


class GraphExecutor:
    """Executes one task list as a DAG on a :class:`Runtime`'s PEs."""

    def __init__(
        self,
        rt: "Runtime",
        *,
        scheduler: Optional[str] = None,
        prefetch: bool = True,
    ) -> None:
        from .runtime import SCHEDULERS  # local: no cycle at module load

        self.rt = rt
        self.scheduler = scheduler or rt.scheduler
        if self.scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        self.prefetch = prefetch
        # interconnect topology, when the context routes transfers
        self._topo = getattr(
            rt.context.ledger.bandwidth_model, "topology", None
        )

    # -- public entry -------------------------------------------------------
    def run(self, tasks: Sequence["Task"]) -> Dict[str, Any]:
        rt = self.rt
        rt.timeline = Timeline()
        graph = build_graph(tasks)
        if not len(graph):
            rt.last_makespan_model = 0.0
            return self._report(graph, 0.0)

        self._graph = graph
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self._remaining = [len(n.deps) for n in graph.nodes]
        self._completed = 0
        self._model_finish: Dict[int, float] = {}
        self._pe_model: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
        # HEFT insertion-based slot search (ISSUE 3): per-PE sorted busy
        # intervals on the scheduler's modeled timeline.
        self._pe_slots: Dict[str, List[Tuple[float, float]]] = {
            pe.name: [] for pe in rt.pes
        }
        if self._topo is not None:
            self._topo.reset_contention()
        # per-task execution records feeding the deterministic topology
        # replay: (index, pe name, moves, comp_m, spill_s, out_s, tr_s,
        # comp_s, w0, w1)
        self._records: Dict[int, tuple] = {}
        # run lifecycle: late items (after teardown) are abandoned, and
        # teardown waits until in-flight items leave the workers
        self._finished = False
        self._inflight = 0
        self._quiet = threading.Condition()

        if self.scheduler == "heft":
            self._rank(graph)
        # Static policies assign in submission order so placement (and
        # therefore rimms copy counts) is bit-identical to serial run().
        self._static: Optional[List["PE"]] = None
        if self.scheduler == "round_robin":
            self._static = [rt._schedule(n.task) for n in graph.nodes]

        pool = rt._get_worker_pool()
        pool.runs_served += 1
        self._pool = pool

        self._t0 = time.perf_counter()
        try:
            with self._lock:
                ready = [n.index for n in graph.nodes if not n.deps]
                self._schedule_ready(ready)
            self._done.wait()
        finally:
            with self._quiet:
                self._finished = True
                # Wait out in-flight workers FIRST: a completing peer can
                # still enqueue dependents and prefetch futures (failure
                # teardown); only after quiescence is the queue content
                # final.
                while self._inflight:
                    self._quiet.wait()
            # Reap items abandoned on any queue: cancel their prefetch
            # futures — or wait out started ones — and release their pins
            # and protection, so no staging outlives the run unaccounted.
            # (Workers popping later see _finished and abandon likewise.)
            for payload in pool.drain(self):
                self._abandon(payload)
        wall = time.perf_counter() - self._t0
        if self._error is not None:
            raise self._error
        if self._topo is not None:
            self._replay_with_topology()
        else:
            rt.last_makespan_model = max(
                self._model_finish.values(), default=0.0
            )
        return self._report(graph, wall)

    # -- scheduling ---------------------------------------------------------
    def _rank(self, graph: TaskGraph) -> None:
        rt, cm = self.rt, self.rt.cost_model
        bw = rt.context.ledger.bandwidth_model

        def compute_cost(task: "Task") -> float:
            kinds = sorted({pe.kind for pe in rt._eligible(task)})
            return cm.mean_estimate(task.op, kinds, task.in_bytes)

        def comm_cost(task: "Task") -> float:
            return bw.typical(task.in_bytes)

        graph.compute_ranks(compute_cost, comm_cost)

    def _staging_delay(self, task: "Task", pe: "PE", at: float) -> float:
        """Extra modeled wait the task's input transfers would queue on
        busy interconnect links if issued at ``at`` (0 without a
        topology) — the contention term of HEFT placement."""
        if self._topo is None:
            return 0.0
        delay = 0.0
        for hd in task.inputs:
            src = hd.last_location
            if src != pe.location:
                delay = max(delay, self._topo.queue_delay(
                    src, pe.location, hd.nbytes, at=at))
        return delay

    def _pick_pe(self, node: TaskNode) -> "PE":
        """Dynamic placement for a ready node (deps complete ⇒ input flags
        are final). Called under the state lock."""
        rt, task = self.rt, node.task
        if task.pin is not None:
            return rt.by_name[task.pin]
        pes = rt._eligible(task)
        if self.scheduler == "data_affinity":
            return rt._affinity_pick(task, pes)
        # heft: earliest-estimated-finish-time placement, on the same
        # cost basis as serial heft dispatch (Runtime._heft_costs) plus
        # input-readiness, link-contention, and an insertion-based slot
        # search over each PE's modeled busy intervals (ISSUE 3).
        ready_m = max(
            (self._model_finish.get(d, 0.0) for d in node.deps), default=0.0
        )

        def placement(pe: "PE") -> Tuple[float, float, float]:
            tr, est = rt._heft_costs(task, pe)
            earliest = ready_m + tr + self._staging_delay(task, pe, ready_m)
            start = insert_slot(self._pe_slots[pe.name], earliest, est)
            return start + est, start, est

        efts = {pe.name: placement(pe) for pe in pes}
        best = min(pes, key=lambda pe: (efts[pe.name][0], pe.name))
        _, start, est = efts[best.name]
        commit_slot(self._pe_slots[best.name], start, est)
        if self._topo is not None:
            # Commit this task's expected link traffic so later
            # placements see the shared links as busy.
            for hd in task.inputs:
                src = hd.last_location
                if src != best.location:
                    self._topo.transfer(src, best.location, hd.nbytes,
                                        at=ready_m, commit=True)
        return best

    def _schedule_ready(self, indices: List[int]) -> None:
        """Assign + enqueue newly-ready nodes (under the state lock).
        HEFT processes the batch highest-upward-rank first.  Each node's
        inputs are protected at its PE until completion — the contract
        behind capacity-aware prefetch."""
        nodes = self._graph.nodes
        ctx = self.rt.context
        if self.scheduler == "heft":
            indices = sorted(indices, key=lambda i: -nodes[i].rank)
        for i in indices:
            node = nodes[i]
            pe = self._static[i] if self._static is not None else self._pick_pe(node)
            for hd in node.task.inputs:
                ctx.protect(hd, pe.location)
            fut: Optional[Future] = None
            if self.prefetch:
                # Prefetch: stage inputs now, possibly while `pe` is still
                # busy with an earlier task — transfer/compute overlap.
                fut = self._pool.transfer.submit(
                    self._prefetch_stage, node.task, pe
                )
            self._pool.submit(self, pe.name, (i, pe, fut))

    def _prefetch_stage(self, task: "Task", pe: "PE"):
        """Speculative pin-free staging on the transfer pool.  Returns
        ``(staged, eviction_epochs)`` — the worker reuses ``staged`` only
        if every input root's eviction epoch is unchanged once pinned —
        or None when capacity pressure defers to demand staging (never
        evicting bytes another queued task still reads)."""
        try:
            staged = self.rt._stage_inputs(task, pe, prefetch=True)
        except PrefetchDeferred:
            return None
        return staged, tuple(hd.root.eviction_epoch for hd in task.inputs)

    # -- workers ------------------------------------------------------------
    def _process(self, pe: "PE", payload: tuple) -> None:
        """Execute one queued payload on its PE worker thread.  Called by
        the persistent pool; must never kill the worker thread."""
        with self._quiet:
            if self._finished:
                live = False
            else:
                live = True
                self._inflight += 1
        if not live:
            self._abandon(payload)
            return
        try:
            if self._error is not None:
                # A peer already failed: drain without executing.
                self._abandon(payload)
                return
            i, pe_assigned, fut = payload
            node = self._graph.nodes[i]
            unprotected = False
            try:
                w0 = time.perf_counter()
                pre = fut.result() if fut is not None else None
                loc = pe_assigned.location
                staged = None
                if pre is not None:
                    # Pin first, then validate: once pinned the inputs
                    # cannot be evicted, so unchanged eviction epochs
                    # prove the prefetched staging is still current.
                    pre_staged, epochs = pre
                    self.rt._pin_inputs(node.task, loc)
                    if all(hd.root.eviction_epoch == ep for hd, ep in
                           zip(node.task.inputs, epochs)):
                        staged = pre_staged
                    else:  # pressure evicted warmed bytes: stage on demand
                        self.rt._unpin_inputs(node.task, loc)
                if staged is None:
                    # no prefetch, prefetch deferred, or warmed bytes
                    # evicted — authoritative pinned staging
                    staged = self.rt._stage_inputs(node.task, pe_assigned)
                    if pre is not None:  # account the wasted warm-up too
                        staged = (staged[0], staged[1] + pre[0][1],
                                  staged[2] + pre[0][2],
                                  pre[0][3] + staged[3])
                ins, tr_s, sp_s, moves = staged
                try:
                    outs, comp_s = self.rt._run_kernel(node.task, pe_assigned, ins)
                    out_s, sp2_s = self.rt._commit_outputs(
                        node.task, pe_assigned, outs
                    )
                finally:
                    self.rt._unpin_inputs(node.task, pe_assigned.location)
                w1 = time.perf_counter()
                # This task no longer reads its inputs: release the
                # queued-reader claim exactly once, before dependents are
                # scheduled (inside _complete).
                self._unprotect(node, pe_assigned)
                unprotected = True
                # _complete can itself raise while scheduling newly-ready
                # dependents (unknown pin, op with no eligible PE) — it
                # must stay inside the except so the run never hangs.
                self._complete(node, pe_assigned, w0, w1, tr_s,
                               sp_s + sp2_s, comp_s, out_s, moves)
            except BaseException as e:  # surface to the caller, stop the run
                with self._lock:
                    if self._error is None:
                        self._error = e
                if not unprotected:
                    self._unprotect(node, pe_assigned)
                self._done.set()
        finally:
            with self._quiet:
                self._inflight -= 1
                self._quiet.notify_all()

    def _unprotect(self, node: TaskNode, pe: "PE") -> None:
        for hd in node.task.inputs:
            self.rt.context.unprotect(hd, pe.location)

    def _abandon(self, payload: tuple) -> None:
        """Release claims of a payload that will never execute: reap its
        prefetch future and drop the queued-reader protection."""
        i, pe, fut = payload
        _reap_future(fut)
        self._unprotect(self._graph.nodes[i], pe)

    def _complete(
        self,
        node: TaskNode,
        pe: "PE",
        w0: float,
        w1: float,
        tr_s: float,
        spill_s: float,
        comp_s: float,
        out_s: float,
        moves: Sequence[tuple] = (),
    ) -> None:
        rt = self.rt
        with self._lock:
            # Schedule simulation: this task's transfers could start once
            # its inputs existed (ready_m), overlapping the PE's previous
            # compute; its compute starts when both the PE and the staged
            # inputs are available.  Spill stalls extend staging.
            ready_m = max(
                (self._model_finish.get(d, 0.0) for d in node.deps), default=0.0
            )
            # Static compute estimate, not contended measured seconds —
            # keeps the simulation comparable to serial run() (see
            # CostModel.prior_estimate).
            comp_m = rt.cost_model.prior_estimate(
                node.task.op, pe.kind, node.task.in_bytes
            )
            stage_s = tr_s + spill_s
            compute_start_m = max(self._pe_model[pe.name], ready_m + stage_s)
            finish_m = compute_start_m + comp_m + out_s
            self._pe_model[pe.name] = finish_m
            self._model_finish[node.index] = finish_m
            rt.timeline.add(TimelineEvent(
                task=node.name, pe=pe.name,
                wall_start=w0 - self._t0, wall_end=w1 - self._t0,
                model_start=max(ready_m, compute_start_m - stage_s),
                model_end=finish_m,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
                spill_s=spill_s,
            ))
            rt.task_log.append((node.name, pe.name))
            self._records[node.index] = (
                pe.name, tuple(moves), comp_m, spill_s, out_s, tr_s,
                comp_s, w0 - self._t0, w1 - self._t0,
            )
            self._completed += 1
            newly_ready: List[int] = []
            for s in node.dependents:
                self._remaining[s] -= 1
                if self._remaining[s] == 0:
                    newly_ready.append(s)
            # A peer failed: the run is tearing down — don't feed new
            # work (or prefetch staging) into a dying run.
            if newly_ready and self._error is None:
                self._schedule_ready(newly_ready)
            if self._completed == len(self._graph):
                self._done.set()

    # -- topology replay (ISSUE 3) ------------------------------------------
    def _replay_with_topology(self) -> None:
        """Deterministically re-simulate the executed schedule under
        per-link contention.

        The online simulation in :meth:`_complete` runs in worker
        completion order, which varies run to run — fine for scalar
        accounting (it is order-independent) but not for link busy-until
        state.  This replay processes the same placements, transfers and
        compute estimates in (ready-time, submission-index) order:
        a task's input copies are issued the moment its dependencies
        finish, walk their routes through link contention (a shared
        bridge serializes them), and compute starts when both the staged
        bytes and the PE are free.  It rebuilds the timeline — including
        per-link transfer lanes — and the modeled makespan, so
        topology-gated metrics are exact across runs."""
        rt, topo, graph = self.rt, self._topo, self._graph
        topo.reset_contention()
        timeline = Timeline()
        pe_free: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
        finish: Dict[int, float] = {}
        remaining = [len(n.deps) for n in graph.nodes]
        heap: List[Tuple[float, int]] = [
            (0.0, n.index) for n in graph.nodes if not n.deps
        ]
        heapq.heapify(heap)
        while heap:
            ready_m, i = heapq.heappop(heap)
            node = graph.nodes[i]
            (pe_name, moves, comp_m, spill_s, out_s, tr_s, comp_s,
             w0, w1) = self._records[i]
            stage_end = ready_m
            for src, dst, nbytes in moves:
                _, end, hops = topo.transfer(src, dst, nbytes, at=ready_m,
                                             commit=True)
                for link, hs, he in hops:
                    timeline.add_transfer(TransferEvent(
                        link=link.label, task=node.name, nbytes=nbytes,
                        model_start=hs, model_end=he,
                    ))
                stage_end = max(stage_end, end)
            start = max(pe_free[pe_name], stage_end + spill_s)
            end = start + comp_m + out_s
            pe_free[pe_name] = end
            finish[i] = end
            stage_s = (stage_end - ready_m) + spill_s
            timeline.add(TimelineEvent(
                task=node.name, pe=pe_name, wall_start=w0, wall_end=w1,
                model_start=max(ready_m, start - stage_s), model_end=end,
                transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
                spill_s=spill_s,
            ))
            for s in node.dependents:
                remaining[s] -= 1
                if remaining[s] == 0:
                    heapq.heappush(heap, (
                        max(finish[d] for d in graph.nodes[s].deps), s
                    ))
        rt.timeline = timeline
        rt.last_makespan_model = max(finish.values(), default=0.0)

    # -- reporting ----------------------------------------------------------
    def _report(self, graph: TaskGraph, wall: float) -> Dict[str, Any]:
        rt = self.rt
        per_pe: Dict[str, float] = {}
        for ev in rt.timeline.events():
            per_pe[ev.pe] = per_pe.get(ev.pe, 0.0) + (ev.model_end - ev.model_start)
        ledger = rt.context.ledger
        return {
            "wall_s": wall,
            "makespan_model": rt.last_makespan_model,
            "n_tasks": len(graph),
            "n_edges": graph.n_edges,
            "critical_path": graph.critical_path_len,
            "scheduler": self.scheduler,
            "policy": rt.policy,
            "prefetch": self.prefetch,
            "topology": self._topo.name if self._topo is not None else None,
            "per_pe_busy_model_s": per_pe,
            "timeline": rt.timeline,
            "spill_stall_model_s": rt.timeline.total_spill_s,
            "evictions": ledger.total_evictions,
            "prefetch_deferrals": ledger.prefetch_deferrals,
        }
