"""Multi-tenant QoS for session streams (ISSUE 5).

PR 4 made one :class:`~repro.core.api.Session` the front door for N
concurrent client threads — but admission was first-come-first-served
and unbounded, so one greedy client could flood the stream, starve the
others' placement, and pin a whole device arena.  This module is the
arbitration layer between submitters and the
:class:`~repro.core.executor.StreamExecutor`:

* **per-client backpressure** — every client (explicit
  :meth:`~repro.core.api.Session.client` handle or the implicit
  per-thread client) has a bounded *in-flight window*: ``submit`` blocks
  while the client already has ``window`` admitted-but-incomplete tasks
  (or raises :class:`BackpressureFull` under ``nowait=True``), keeping
  the admitted frontier small enough for windowed HEFT to stay
  effective;
* **weighted fair admission** — when submissions wait (their own window
  or the stream's optional global window is full), freed slots are
  granted by a **deficit round-robin** over the waiting clients: each
  round credits every backlogged client ``quantum × weight`` bytes of
  deficit, and a client is granted its head-of-line submission only
  when its deficit covers the task's byte cost — so admitted service
  converges to the configured weight ratios, independent of how
  aggressively each client submits;
* **per-tenant arena quotas** — :class:`QuotaExceeded` (an
  :class:`~repro.core.allocator.AllocError`) is the *per-tenant*
  exhaustion signal: a tenant exceeding its reservation budget in a
  device arena fails alone (see :meth:`~repro.core.hete.HeteContext.set_quota`),
  instead of exhausting the arena for everyone;
* **deterministic QoS replay** — :func:`fair_replay` extends the
  executor's deterministic schedule replay with a virtual re-enactment
  of admission itself: each client's recorded task sequence is released
  through its window and the DRR queue in *modeled* time, so per-client
  latency and fairness metrics depend only on every client's own
  submission order (deterministic) — never on wall-clock thread
  interleaving — and can be gated in CI byte-exactly
  (``benchmarks/bench_multitenant.py``).

Per-client observability (task/byte/stall/eviction counters and the
Jain's-index ``fairness_report``) lives on the
:class:`~repro.core.instrument.TransferLedger`.
"""

from __future__ import annotations

import heapq
import itertools
import math
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from .allocator import AllocError
from .instrument import Timeline, TimelineEvent, TransferEvent

__all__ = [
    "BackpressureFull",
    "QuotaExceeded",
    "ClientState",
    "DrrWheel",
    "QoSManager",
    "admission_cost",
    "fair_replay",
    "DEFAULT_CLIENT",
]

#: client name tasks fall under when no client was named (fair_replay
#: groups them into one unbounded tenant, preserving pre-QoS behaviour).
DEFAULT_CLIENT = "_default"


class BackpressureFull(RuntimeError):
    """``submit(nowait=True)`` found the client's in-flight window (or
    the stream's global admission window) full — resubmit after a
    completion, or use the blocking default."""


class QuotaExceeded(AllocError):
    """A tenant's arena reservation budget is exhausted.  Unlike a plain
    capacity :class:`~repro.core.allocator.AllocError`, this failure is
    *per-tenant*: the arena may still have room for other tenants, and
    only the offending tenant's task subtree fails."""

    def __init__(self, msg: str, *, tenant: Optional[str] = None,
                 location: Any = None) -> None:
        super().__init__(msg)
        self.tenant = tenant
        self.location = location


def admission_cost(task: Any) -> int:
    """DRR byte cost of admitting one task: its input + output bytes
    (floored at 1 so zero-byte tasks still consume deficit).  Shared by
    live admission (:meth:`QoSManager.admit` callers) and the virtual
    admission in :func:`fair_replay`, so both charge identically."""
    return max(1, int(task.in_bytes) + int(task.out_bytes))


class ClientState:
    """One tenant's QoS state: configuration (weight, in-flight window,
    optional arena quota) plus the manager-owned live counters.  Mutable
    fields are guarded by the owning :class:`QoSManager`'s lock."""

    __slots__ = ("name", "weight", "window", "quota_bytes", "think_s",
                 "slo_latency_s", "slo_target",
                 "inflight", "deficit", "admitted", "waiting")

    def __init__(self, name: str, *, weight: float = 1.0, window: int = 64,
                 quota_bytes: Optional[int] = None,
                 think_s: float = 0.0,
                 slo_latency_s: Optional[float] = None,
                 slo_target: float = 0.99) -> None:
        if weight <= 0:
            raise ValueError(f"client weight must be > 0, got {weight}")
        if window <= 0:
            raise ValueError(f"client window must be > 0, got {window}")
        if think_s < 0:
            raise ValueError(f"client think_s must be >= 0, got {think_s}")
        if slo_latency_s is not None and slo_latency_s <= 0:
            raise ValueError(
                f"client slo_latency_s must be > 0, got {slo_latency_s}")
        if not 0.0 < slo_target < 1.0:
            raise ValueError(
                f"client slo_target must be in (0, 1), got {slo_target}")
        self.name = name
        self.weight = float(weight)
        self.window = int(window)
        self.quota_bytes = quota_bytes
        # Closed-loop think time (ISSUE 7 satellite, carried from PR 5):
        # the modeled pause between one of this client's tasks finishing
        # and its next submission becoming admissible.  0 = open-loop
        # (submissions are available as fast as windows allow).  Only the
        # deterministic replay (fair_replay) consumes it — live
        # admission sees real submission timing.
        self.think_s = float(think_s)
        # Per-tenant latency SLO (ISSUE 8): a modeled-latency objective
        # this tenant declared.  None = no objective; qos_report() grows
        # an ``slo`` section (burn rate, breached flag) and the trace
        # gains alert instants for tenants that set one.
        self.slo_latency_s = (None if slo_latency_s is None
                              else float(slo_latency_s))
        self.slo_target = float(slo_target)
        self.inflight = 0  # admitted-but-incomplete tasks
        self.deficit = 0.0  # DRR byte credit (only while backlogged)
        self.admitted = 0  # total grants (diagnostics)
        self.waiting: deque = deque()  # (ticket, byte cost) FIFO

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ClientState({self.name!r}, weight={self.weight}, "
                f"window={self.window}, inflight={self.inflight}, "
                f"waiting={len(self.waiting)})")


class DrrWheel:
    """Token-passing deficit round robin — the grant-order core shared
    by the live :class:`QoSManager` and the virtual admission in
    :func:`fair_replay` (so both produce the same weighted order).

    The *token* stays with one client while its deficit covers its
    head-of-line cost; each fresh visit credits ``quantum × weight``.
    A client whose deficit runs dry (or who becomes ineligible) passes
    the token on; a full ineligible-or-unaffordable cycle fast-forwards
    every eligible client's deficit by whole rounds, so a grant costs
    O(clients), never O(cost/quantum).  Deficits die with the backlog
    (:meth:`drained`), as in classic DRR.
    """

    def __init__(self, quantum: int) -> None:
        self.quantum = int(quantum)
        self.order: List[str] = []
        self.deficit: Dict[str, float] = {}
        self.weight: Dict[str, float] = {}
        self.pos = 0
        self.fresh = True

    def add(self, name: str, weight: float) -> None:
        if name not in self.deficit:
            self.order.append(name)
            self.deficit[name] = 0.0
        self.weight[name] = float(weight)

    def drained(self, name: str) -> None:
        """The client's backlog emptied: its unused credit expires."""
        self.deficit[name] = 0.0

    def _advance(self) -> None:
        self.pos = (self.pos + 1) % max(1, len(self.order))
        self.fresh = True

    def next_grant(self, eligible, head_cost) -> Optional[str]:
        """The next client to grant, by token order (its head cost is
        deducted from its deficit).  ``eligible(name)`` says whether the
        client has a waiting submission AND window room; ``head_cost``
        returns its head-of-line byte cost.  Returns None when no client
        is eligible."""
        n = len(self.order)
        if n == 0 or not any(eligible(x) for x in self.order):
            return None
        passes = 0
        while True:
            name = self.order[self.pos % len(self.order)]
            if not eligible(name):
                self._advance()
                passes += 1
            else:
                if self.fresh:
                    self.deficit[name] += self.quantum * self.weight[name]
                    self.fresh = False
                cost = head_cost(name)
                if self.deficit[name] >= cost:
                    self.deficit[name] -= cost
                    return name
                self._advance()
                passes += 1
            if passes > len(self.order):
                # Full cycle, no grant: bulk-replenish whole DRR rounds
                # until the neediest eligible client can afford.
                elig = [x for x in self.order if eligible(x)]
                rounds = max(1, math.ceil(min(
                    (head_cost(x) - self.deficit[x])
                    / (self.quantum * self.weight[x])
                    for x in elig
                )))
                for x in elig:
                    self.deficit[x] += rounds * self.quantum * self.weight[x]
                passes = 0


class QoSManager:
    """Admission arbiter for one session stream: per-client windows, an
    optional global window, and deficit-round-robin grant order among
    waiting clients.

    The *per-client* window is pure backpressure (a client blocks only
    on its own completions); the optional *global* window is the shared
    resource the DRR weights arbitrate — when the admitted frontier is
    capped, freed slots are granted across waiting clients in
    weight-proportional bursts.

    Thread-safe; lock order is strictly *after* the stream lock (the
    session calls :meth:`admit` with no locks held and :meth:`release`
    from the stream's completion callback), so the manager never takes
    another lock while holding its own.
    """

    def __init__(self, *, default_window: int = 64,
                 global_window: Optional[int] = None,
                 quantum_bytes: int = 64 << 10) -> None:
        if quantum_bytes <= 0:
            raise ValueError("quantum_bytes must be > 0")
        self.default_window = int(default_window)
        self.global_window = global_window
        self.quantum_bytes = int(quantum_bytes)
        self._cv = threading.Condition()
        self._clients: Dict[str, ClientState] = {}
        self._wheel = DrrWheel(self.quantum_bytes)
        self._granted: set = set()
        self._n_waiting = 0
        self._total_inflight = 0
        self._seq = itertools.count()

    # -- registration --------------------------------------------------------
    def client(self, name: str, *, weight: Optional[float] = None,
               window: Optional[int] = None,
               quota_bytes: Optional[int] = None,
               think_s: Optional[float] = None,
               slo_latency_s: Optional[float] = None,
               slo_target: Optional[float] = None) -> ClientState:
        """Get-or-create the named client; passed keywords update the
        existing configuration (omitted ones are preserved)."""
        with self._cv:
            st = self._clients.get(name)
            if st is None:
                st = ClientState(
                    name,
                    weight=weight if weight is not None else 1.0,
                    window=window if window is not None else self.default_window,
                    quota_bytes=quota_bytes,
                    think_s=think_s if think_s is not None else 0.0,
                    slo_latency_s=slo_latency_s,
                    slo_target=slo_target if slo_target is not None else 0.99,
                )
                self._clients[name] = st
                self._wheel.add(name, st.weight)
            else:
                if weight is not None:
                    if weight <= 0:
                        raise ValueError("client weight must be > 0")
                    st.weight = float(weight)
                    self._wheel.add(name, st.weight)
                if window is not None:
                    if window <= 0:
                        raise ValueError("client window must be > 0")
                    st.window = int(window)
                if quota_bytes is not None:
                    st.quota_bytes = quota_bytes
                if think_s is not None:
                    if think_s < 0:
                        raise ValueError("client think_s must be >= 0")
                    st.think_s = float(think_s)
                if slo_latency_s is not None:
                    if slo_latency_s <= 0:
                        raise ValueError("client slo_latency_s must be > 0")
                    st.slo_latency_s = float(slo_latency_s)
                if slo_target is not None:
                    if not 0.0 < slo_target < 1.0:
                        raise ValueError(
                            "client slo_target must be in (0, 1)")
                    st.slo_target = float(slo_target)
            return st

    def weights(self) -> Dict[str, float]:
        with self._cv:
            return {n: c.weight for n, c in self._clients.items()}

    def params(self) -> Dict[str, Any]:
        """Deterministic snapshot of the admission configuration — the
        input :func:`fair_replay` re-enacts."""
        with self._cv:
            return {
                "clients": {
                    n: {"weight": c.weight, "window": c.window,
                        "quota_bytes": c.quota_bytes,
                        "think_s": c.think_s,
                        "slo_latency_s": c.slo_latency_s,
                        "slo_target": c.slo_target}
                    for n, c in self._clients.items()
                },
                "default_window": self.default_window,
                "global_window": self.global_window,
                "quantum_bytes": self.quantum_bytes,
            }

    # -- admission -----------------------------------------------------------
    def _has_room(self, st: ClientState) -> bool:
        if st.inflight >= st.window:
            return False
        if (self.global_window is not None
                and self._total_inflight >= self.global_window):
            return False
        return True

    def _grant(self, st: ClientState) -> None:
        st.inflight += 1
        st.admitted += 1
        self._total_inflight += 1

    def admit(self, st: ClientState, cost: int, *, nowait: bool = False,
              timeout: Optional[float] = None) -> float:
        """Admit one submission of byte ``cost`` for client ``st``.
        Fast-paths when nothing is waiting and the windows have room;
        otherwise blocks in the DRR queue (or raises
        :class:`BackpressureFull` under ``nowait=True``).  Returns the
        seconds spent blocked (0.0 on the fast path) — the session
        records it as the client's admission stall."""
        cost = max(1, int(cost))
        with self._cv:
            if self._n_waiting == 0 and self._has_room(st):
                self._grant(st)
                return 0.0
            ticket = next(self._seq)
            st.waiting.append((ticket, cost))
            self._n_waiting += 1
            if nowait:
                # One real DRR pass: the slot may be grantable right now
                # (e.g. other clients' waiters are blocked on their own
                # windows); only an actually-ungrantable submission
                # raises.
                self._pump()
                if ticket in self._granted:
                    self._granted.discard(ticket)
                    return 0.0
                st.waiting = deque(x for x in st.waiting if x[0] != ticket)
                self._n_waiting -= 1
                raise BackpressureFull(
                    f"client {st.name!r} backpressured: {st.inflight}/"
                    f"{st.window} in flight"
                    + ("" if self.global_window is None else
                       f", {self._total_inflight}/{self.global_window} global")
                )
            t0 = time.perf_counter()
            self._pump()
            ok = self._cv.wait_for(lambda: ticket in self._granted, timeout)
            if not ok:
                st.waiting = deque(x for x in st.waiting if x[0] != ticket)
                self._n_waiting -= 1
                raise TimeoutError(
                    f"client {st.name!r} admission timed out after {timeout}s"
                )
            self._granted.discard(ticket)
            return time.perf_counter() - t0

    def release(self, st: ClientState) -> None:
        """One of the client's admitted tasks completed (or failed, or
        was cancelled before reaching the stream): free its slot and
        grant waiting submissions."""
        with self._cv:
            if st.inflight <= 0:
                raise ValueError(f"release without admit for {st.name!r}")
            st.inflight -= 1
            self._total_inflight -= 1
            self._pump()
            self._cv.notify_all()

    def _pump(self) -> None:
        """Grant as many waiting submissions as the windows allow, in
        token-order deficit round robin (called under the lock)."""

        def eligible(name: str) -> bool:
            c = self._clients[name]
            return bool(c.waiting) and c.inflight < c.window

        def head_cost(name: str) -> int:
            return self._clients[name].waiting[0][1]

        while True:
            if (self.global_window is not None
                    and self._total_inflight >= self.global_window):
                return
            name = self._wheel.next_grant(eligible, head_cost)
            if name is None:
                return
            c = self._clients[name]
            ticket, cost = c.waiting.popleft()
            self._n_waiting -= 1
            c.deficit = self._wheel.deficit[name]  # diagnostics mirror
            if not c.waiting:
                self._wheel.drained(name)
                c.deficit = 0.0
            self._grant(c)
            self._granted.add(ticket)
            self._cv.notify_all()

    # -- evidence ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._cv:
            return {
                "total_inflight": self._total_inflight,
                "waiting": self._n_waiting,
                "clients": {
                    n: {"inflight": c.inflight, "admitted": c.admitted,
                        "waiting": len(c.waiting), "weight": c.weight,
                        "window": c.window,
                        "deficit": self._wheel.deficit.get(n, 0.0)}
                    for n, c in self._clients.items()
                },
            }


# ---------------------------------------------------------------------------
# Deterministic QoS-aware schedule replay
# ---------------------------------------------------------------------------


def fair_replay(
    rt: Any,
    nodes: List[Any],
    records: Dict[int, tuple],
    topo: Any = None,
    qos: Any = None,
) -> Tuple[Timeline, float, Dict[int, float], Dict[int, float]]:
    """Re-simulate an executed stream *including admission* in virtual
    time.

    :func:`~repro.core.executor.replay_schedule` treats every recorded
    task as available at its dependency readiness — correct for one
    batch, but blind to multi-tenant pacing: a backlogged client's 96
    roots would all contend at t=0 even though backpressure admitted
    them a window at a time.  This replay re-enacts the QoS policy
    deterministically:

    * each client's recorded tasks form a queue in that client's own
      submission order (deterministic run to run — unlike the global
      interleaving, which is thread-timing);
    * a task is **released** when the virtual DRR admission (weights,
      per-client windows, optional global window — from
      ``qos.params()``) grants it a slot; window slots free at task
      completion in virtual time;
    * execution then follows the recorded placements exactly like
      ``replay_schedule`` — per-PE busy-until, routed per-link
      contention under a topology — but a task can never start before
      ``max(release, dependency finishes)``;
    * a client configured with ``think_s > 0`` is replayed closed-loop
      (ISSUE 7 satellite): after each of its completions the client
      "thinks" for ``think_s`` virtual seconds before its next queued
      submission becomes admissible, so replayed latencies match
      closed-loop submission semantics instead of treating every
      backlog as an open-loop burst.

    Every ordering key is ``(time, client name, within-client seq)``, so
    the result is byte-identical across runs and machines.  Clients are
    rotated in sorted-name order (the live manager rotates in
    registration order, which is thread-raced — the replay substitutes
    its own deterministic rotation).

    Returns ``(timeline, modeled makespan, finish, release)`` with
    ``finish``/``release`` keyed by node index — the per-chain latency
    evidence ``bench_multitenant`` gates on.
    """
    params = qos.params() if isinstance(qos, QoSManager) else dict(qos or {})
    cfg = params.get("clients", {})
    default_window = int(params.get("default_window", 64))
    global_window = params.get("global_window")
    quantum = int(params.get("quantum_bytes", 64 << 10))

    if topo is not None:
        topo.reset_contention()

    by_client: Dict[str, List[int]] = {}
    for i in sorted(records):
        name = nodes[i].task.client or DEFAULT_CLIENT
        by_client.setdefault(name, []).append(i)
    names = sorted(by_client)
    weight = {n: float(cfg.get(n, {}).get("weight", 1.0)) for n in names}
    window = {
        n: (len(by_client[n]) if n == DEFAULT_CLIENT and n not in cfg
            else int(cfg.get(n, {}).get("window", default_window)))
        for n in names
    }
    seq_of: Dict[int, Tuple[str, int]] = {}
    for n, idxs in by_client.items():
        for k, i in enumerate(idxs):
            seq_of[i] = (n, k)

    pending = {n: deque(idxs) for n, idxs in by_client.items()}
    inflight = {n: 0 for n in names}
    # Closed-loop think time (ISSUE 7 satellite): a client with
    # ``think_s > 0`` models a submitter who waits for a completion,
    # "thinks", then submits again — its next pending task becomes
    # admissible no earlier than (previous completion + think_s).  Open
    # loop (think_s = 0, the default) keeps the original semantics:
    # everything a window allows is admissible immediately.
    think = {n: float(cfg.get(n, {}).get("think_s", 0.0)) for n in names}
    next_ok = {n: 0.0 for n in names}
    wakeups: List[Tuple[float, str]] = []  # think-time admission retries
    wheel = DrrWheel(quantum)
    for n in names:  # sorted: the replay's deterministic rotation order
        wheel.add(n, weight[n])
    state = {"total": 0}

    release: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    remaining = {
        i: sum(1 for d in nodes[i].deps if d in records) for i in records
    }
    ready: List[Tuple[float, str, int, int]] = []  # (t, client, seq, idx)
    completions: List[Tuple[float, str, int, int]] = []

    def push_ready(i: int, t: float) -> None:
        c, k = seq_of[i]
        heapq.heappush(ready, (t, c, k, i))

    def admit_at(t: float) -> None:
        def eligible(n: str) -> bool:
            return (bool(pending[n]) and inflight[n] < window[n]
                    and next_ok[n] <= t)

        def head_cost(n: str) -> int:
            return admission_cost(nodes[pending[n][0]].task)

        while True:
            if (global_window is not None
                    and state["total"] >= global_window):
                return
            n = wheel.next_grant(eligible, head_cost)
            if n is None:
                return
            i = pending[n].popleft()
            if not pending[n]:
                wheel.drained(n)
            inflight[n] += 1
            state["total"] += 1
            release[i] = t
            if remaining[i] == 0:
                dep_t = max(
                    (finish[d] for d in nodes[i].deps if d in records),
                    default=0.0,
                )
                push_ready(i, max(t, dep_t))

    timeline = Timeline()
    pe_free: Dict[str, float] = {pe.name: 0.0 for pe in rt.pes}
    admit_at(0.0)
    while ready or completions or wakeups:
        t_r = ready[0][0] if ready else math.inf
        t_c = completions[0][0] if completions else math.inf
        t_w = wakeups[0][0] if wakeups else math.inf
        if t_c <= t_r and t_c <= t_w:
            end, c, _, _ = heapq.heappop(completions)
            inflight[c] -= 1
            state["total"] -= 1
            if think[c] > 0.0:
                # the client observes this completion, thinks, then its
                # next submission becomes admissible
                next_ok[c] = max(next_ok[c], end + think[c])
                if pending[c]:
                    heapq.heappush(wakeups, (next_ok[c], c))
            admit_at(end)
            continue
        if t_w <= t_r:
            t, _ = heapq.heappop(wakeups)
            admit_at(t)
            continue
        ready_m, c, k, i = heapq.heappop(ready)
        node = nodes[i]
        (pe_name, moves, comp_m, spill_s, out_s, tr_s, comp_s,
         w0, w1) = records[i]
        if topo is not None:
            stage_end = ready_m
            for src, dst, nbytes in moves:
                _, end, hops = topo.transfer(src, dst, nbytes, at=ready_m,
                                             commit=True)
                for link, hs, he in hops:
                    timeline.add_transfer(TransferEvent(
                        link=link.label, task=node.name, nbytes=nbytes,
                        model_start=hs, model_end=he, node=i,
                    ))
                stage_end = max(stage_end, end)
        else:
            stage_end = ready_m + tr_s
        start = max(pe_free[pe_name], stage_end + spill_s)
        end = start + comp_m + out_s
        pe_free[pe_name] = end
        finish[i] = end
        stage_s = (stage_end - ready_m) + spill_s
        timeline.add(TimelineEvent(
            task=node.name, pe=pe_name, wall_start=w0, wall_end=w1,
            model_start=max(ready_m, start - stage_s), model_end=end,
            transfer_s=tr_s, compute_s=comp_s, out_transfer_s=out_s,
            spill_s=spill_s, compute_start_m=start, node=i,
        ))
        heapq.heappush(completions, (end, c, k, i))
        for s in sorted(node.dependents):
            if s in remaining and s in records:
                remaining[s] -= 1
                if remaining[s] == 0 and s in release:
                    dep_t = max(
                        (finish[d] for d in nodes[s].deps if d in records),
                        default=0.0,
                    )
                    push_ready(s, max(release[s], dep_t))
    return timeline, max(finish.values(), default=0.0), finish, release
