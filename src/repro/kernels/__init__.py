"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel subpackage ships:
  <name>.py — the pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (padding, reshapes, vmap)
  ref.py    — the pure-jnp oracle used by the allclose test sweeps

``INTERPRET`` is True off-TPU: kernels execute their bodies in Python
via the Pallas interpreter for correctness validation (this container is
CPU-only; TPU v5e is the deployment target).
"""

import jax

INTERPRET = jax.default_backend() != "tpu"
