"""Oracle: paged decode attention via dense gather (pure jnp)."""

import math

import jax
import jax.numpy as jnp


def paged_attention(q, k_pages, v_pages, block_table, lengths):
    """Same signature as the kernel; gathers pages densely."""
    B, hq, d = q.shape
    P, page, n_kv, _ = k_pages.shape
    group = hq // n_kv
    n_pages = block_table.shape[1]
    k = k_pages[block_table].reshape(B, n_pages * page, n_kv, d)
    v = v_pages[block_table].reshape(B, n_pages * page, n_kv, d)
    qg = q.reshape(B, n_kv, group, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    t = jnp.arange(n_pages * page)
    mask = t[None] < lengths[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, hq, d).astype(q.dtype)
