"""Public paged-attention op (thin: the kernel signature is already the
serving-engine-facing one)."""

from .paged_attention import paged_attention  # noqa: F401
