"""Paged decode attention over RIMMS block tables (serving hot spot).

This is the kernel-level integration of the paper's technique: the KV
cache lives in a page pool handed out by the RIMMS marking systems
(:mod:`repro.core.paged_kv`); per-sequence *block tables* (the
``hete_Data`` resource pointers) drive the kernel's BlockSpec index maps
through **scalar prefetch** — page p of sequence b streams
``k_pages[block_table[b, p]]`` HBM→VMEM with no host-side gather and no
dense copy of the cache.

Grid: (batch, n_pages) with pages innermost; online-softmax scratch
persists across a sequence's pages (TPU grids are sequential over the
trailing axis).  GQA is handled in-kernel (no KV repetition in HBM).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import INTERPRET

NEG_INF = -1e30


def _paged_kernel(page_size, n_kv, group, scale,
                  bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hq = n_kv * group
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, group, -1)  # (Hkv,G,d)
    k = k_ref[0].astype(jnp.float32)  # (page, Hkv, d)
    v = v_ref[0].astype(jnp.float32)
    # batched over kv heads: (Hkv, G, d) x (Hkv, page, d) -> (Hkv, G, page)
    s = jax.lax.dot_general(
        q, k.swapaxes(0, 1),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * scale
    pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (n_kv, group, page_size), 2
    )
    valid = pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)
    s2 = s.reshape(hq, page_size)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=1, keepdims=True))
    pexp = jnp.exp(s2 - m_new)  # (Hq, page)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = jnp.broadcast_to(
        alpha * l_ref[:, :1] + jnp.sum(pexp, axis=1, keepdims=True),
        l_ref.shape,
    )
    pv = jax.lax.dot_general(
        pexp.reshape(n_kv, group, page_size), v.swapaxes(0, 1),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (Hkv, G, d)
    acc_ref[...] = acc_ref[...] * alpha + pv.reshape(hq, -1)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(p == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    interpret: bool = INTERPRET):
    """q: (B, Hq, d); k_pages/v_pages: (P, page, Hkv, d);
    block_table: (B, n_pages) int32; lengths: (B,) int32.
    Returns (B, Hq, d)."""
    B, hq, d = q.shape
    P, page, n_kv, _ = k_pages.shape
    group = hq // n_kv
    n_pages = block_table.shape[1]
    scale = 1.0 / math.sqrt(d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda b, p, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, n_kv, d),
                         lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, page, n_kv, d),
                         lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda b, p, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 128), jnp.float32),
            pltpu.VMEM((hq, 128), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, page, n_kv, group, scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, hq, d), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pages, v_pages)
