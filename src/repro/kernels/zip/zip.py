"""ZIP kernel: pointwise complex multiply (the paper's ZIP accelerator,
§4.1 — HLS pointwise vector unit on the ZCU102, cuFFT-style pointwise
stage on the Jetson).

Complex data is carried as separate real/imag planes (TPU VPU has no
complex dtype).  Tiling: (block_rows, 128) f32 tiles in VMEM — lane
dimension 128 to match the VPU registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import INTERPRET

BLOCK_ROWS = 256
LANES = 128


def _zip_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    or_ref[...] = ar * br - ai * bi
    oi_ref[...] = ar * bi + ai * br


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def zip_mul_planes(ar, ai, br, bi, *, block_rows: int = BLOCK_ROWS,
                   interpret: bool = INTERPRET):
    """(rows, 128) f32 planes → complex product planes.  ``block_rows``
    is a pure launch parameter (elementwise op → bit-identical tiling,
    autotuned in ISSUE 10)."""
    rows = ar.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _zip_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 2,
        interpret=interpret,
    )(ar, ai, br, bi)
