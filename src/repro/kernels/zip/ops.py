"""Public ZIP op: complex64 in/out, pads + reshapes to kernel tiles."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .zip import BLOCK_ROWS, LANES, zip_mul_planes


def zip_mul(a: jnp.ndarray, b: jnp.ndarray, *,
            block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """Pointwise complex multiply via the Pallas ZIP kernel.
    ``block_rows`` tunes the row tile (bit-identical across values)."""
    shape = a.shape
    n = a.size
    pad = (-n) % LANES
    def planes(x):
        f = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
        f = f.reshape(-1, LANES)
        return jnp.real(f).astype(jnp.float32), jnp.imag(f).astype(jnp.float32)
    ar, ai = planes(a)
    br, bi = planes(b)
    orr, oi = zip_mul_planes(ar, ai, br, bi, block_rows=block_rows)
    out = (orr + 1j * oi).astype(jnp.complex64).reshape(-1)[:n]
    return out.reshape(shape)
