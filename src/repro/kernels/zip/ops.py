"""Public ZIP op: complex64 in/out, pads + reshapes to kernel tiles."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .zip import LANES, zip_mul_planes


def zip_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pointwise complex multiply via the Pallas ZIP kernel."""
    shape = a.shape
    n = a.size
    pad = (-n) % LANES
    def planes(x):
        f = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
        f = f.reshape(-1, LANES)
        return jnp.real(f).astype(jnp.float32), jnp.imag(f).astype(jnp.float32)
    ar, ai = planes(a)
    br, bi = planes(b)
    orr, oi = zip_mul_planes(ar, ai, br, bi)
    out = (orr + 1j * oi).astype(jnp.complex64).reshape(-1)[:n]
    return out.reshape(shape)
