"""Oracle: pointwise complex multiply."""

import jax.numpy as jnp


def zip_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b: complex64 arrays of identical shape."""
    return a * b
