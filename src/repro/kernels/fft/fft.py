"""Batched radix-2 Stockham FFT kernel (the paper's FFT accelerator, §4.1).

TPU adaptation of the Xilinx FFT IP / cuFFT stage: one VMEM-resident
batch tile (block_rows × N complex as separate re/im planes), iterative
**Stockham autosort** — no bit-reversal permutation, no gather tables:
each of the log2(N) stages is slice + butterfly + concat, with twiddle
factors computed in-kernel from ``broadcasted_iota`` (cos/sin on the
VPU), so the kernel captures no host constants.

Supports power-of-two N (the paper sweeps 64..2048; tests go to 8192).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import INTERPRET

BLOCK_ROWS = 8


def _fft_kernel(n, xr_ref, xi_ref, or_ref, oi_ref):
    stages = int(math.log2(n))
    B = xr_ref.shape[0]
    xr = xr_ref[...].reshape(B, 1, n)
    xi = xi_ref[...].reshape(B, 1, n)
    m = n
    for _ in range(stages):
        m2 = m // 2
        ar, br = xr[:, :, :m2], xr[:, :, m2:]
        ai, bi = xi[:, :, :m2], xi[:, :, m2:]
        k = jax.lax.broadcasted_iota(jnp.float32, (1, 1, m2), 2)
        ang = (-2.0 * math.pi / m) * k
        wr, wi = jnp.cos(ang), jnp.sin(ang)
        sr, si = ar - br, ai - bi
        top_r, top_i = ar + br, ai + bi
        bot_r = sr * wr - si * wi
        bot_i = sr * wi + si * wr
        xr = jnp.concatenate([top_r, bot_r], axis=1)
        xi = jnp.concatenate([top_i, bot_i], axis=1)
        m = m2
    or_ref[...] = xr.reshape(B, n)
    oi_ref[...] = xi.reshape(B, n)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fft_planes(xr, xi, *, block_rows: int = BLOCK_ROWS,
               interpret: bool = INTERPRET):
    """xr, xi: (rows, N) f32 → FFT along axis 1 (rows padded to tiles).
    ``block_rows`` is a pure launch parameter — rows are independent, so
    any tiling produces bit-identical planes (autotuned, ISSUE 10)."""
    rows, n = xr.shape
    spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fft_kernel, n),
        grid=(pl.cdiv(rows, block_rows),),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, n), jnp.float32)] * 2,
        interpret=interpret,
    )(xr, xi)
