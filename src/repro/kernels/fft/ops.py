"""Public FFT op: complex64 batches, forward/inverse."""

from __future__ import annotations

import jax.numpy as jnp

from .fft import BLOCK_ROWS, fft_planes


def fft(x: jnp.ndarray, forward: bool = True, *,
        block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """FFT along the last axis via the Pallas kernel.
    IFFT uses the conjugation identity ifft(x) = conj(fft(conj(x)))/N.
    ``block_rows`` tunes the batch tile (bit-identical across values)."""
    shape = x.shape
    n = shape[-1]
    rows = int(jnp.prod(jnp.asarray(shape[:-1]))) if len(shape) > 1 else 1
    xf = x.reshape(rows, n)
    if not forward:
        xf = jnp.conj(xf)
    pad = (-rows) % block_rows
    xf = jnp.pad(xf, ((0, pad), (0, 0)))
    orr, oi = fft_planes(
        jnp.real(xf).astype(jnp.float32), jnp.imag(xf).astype(jnp.float32),
        block_rows=block_rows,
    )
    out = (orr + 1j * oi).astype(jnp.complex64)[:rows]
    if not forward:
        out = jnp.conj(out) / n
    return out.reshape(shape)
