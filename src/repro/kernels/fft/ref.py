"""Oracle: batched FFT/IFFT via jnp.fft."""

import jax.numpy as jnp


def fft(x: jnp.ndarray, forward: bool = True) -> jnp.ndarray:
    return jnp.fft.fft(x, axis=-1) if forward else jnp.fft.ifft(x, axis=-1)
