"""Public RG-LRU op: pads D to lane multiples."""

from __future__ import annotations

import jax.numpy as jnp

from .rg_lru import LANES, rg_lru_scan as _kernel


def rg_lru_scan(a, b, h0, *, block_lanes: int = LANES):
    """``block_lanes`` tunes lanes per grid step (bit-identical across
    values); clamped down to the largest valid divisor of padded D."""
    B, S, D = a.shape
    pad = (-D) % LANES
    if pad:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad)))
    dp = D + pad
    bl = max(lane for lane in range(LANES, min(block_lanes, dp) + 1, LANES)
             if dp % lane == 0)
    hs, hN = _kernel(a.astype(jnp.float32), b.astype(jnp.float32),
                     h0.astype(jnp.float32), block_lanes=bl)
    return hs[..., :D], hN[..., :D]
