"""Public RG-LRU op: pads D to lane multiples."""

from __future__ import annotations

import jax.numpy as jnp

from .rg_lru import LANES, rg_lru_scan as _kernel


def rg_lru_scan(a, b, h0):
    B, S, D = a.shape
    pad = (-D) % LANES
    if pad:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad)))
    hs, hN = _kernel(a.astype(jnp.float32), b.astype(jnp.float32),
                     h0.astype(jnp.float32))
    return hs[..., :D], hN[..., :D]
