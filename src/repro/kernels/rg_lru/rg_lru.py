"""RG-LRU linear-recurrence kernel (RecurrentGemma prefill hot spot).

h_t = a_t · h_{t-1} + b_t, elementwise over (B, S, D).

XLA's ``associative_scan`` materializes O(log S) intermediate passes over
HBM; this kernel reads a,b once and writes h once — one VMEM-resident
(1, S, 128) lane tile per grid step, sequential fori_loop over time
inside VMEM (the op is memory-bound; arithmetic is negligible).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import INTERPRET

LANES = 128


def _rg_lru_kernel(a_ref, b_ref, h0_ref, o_ref, hN_ref):
    S = a_ref.shape[1]
    a = a_ref[0]  # (S, LANES)
    b = b_ref[0]

    def body(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, S, body, h0_ref[0])
    hN_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_lanes", "interpret"))
def rg_lru_scan(a, b, h0, *, block_lanes: int = LANES,
                interpret: bool = INTERPRET):
    """a, b: (B, S, D) f32; h0: (B, D) initial state.
    Returns (h_seq (B,S,D), h_final (B,D)).  ``block_lanes`` (a multiple
    of 128 dividing D) tunes lanes per grid step — the recurrence is
    elementwise over lanes, so any tiling is bit-identical (ISSUE 10)."""
    B, S, D = a.shape
    assert block_lanes % LANES == 0 and D % block_lanes == 0, (D, block_lanes)
    grid = (B, D // block_lanes)
    seq_spec = pl.BlockSpec((1, S, block_lanes), lambda i, j: (i, 0, j))
    vec_spec = pl.BlockSpec((1, block_lanes), lambda i, j: (i, j))
    return pl.pallas_call(
        _rg_lru_kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, vec_spec],
        out_specs=[seq_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, h0)
