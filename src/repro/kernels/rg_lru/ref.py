"""Oracle: RG-LRU linear recurrence via associative_scan."""

import jax


def rg_lru_scan(a, b, h0):
    """a, b: (B,S,D); h0: (B,D) → (h_seq, h_final)."""
    # fold h0 into the first step: h_1 = a_1 h0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    hs = hs.swapaxes(0, 1)
    return hs, hs[:, -1]
