"""Causal flash attention kernel (train / prefill hot spot).

Standard TPU pallas flash pattern: grid (batch·heads, q_blocks,
k_blocks) with the k dimension innermost — TPU grids execute
sequentially over the last axis, so VMEM scratch (running max m, sum l,
accumulator acc) persists across k blocks of one q block (online
softmax).  BlockSpecs stream (block, head_dim) tiles of Q/K/V from HBM;
VMEM per step ≈ 4 · block · head_dim · 4 B.

Fully-masked k blocks (k_start > q_end) are skipped via ``pl.when``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import INTERPRET

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(block_q, block_k, scale, causal,
                  q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_bh(q, k, v, *, causal: bool = True,
                       block_q: int = DEFAULT_BLOCK_Q,
                       block_k: int = DEFAULT_BLOCK_K,
                       interpret: bool = INTERPRET):
    """q,k,v: (BH, S, d) — batch·heads flattened. Returns (BH, S, d)."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    scale = 1.0 / math.sqrt(d)
    grid = (bh, pl.cdiv(s, block_q), pl.cdiv(s, block_k))
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q, block_k, scale, causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),  # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
