"""Public flash-attention op: (B,S,H,d) GQA layout → kernel layout."""

from __future__ import annotations

import jax.numpy as jnp

from .flash_attention import flash_attention_bh


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256):
    """q: (B,S,Hq,d); k,v: (B,S,Hkv,d) with Hq % Hkv == 0.
    Returns (B,S,Hq,d)."""
    B, S, Hq, d = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * Hq, S, d)
    o = flash_attention_bh(to_bh(q), to_bh(k), to_bh(v), causal=causal,
                           block_q=block_q, block_k=block_k)
    return o.reshape(B, Hq, S, d).transpose(0, 2, 1, 3)
