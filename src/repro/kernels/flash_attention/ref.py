"""Oracle: dense causal attention in fp32."""

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, causal: bool = True):
    """q,k,v: (BH, S, d)."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
