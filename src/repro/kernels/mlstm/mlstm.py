"""Chunkwise mLSTM kernel (xLSTM matrix-memory recurrence).

Grid (batch·heads, n_chunks) with chunks innermost: the (m × m) matrix
memory ``C`` and normalizer ``n`` live in VMEM scratch across a
sequence's chunks (TPU grids are sequential over the trailing axis), so
the state never round-trips HBM between chunks — the chunk-boundary
states that XLA's ``associative_scan`` path materializes (O(S/c · m²)
HBM) stay on-chip.

Per chunk (c tokens): intra-chunk quadratic term (c×c MXU matmuls with
cumulative-gate decay), inter-chunk term against the carried state, and
the stabilizer-free sigmoid gating used by the model (see
models/recurrent.py for the numerics note).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import INTERPRET


def _mlstm_kernel(chunk, q_ref, k_ref, v_ref, i_ref, lf_ref, o_ref,
                  C_ref, n_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0].astype(jnp.float32)  # (c, m)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    ii = i_ref[0, :, 0]  # (c,)
    lf = lf_ref[0, :, 0]
    cum = jnp.cumsum(lf)  # (c,)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, c)
    dlt = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) <= (
        jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    )
    A = jnp.where(mask, scores * jnp.exp(dlt) * ii[None, :], 0.0)

    C = C_ref[...]
    nv = n_ref[0]
    ecum = jnp.exp(cum)[:, None]  # (c,1)
    num = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + ecum * jax.lax.dot_general(
        q, C, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den = jnp.sum(A, axis=1, keepdims=True) + ecum * jax.lax.dot_general(
        q, nv[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = (num / jnp.maximum(jnp.abs(den), 1.0)).astype(o_ref.dtype)

    # carry the chunk-boundary state forward in VMEM
    w_s = (jnp.exp(cum[-1] - cum) * ii)[:, None]  # (c,1)
    C_ref[...] = jnp.exp(cum[-1]) * C + jax.lax.dot_general(
        k * w_s, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_ref[...] = jnp.exp(cum[-1]) * n_ref[...] + jnp.sum(
        k * w_s, axis=0, keepdims=True
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise_bh(q, k, v, i_gate, log_f, *, chunk: int = 64,
                       interpret: bool = INTERPRET):
    """q,k,v: (BH, S, m) with q pre-scaled by 1/sqrt(m);
    i_gate, log_f: (BH, S) fp32.  Returns h: (BH, S, m)."""
    bh, s, m = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    qkv_spec = pl.BlockSpec((1, chunk, m), lambda b, j: (b, j, 0))
    gate_spec = pl.BlockSpec((1, chunk, 1), lambda b, j: (b, j, 0))
    return pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk),
        grid=grid,
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, m), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((m, m), jnp.float32),  # matrix memory C
            pltpu.VMEM((1, m), jnp.float32),  # normalizer n
        ],
        interpret=interpret,
    )(q, k, v, i_gate[..., None], log_f[..., None])
