"""Public mLSTM op: (B, S, H, m) layout → kernel layout."""

from __future__ import annotations

import math

import jax.numpy as jnp

from .mlstm import mlstm_chunkwise_bh


def mlstm_chunkwise(q, k, v, i_gate, log_f, *, chunk: int = 64):
    """q,k,v: (B,S,H,m) (q unscaled); i_gate/log_f: (B,S,H) fp32.
    Returns (B,S,H,m)."""
    B, S, H, m = q.shape

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, m)

    def g_bh(x):
        return x.transpose(0, 2, 1).reshape(B * H, S)

    h = mlstm_chunkwise_bh(
        to_bh(q / math.sqrt(m)), to_bh(k), to_bh(v),
        g_bh(i_gate.astype(jnp.float32)), g_bh(log_f.astype(jnp.float32)),
        chunk=chunk,
    )
    return h.reshape(B, H, S, m).transpose(0, 2, 1, 3)
