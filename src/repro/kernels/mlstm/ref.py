"""Oracle: sequential (per-token) mLSTM recurrence in fp32."""

import jax.numpy as jnp
import numpy as np


def mlstm_sequential(q, k, v, i_gate, log_f):
    """q,k,v: (BH, S, m) with q pre-scaled; gates (BH, S).
    h_t = (q_t C_t) / max(|q_t·n_t|, 1);
    C_t = f_t C_{t-1} + i_t k_t v_tᵀ;  n_t = f_t n_{t-1} + i_t k_t."""
    q, k, v = (np.asarray(t, np.float64) for t in (q, k, v))
    ii = np.asarray(i_gate, np.float64)
    f = np.exp(np.asarray(log_f, np.float64))
    BH, S, m = q.shape
    h = np.zeros((BH, S, m))
    for b in range(BH):
        C = np.zeros((m, m))
        n = np.zeros((m,))
        for t in range(S):
            C = f[b, t] * C + ii[b, t] * np.outer(k[b, t], v[b, t])
            n = f[b, t] * n + ii[b, t] * k[b, t]
            den = max(abs(q[b, t] @ n), 1.0)
            h[b, t] = (q[b, t] @ C) / den
    return jnp.asarray(h, jnp.float32)
