"""Calibration CLI (ISSUE 10): measure, inspect, and diff calibration
tables from the command line.

    python -m repro.calibrate run --out calib.json          # measure
    python -m repro.calibrate show calib.json               # markdown
    python -m repro.calibrate show calib.json --json        # raw state
    python -m repro.calibrate diff old.json new.json        # what moved
    python -m repro.calibrate --report calib.json ...       # nightly step

``run`` builds an emulated session (thread or process backend),
registers the Pallas autotuning variants plus the radar app's ops, and
races every variant per PE kind across the shape-bucket ladder; the
resulting "rimms-calib-v1" file feeds ``Session(calibration=...)``.
``--report`` is the multi-file markdown form the nightly bench workflow
appends to its step summary.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.core.calibrate import DEFAULT_LADDER, CalibrationTable

__all__ = ["main"]


def _parse_ladder(text: str) -> List[int]:
    out = []
    for part in text.split(","):
        part = part.strip().lower()
        if not part:
            continue
        mult = 1
        for suffix, m in (("kib", 1 << 10), ("mib", 1 << 20), ("k", 1 << 10),
                          ("m", 1 << 20)):
            if part.endswith(suffix):
                part, mult = part[: -len(suffix)], m
                break
        out.append(int(float(part) * mult))
    if not out:
        raise argparse.ArgumentTypeError("empty ladder")
    return out


def _cmd_run(args) -> int:
    # heavy imports deferred so `show`/`diff` stay fast
    import repro.apps.radar  # noqa: F401  (registers radar ops + calib)
    from repro.core.api import Session
    from repro.core.autotune import autotune

    accelerators = tuple(a for a in args.accelerators.split(",") if a)
    session = Session.emulated(n_cpu=args.n_cpu, accelerators=accelerators,
                               backend=args.backend)
    try:
        table = autotune(session, nbytes=args.ladder, k=args.k,
                         warmup=args.warmup, seed=args.seed,
                         verbose=args.verbose,
                         extra_ops=("fft", "ifft", "zip"))
        table.meta["cli"] = {
            "n_cpu": args.n_cpu, "accelerators": list(accelerators),
            "backend": session.runtime.backend,
        }
        session.save_calibration(args.out)
    finally:
        session.close()
    n_win = sum(1 for _, w in table.winners()
                if w.get("variant") != "default")
    print(f"wrote {args.out}: {len(table)} cells, "
          f"{len(table.winners())} winner rows "
          f"({n_win} non-default)", file=sys.stderr)
    if args.markdown:
        print(table.to_markdown())
    return 0


def _cmd_show(args) -> int:
    table = CalibrationTable.load(args.table)
    if args.json:
        json.dump(table.state(), sys.stdout, indent=1, sort_keys=True)
        print()
    else:
        print(table.to_markdown())
    return 0


def _cmd_diff(args) -> int:
    a = CalibrationTable.load(args.a)
    b = CalibrationTable.load(args.b)
    delta = a.diff(b)
    json.dump(delta, sys.stdout, indent=1, sort_keys=True)
    print()
    return 1 if delta and args.exit_code else 0


def _cmd_report(paths: List[str]) -> int:
    status = 0
    for path in paths:
        try:
            table = CalibrationTable.load(path)
        except (OSError, ValueError) as e:
            print(f"error: {path}: {e}", file=sys.stderr)
            status = 1
            continue
        print(f"# Calibration report — {path}\n")
        print(table.to_markdown())
        print()
    return status


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "--report":
        if not argv[1:]:
            print("usage: python -m repro.calibrate --report TABLE...",
                  file=sys.stderr)
            return 2
        return _cmd_report(argv[1:])

    ap = argparse.ArgumentParser(
        prog="python -m repro.calibrate",
        description="Measure, inspect, and diff RIMMS calibration tables "
                    "(rimms-calib-v1).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="measure a calibration table")
    run.add_argument("--out", required=True, metavar="TABLE.json")
    run.add_argument("--backend", default="thread",
                     choices=("thread", "process"))
    run.add_argument("--n-cpu", type=int, default=2)
    run.add_argument("--accelerators", default="gpu0",
                     help="comma-separated accelerator names (default gpu0)")
    run.add_argument("--ladder", type=_parse_ladder,
                     default=list(DEFAULT_LADDER),
                     help="comma-separated input sizes, e.g. 64KiB,1MiB,8MiB")
    run.add_argument("-k", type=int, default=5,
                     help="timed repeats per cell (median taken)")
    run.add_argument("--warmup", type=int, default=2)
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--markdown", action="store_true",
                     help="print the markdown report after measuring")
    run.add_argument("--verbose", action="store_true")
    run.set_defaults(fn=_cmd_run)

    show = sub.add_parser("show", help="print a table (markdown or JSON)")
    show.add_argument("table", metavar="TABLE.json")
    show.add_argument("--json", action="store_true")
    show.set_defaults(fn=_cmd_show)

    diff = sub.add_parser("diff", help="diff two tables")
    diff.add_argument("a", metavar="OLD.json")
    diff.add_argument("b", metavar="NEW.json")
    diff.add_argument("--exit-code", action="store_true",
                      help="exit 1 when the tables differ")
    diff.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
