"""AdamW with global-norm clipping, built from scratch (no optax here).

Optimizer state is sharded exactly like the parameters (which are
FSDP-sharded over the "fsdp"/"data" axis by the model specs) — i.e.
ZeRO-1-style partitioned optimizer state falls out of GSPMD for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs) -> Dict[str, Any]:
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, grads, opt_state, params, lr_scale=1.0
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim > 1 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + decay)
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
