from .adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .schedule import cosine_schedule
from .compression import compress_grads, decompress_grads, CompressionState

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
    "cosine_schedule", "compress_grads", "decompress_grads", "CompressionState",
]
