"""Gradient compression with error feedback (distributed-optimization
trick for cross-pod links).

int8 block-quantized gradients: each contiguous block of ``block`` values
is scaled by its absmax and rounded to int8.  The quantization residual
is carried in a per-leaf error-feedback buffer and added back the next
step, so the compression is unbiased over time (Seide et al. / EF-SGD
style).  Intended use: compress *cross-pod* DP all-reduce traffic — the
pod axis is the slow edge at 512+ chips.  4× reduction of the dominant
collective term on the pod axis (bf16 → int8 payload + fp32 scales/block).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "compress_grads", "decompress_grads",
           "ef_compress_tree", "init_compression_state"]

BLOCK = 256


@dataclasses.dataclass
class CompressionState:
    error: Any  # pytree of error-feedback buffers (same shapes as grads)


def init_compression_state(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _pad_to(x, mult):
    n = x.size
    pad = (-n) % mult
    return jnp.pad(x.reshape(-1), (0, pad)), n


def compress_grads(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g → (int8 codes, fp32 scales per block)."""
    flat, n = _pad_to(g.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0]


def decompress_grads(codes: jnp.ndarray, scales: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (codes.astype(jnp.float32) * scales[:, None]).reshape(-1)
    n = int(jnp.prod(jnp.asarray(shape)))
    return flat[:n].reshape(shape)


def ef_compress_tree(grads, state: CompressionState):
    """Apply error-feedback int8 compression to every gradient leaf;
    returns (quantized-and-dequantized grads, new state).  The round trip
    models what crosses the slow link; the residual stays local."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        codes, scales = compress_grads(target)
        deq = decompress_grads(codes, scales, g.shape)
        return deq.astype(g.dtype), target - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in out])
    new_e = tdef.unflatten([o[1] for o in out])
    return new_g, CompressionState(error=new_e)
