"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule"]


def cosine_schedule(step, *, warmup: int = 100, total: int = 10_000,
                    min_ratio: float = 0.1):
    """Linear warmup → cosine decay to min_ratio. Returns an lr *scale*."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
