"""JAX version-compatibility shims (ISSUE 2).

The repo targets both older (0.4.x) and current JAX:

* ``jax.sharding.AxisType`` (explicit/auto axis types) only exists in
  newer releases — on older ones every mesh axis is implicitly "auto",
  which is exactly what this codebase wants, so :func:`make_mesh` simply
  omits the argument there.
* ``PartitionSpec`` equality: older releases compare entries
  structurally, so ``P("data") != P(("data",))``; newer ones normalize.
  ``AxisRules.entry`` / ``resolve_spec`` therefore always emit the
  canonical tuple form (see repro.distributed.sharding).

Import this module instead of touching ``jax.sharding.AxisType``
directly anywhere in src/ or tests/.
"""

from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.5-era: explicit sharding axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # older jax: meshes are implicitly auto-typed
    AxisType = None

HAS_AXIS_TYPE = AxisType is not None

__all__ = ["AxisType", "HAS_AXIS_TYPE", "make_mesh", "cost_analysis"]


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with auto axis types on every jax version."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(shape), tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax version
    (older releases return a one-element list of dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
