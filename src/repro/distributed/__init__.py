from .sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    AxisRules,
    current_rules,
    resolve_spec,
    resolve_spec_tree,
    set_rules,
    shard,
    shard_if_divisible,
    spec,
    use_rules,
)

__all__ = [
    "MULTI_POD_RULES", "SINGLE_POD_RULES", "AxisRules", "current_rules",
    "resolve_spec", "resolve_spec_tree", "set_rules", "shard",
    "shard_if_divisible", "spec", "use_rules",
]
