"""Logical-axis sharding rules (GSPMD named-axis indirection).

Models annotate tensors with *logical* axis names ("batch", "heads",
"ff", "fsdp", ...); the launcher installs an :class:`AxisRules` mapping
logical names → mesh axis names for the active mesh (2-axis single-pod or
3-axis multi-pod).  This keeps every model definition mesh-agnostic: the
same code lowers on ``("data","model")`` and ``("pod","data","model")``.

Divisibility guard: a logical dim that does not divide the mapped mesh
axes is *replicated* instead (e.g. 10 attention heads on a 16-wide model
axis; 40 experts on 16) — XLA would otherwise pad, silently wasting up to
axis-size/dim of compute.  Each drop is recorded so the roofline report
can surface it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "AxisRules",
    "set_rules",
    "current_rules",
    "spec",
    "shard",
    "shard_if_divisible",
    "SINGLE_POD_RULES",
    "MULTI_POD_RULES",
]

#: default logical→mesh map for the 16×16 single-pod mesh
SINGLE_POD_RULES: Dict[str, Union[str, Tuple[str, ...], None]] = {
    "batch": ("data",),
    "fsdp": ("data",),        # parameter / optimizer-state sharding axis
    "seq": None,               # qkv seq dim (halo-free ops only)
    "res_seq": None,           # residual-stream seq dim — ("model",) = Megatron-style SP
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "dmodel": None,            # activations replicated across model between ops
    "pages": None,
    "model": ("model",),       # direct tensor-parallel axis reference
    "data": ("data",),
}

#: pjit boundary shardings must divide evenly, so non-divisible dims are
#: always replicated; memory-critical KV caches with non-divisible head
#: counts switch to sequence-sharded layouts instead (blocks.kv_specs).
UNEVEN_OK: set = set()

#: 2×16×16 multi-pod: pod is an outer DP axis; parameters/optimizer
#: state FSDP over the full DP extent ("pod","data") — ZeRO across all
#: replicas, required to fit e.g. qwen3-235B's fp32 Adam state
#: (§Perf iteration 4).
MULTI_POD_RULES: Dict[str, Union[str, Tuple[str, ...], None]] = {
    **SINGLE_POD_RULES,
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "pod": ("pod",),
}


@dataclasses.dataclass
class AxisRules:
    rules: Dict[str, Union[str, Tuple[str, ...], None]]
    mesh: Optional[Mesh] = None
    #: (logical, dim, axes) triples dropped for non-divisibility
    dropped: list = dataclasses.field(default_factory=list)

    def axes_for(self, logical: Optional[str]) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}")
        ax = self.rules[logical]
        if ax is None:
            return None
        return (ax,) if isinstance(ax, str) else tuple(ax)

    def mesh_size(self, axes: Sequence[str]) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def entry(self, logical: Optional[str], dim: Optional[int]) -> Union[None, Tuple[str, ...]]:
        """Resolve one PartitionSpec entry, with the divisibility guard:
        non-divisible dims are replicated, except ``UNEVEN_OK`` logicals
        with dim ≥ axis size, which shard unevenly (XLA pads).

        Always returns the canonical tuple form (or None): older jax
        compares PartitionSpec entries structurally, so mixing ``"data"``
        and ``("data",)`` breaks spec equality (see
        repro.distributed.compat)."""
        axes = self.axes_for(logical)
        if not axes:
            return None
        if dim is not None and self.mesh is not None:
            size = self.mesh_size(axes)
            if size > 1 and dim % size != 0:
                self.dropped.append((logical, dim, axes))
                return None
        return axes

    def spec(self, *logical: Optional[str], dims: Optional[Sequence[Optional[int]]] = None) -> P:
        dims = dims if dims is not None else [None] * len(logical)
        return P(*[self.entry(l, d) for l, d in zip(logical, dims)])


_state = threading.local()


def set_rules(rules: AxisRules) -> None:
    _state.rules = rules


def current_rules() -> AxisRules:
    r = getattr(_state, "rules", None)
    if r is None:
        r = AxisRules(dict(SINGLE_POD_RULES), mesh=None)
        _state.rules = r
    return r


@contextlib.contextmanager
def use_rules(rules: AxisRules) -> Iterator[AxisRules]:
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def spec(*logical: Optional[str], dims: Optional[Sequence[Optional[int]]] = None) -> P:
    return current_rules().spec(*logical, dims=dims)


def shard(x, *logical: Optional[str]):
    """with_sharding_constraint under the active rules; no-op without mesh."""
    rules = current_rules()
    if rules.mesh is None or rules.mesh.empty:
        return x
    s = rules.spec(*logical, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, s))


def shard_if_divisible(dim: int, logical: str) -> Union[None, str, Tuple[str, ...]]:
    return current_rules().entry(logical, dim)


def resolve_spec(p: P, rules: AxisRules, dims: Optional[Sequence[int]] = None) -> P:
    """Translate a logical PartitionSpec (entries are logical axis names)
    into a mesh PartitionSpec under ``rules``.  Entries come out in the
    same canonical tuple form as :meth:`AxisRules.entry`, so specs built
    through either path compare equal on every jax version."""
    entries = []
    for i, e in enumerate(p):
        dim = dims[i] if dims is not None and i < len(dims) else None
        if e is None:
            entries.append(None)
            continue
        names = (e,) if isinstance(e, str) else tuple(e)
        axes: list = []
        for nm in names:
            a = rules.entry(nm, dim)
            if a is not None:
                axes.extend(a)
        entries.append(tuple(axes) if axes else None)
    return P(*entries)


def resolve_spec_tree(tree, rules: AxisRules, shapes=None):
    """Map a pytree of logical PartitionSpecs (+ optional matching pytree
    of abstract values for dim-aware guards) to mesh NamedShardings."""
    is_p = lambda x: isinstance(x, P)
    if shapes is None:
        return jax.tree.map(
            lambda p: NamedSharding(rules.mesh, resolve_spec(p, rules)),
            tree, is_leaf=is_p,
        )
    return jax.tree.map(
        lambda p, s: NamedSharding(rules.mesh, resolve_spec(p, rules, s.shape)),
        tree, shapes, is_leaf=is_p,
    )
