"""Production training launcher.

On a real TPU fleet this process runs per host under the cluster
orchestrator (GKE/xmanager): `jax.distributed.initialize()` wires the
hosts, `make_production_mesh()` builds the pod mesh, and the Trainer's
checkpoint/restart + preemption handling carry fault tolerance.  On this
CPU box it runs the same code on a 1×1 mesh with reduced configs.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --steps 50 --smoke            # reduced config, local
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --multi-pod                   # full config on the pod mesh (TPU)
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import SHAPES, get_config
from repro.distributed.sharding import use_rules
from repro.launch.mesh import make_local_mesh, make_production_mesh, rules_for_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.loop import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (real fleet)")
    ap.add_argument("--ckpt-dir", default="checkpoints/launch")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        batch = args.batch or 2
        seq = args.seq or 64
    else:
        shape = SHAPES["train_4k"]
        batch = args.batch or shape.global_batch
        seq = args.seq or shape.seq_len

    n_dev = len(jax.devices())
    if n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_local_mesh(data=n_dev, model=1)
    rules = rules_for_mesh(mesh)

    with use_rules(rules), mesh:
        trainer = Trainer(
            cfg, batch_size=batch, seq_len=seq,
            tcfg=TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                               microbatches=args.microbatches),
            opt_cfg=AdamWConfig(),
        )
        trainer.install_signal_handlers()
        report = trainer.run()
    print(f"finished at step {report['final_step']} "
          f"(preempted={report['preempted']}, "
          f"stragglers={report['straggler_events']})")
    for m in report["metrics"][-5:]:
        print(m)


if __name__ == "__main__":
    main()
