"""Production mesh construction (TPU v5e pods; host-device placeholders
for the dry-run)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType

from repro.distributed.sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    AxisRules,
)

__all__ = ["make_production_mesh", "rules_for_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )


def rules_for_mesh(mesh, overrides=None) -> AxisRules:
    base = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    rules = dict(base)
    if overrides:
        rules.update(overrides)
    return AxisRules(rules, mesh=mesh)
