"""Production mesh construction (TPU v5e pods; host-device placeholders
for the dry-run)."""

from __future__ import annotations

from repro.distributed.compat import make_mesh
from repro.distributed.sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    AxisRules,
)

__all__ = ["make_production_mesh", "rules_for_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))


def rules_for_mesh(mesh, overrides=None) -> AxisRules:
    base = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    rules = dict(base)
    if overrides:
        rules.update(overrides)
    return AxisRules(rules, mesh=mesh)
