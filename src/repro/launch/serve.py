"""Serving launcher: stand up a ServeEngine for an arch and pump a
synthetic request stream through it (batched, paged KV).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--allocator", default="bitset",
                    choices=["bitset", "nextfit"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg.smoke(), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      allocator=args.allocator)
    rng = np.random.default_rng(0)
    reqs = [
        eng.submit(rng.integers(1, cfg.vocab, size=int(l)).tolist(),
                   max_new_tokens=args.max_new)
        for l in rng.integers(3, 10, size=args.requests)
    ]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    tok = sum(len(r.generated) for r in reqs)
    print(f"{len(reqs)} requests, {tok} tokens, {wall:.2f}s "
          f"({tok/max(wall,1e-9):.1f} tok/s); pool free "
          f"{eng.pool.free_pages}/{eng.pool.num_pages}")


if __name__ == "__main__":
    main()
