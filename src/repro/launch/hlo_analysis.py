"""Post-SPMD HLO analysis: collective-traffic accounting for §Roofline.

``compiled.cost_analysis()`` has no collective term, so we parse the
per-device optimized HLO text: build a symbol table of instruction →
result bytes, find every collective op, resolve its operand sizes and
replica-group size, and convert to *algorithm bytes per device*:

  all-reduce       2·B·(g-1)/g        (ring: reduce-scatter + all-gather)
  all-gather       B_out·(g-1)/g      (received shards)
  reduce-scatter   B_in·(g-1)/g
  all-to-all       B·(g-1)/g
  collective-permute  B

The module is the per-device SPMD program, so these are per-chip link
bytes — divide by link bandwidth for the collective roofline term.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict
from typing import Dict, List


__all__ = ["collective_stats", "CollectiveReport"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # transposed iota form: [a,b]<=[x,y]T(1,0) handled by first regex too
    return n_devices


@dataclasses.dataclass
class CollectiveReport:
    total_algorithm_bytes: float
    by_op: Dict[str, float]
    counts: Dict[str, int]
    result_bytes: Dict[str, float]
    schedule: List[str]  # ordered (opcode, MB, group) lines
    n_while_loops: int


def collective_stats(hlo_text: str, n_devices: int = 1) -> CollectiveReport:
    # symbol table: instruction name -> result bytes
    sym: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            sym[m.group(1)] = _result_bytes(m.group(2))

    by_op: Dict[str, float] = defaultdict(float)
    res_by_op: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = Counter()
    schedule: List[str] = []
    n_while = hlo_text.count(" while(")

    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        base = None
        for c in _COLLECTIVES:
            if opcode == c or opcode == c + "-start":
                base = c
                break
        if base is None:
            continue
        result_b = _result_bytes(type_str)
        # operand bytes via symbol table
        args = re.findall(r"%([\w\.\-]+)", line[line.index(opcode) :])
        operand_b = sum(sym.get(a, 0) for a in args)
        g = _group_size(line, n_devices)
        gf = (g - 1) / g if g > 1 else 0.0
        if base == "all-reduce":
            algo = 2.0 * operand_b * gf
        elif base == "all-gather":
            algo = result_b * gf
        elif base == "reduce-scatter":
            algo = operand_b * gf
        elif base in ("all-to-all", "ragged-all-to-all"):
            algo = operand_b * gf
        else:  # collective-permute
            algo = float(operand_b)
        by_op[base] += algo
        res_by_op[base] += result_b
        counts[base] += 1
        schedule.append(
            f"{base:<20s} {operand_b/1e6:9.2f} MB op, {result_b/1e6:9.2f} MB res, g={g}"
        )

    return CollectiveReport(
        total_algorithm_bytes=float(sum(by_op.values())),
        by_op=dict(by_op),
        counts=dict(counts),
        result_bytes=dict(res_by_op),
        schedule=schedule,
        n_while_loops=n_while,
    )
