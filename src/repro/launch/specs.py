"""Abstract input specs + shardings for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the lowered step — weak-type-correct, shardable, no device
allocation:

* train:   (params, opt_state, batch)
* prefill: (params, batch)
* decode:  (params, caches, token, pos)
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.sharding import AxisRules, resolve_spec_tree
from repro.models.model_api import (
    Model,
    batch_sharding_specs,
    batch_specs,
    build_model,
)
from repro.optim.adamw import adamw_init, opt_state_specs

__all__ = ["input_specs", "input_shardings", "abstract_params"]


def abstract_params(model: Model):
    return jax.eval_shape(model.init, jax.random.key(0))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[Any, ...]:
    model = build_model(cfg)
    params = abstract_params(model)
    batch = batch_specs(cfg, shape)
    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        return (params, opt, batch)
    if shape.kind == "prefill":
        return (params, batch)
    # decode
    caches = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, shape.seq_len)
    )
    return (params, caches, batch["token"], batch["pos"])


def input_shardings(cfg: ArchConfig, shape: ShapeSpec, rules: AxisRules):
    """NamedShardings matching input_specs' structure (dim-aware)."""
    model = build_model(cfg)
    params = abstract_params(model)
    p_sh = resolve_spec_tree(model.param_specs(), rules, params)
    b_specs = batch_specs(cfg, shape)
    b_sh = resolve_spec_tree(
        batch_sharding_specs(cfg, shape), rules, b_specs
    )
    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        o_sh = resolve_spec_tree(
            opt_state_specs(model.param_specs()), rules, opt
        )
        return (p_sh, o_sh, b_sh)
    if shape.kind == "prefill":
        return (p_sh, b_sh)
    caches = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, shape.seq_len)
    )
    c_sh = resolve_spec_tree(model.cache_specs(), rules, caches)
    return (p_sh, c_sh, b_sh["token"], b_sh["pos"])


def replicated(rules: AxisRules):
    return NamedSharding(rules.mesh, P())
