import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with zero device allocation:
  * proof of shardability: ``jax.jit(step).lower(**specs).compile()``
    on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh,
  * ``compiled.memory_analysis()``  → bytes per device (fits-HBM check),
  * ``compiled.cost_analysis()``    → per-device HLO FLOPs / bytes,
  * a collective-traffic report parsed from the post-SPMD HLO text.

Roofline probes (``--probe 1|2``) recompile the model with 1 or 2 layer
groups, fully unrolled (scan bodies are counted once by XLA cost
analysis — DESIGN.md): the roofline tool extrapolates
``cost = c1 + (G_eff - 1) · (c2 - c1)``.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep [--probes] [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, cells_for, get_config
from repro.distributed.sharding import resolve_spec, resolve_spec_tree, use_rules
from repro.launch.hlo_analysis import collective_stats
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.launch.specs import input_shardings, input_specs
from repro.models.model_api import build_model, stack_plan
from repro.train.step import build_prefill_step, build_serve_step, build_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

#: gradient-accumulation factor per arch for train cells (activation
#: memory control; probes always use 1 — same per-step cost totals).
MICROBATCHES = {"command_r_plus_104b": 16, "internvl2_26b": 8}
DEFAULT_MICROBATCHES = 8

#: per-arch sharding-rule overrides (§Perf iteration 3): Megatron-style
#: sequence parallelism on the residual stream for the largest dense
#: archs — layer-scan carries shrink by the TP width (command-r train
#: 26.5 → 11.3 GiB/dev) at the cost of per-layer seq all-gathers.
RULES_OVERRIDES = {
    "command_r_plus_104b": {"res_seq": ("model",)},
    "internvl2_26b": {"res_seq": ("model",)},
    "qwen3_moe_235b_a22b": {"res_seq": ("model",)},
}


def _probe_cfg(cfg, probe_groups: int):
    plan = stack_plan(cfg)
    k = len(plan[0][0])
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}-p{probe_groups}",
        n_layers=k * probe_groups,
        n_enc_layers=probe_groups if cfg.n_enc_layers else 0,
    )


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               probe_groups: int = 0, remat: bool = True,
               rules_overrides=None, save_hlo: bool = False) -> dict:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    probe = probe_groups > 0
    eff_groups = sum(G for _, G in stack_plan(cfg))  # extrapolation count
    if probe:
        cfg = _probe_cfg(cfg, probe_groups)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh, overrides=rules_overrides)
    n_dev = mesh.devices.size

    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev, "probe": probe_groups,
        "eff_groups": eff_groups,
    }
    t0 = time.time()
    with use_rules(rules):
        model = build_model(cfg)
        specs = input_specs(cfg, shape)
        shardings = input_shardings(cfg, shape, rules)
        rep = NamedSharding(mesh, P())

        if shape.kind == "train":
            k_micro = 1 if probe else MICROBATCHES.get(
                arch_id, DEFAULT_MICROBATCHES
            )
            # cap: per-microbatch batch must stay shardable over the
            # full DP extent (pod×data), else activations replicate
            batch_shards = rules.mesh_size(rules.axes_for("batch"))
            k_micro = max(1, min(k_micro, shape.global_batch // batch_shards))
            step = build_train_step(model, remat=remat, probe=probe,
                                    microbatches=k_micro)
            rec["microbatches"] = k_micro
            donate = (0, 1)
            metrics_sh = {"grad_norm": rep, "lr": rep, "loss": rep}
            out_sh = (shardings[0], shardings[1], metrics_sh)
        elif shape.kind == "prefill":
            step = build_prefill_step(model, shape.seq_len, probe=probe)
            donate = ()
            caches = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            c_sh = resolve_spec_tree(model.cache_specs(), rules, caches)
            logits_sh = NamedSharding(
                mesh, resolve_spec(P("batch", "vocab"), rules,
                                   (shape.global_batch, cfg.vocab))
            )
            out_sh = (logits_sh, c_sh)
        else:  # decode
            step = build_serve_step(model)
            donate = (1,)
            tok_sh = NamedSharding(
                mesh, resolve_spec(P("batch"), rules, (shape.global_batch,))
            )
            out_sh = (tok_sh, shardings[1])

        with mesh:
            jitted = jax.jit(step, in_shardings=shardings,
                             out_shardings=out_sh, donate_argnums=donate)
            lowered = jitted.lower(*specs)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(ma, k, 0) or 0)
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes")
    }
    # live bytes per device ≈ args + temps (outputs alias donated args)
    rec["memory"]["per_device_total"] = (
        rec["memory"]["argument_size_in_bytes"]
        + rec["memory"]["temp_size_in_bytes"]
        + rec["memory"]["output_size_in_bytes"]
        - rec["memory"]["alias_size_in_bytes"]
    )
    from repro.distributed.compat import cost_analysis
    ca = cost_analysis(compiled)
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    txt = compiled.as_text()
    coll = collective_stats(txt, n_devices=n_dev)
    rec["collectives"] = {
        "algorithm_bytes": coll.total_algorithm_bytes,
        "by_op": coll.by_op,
        "counts": coll.counts,
        "n_while_loops": coll.n_while_loops,
    }
    rec["collective_schedule"] = coll.schedule[:200]
    rec["dropped_shardings"] = [
        f"{l}:{d}:{a}" for (l, d, a) in rules.dropped
    ][:40]
    # analytic model flops (full model, not the probe's truncated stack)
    full_model = build_model(get_config(arch_id))
    rec["model_flops"] = full_model.model_flops(shape)
    rec["recurrent_correction_flops"] = full_model.recurrent_correction_flops(shape)
    pc = full_model.param_counts()
    rec["params_total"] = pc["total"]
    rec["params_active"] = pc["active"]
    if save_hlo:
        hlo_path = OUT_DIR / (cell_name(arch_id, shape_name, multi_pod, probe_groups) + ".hlo")
        hlo_path.write_text(txt)
    return rec


def cell_name(arch, shape, multi, probe):
    s = f"{arch}__{shape}__{'multi' if multi else 'single'}"
    if probe:
        s += f"__p{probe}"
    return s


def run_one(arch, shape, multi, probe, out_dir: Path, skip_existing=True,
            save_hlo=False, rules_overrides=None, tag="") -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    name = cell_name(arch, shape, multi, probe) + (f"__{tag}" if tag else "")
    path = out_dir / (name + ".json")
    if skip_existing and path.exists():
        rec = json.loads(path.read_text())
        if "error" not in rec:
            print(f"[skip] {name}")
            return rec
    print(f"[run ] {name} ...", flush=True)
    try:
        rec = lower_cell(arch, shape, multi, probe, save_hlo=save_hlo,
                         rules_overrides=rules_overrides)
        status = (
            f"ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
            f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB "
            f"flops/dev={rec['cost']['flops']:.3e}"
        )
    except Exception as e:  # record failure, keep sweeping
        rec = {"arch": arch, "shape": shape,
               "mesh": "multi" if multi else "single", "probe": probe,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        status = f"FAIL {type(e).__name__}: {str(e)[:200]}"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[done] {name}: {status}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--probe", type=int, default=0)
    ap.add_argument("--probes", action="store_true",
                    help="also run probe=1,2 cells (single-pod)")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--no-skip-existing", dest="skip_existing", action="store_false")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = ARCH_IDS if args.arch == "all" else [args.arch.replace("-", "_")]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        shapes = cells_for(arch) if args.shape == "all" else [args.shape]
        overrides = RULES_OVERRIDES.get(arch)
        for shape in shapes:
            for multi in meshes:
                run_one(arch, shape, multi, args.probe, out_dir,
                        args.skip_existing, args.save_hlo,
                        rules_overrides=overrides)
            if args.probes or args.sweep:
                for p in (1, 2):
                    run_one(arch, shape, False, p, out_dir,
                            args.skip_existing, args.save_hlo,
                            rules_overrides=overrides)


if __name__ == "__main__":
    main()
