"""Roofline derivation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) cell on the single-pod mesh:

  compute    = FLOPs_dev / 197 TF/s          (bf16 MXU peak, v5e)
  memory     = bytes_dev / 819 GB/s          (HBM bandwidth)
  collective = algo_bytes_dev / 50 GB/s      (ICI link)

All three per-device quantities come from the 1-vs-2-group *probe*
compiles, extrapolated ``c1 + (G_eff − 1)(c2 − c1)`` (XLA cost analysis
counts a scan body once, so the proof compile undercounts — DESIGN.md).
The sLSTM while-loop correction is added analytically.

Definitions reported per cell:
  bound          = max(compute, memory, collective)   — step-time lower bound
  bottleneck     = argmax term
  MODEL_FLOPS    = 6·N_active·tokens (train) / 2·N_active·tokens (fwd)
  useful_ratio   = MODEL_FLOPS / (FLOPs_dev · n_dev)  — remat/dispatch waste
  roofline_frac  = (MODEL_FLOPS / n_dev / peak) / bound — fraction of the
                   chip's peak the cell can reach under this compile
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # B/s / chip
LINK_BW = 50e9       # B/s / link

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _load(name: str, out_dir: Path) -> Optional[dict]:
    p = out_dir / (name + ".json")
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return None if "error" in rec else rec


def cell_roofline(arch: str, shape: str, out_dir: Path = DRYRUN_DIR,
                  tag: str = "") -> Optional[Dict]:
    sfx = f"__{tag}" if tag else ""
    full = _load(f"{arch}__{shape}__single{sfx}", out_dir)
    p1 = _load(f"{arch}__{shape}__single__p1{sfx}", out_dir)
    p2 = _load(f"{arch}__{shape}__single__p2{sfx}", out_dir)
    if not (full and p1 and p2):
        return None
    eff = full["eff_groups"]
    n_dev = full["n_devices"]

    def extrap(get):
        c1, c2 = get(p1), get(p2)
        return c1 + (eff - 1) * (c2 - c1)

    flops = extrap(lambda r: r["cost"]["flops"])
    flops += full.get("recurrent_correction_flops", 0.0) / n_dev
    mem_bytes = extrap(lambda r: r["cost"]["bytes_accessed"])
    coll_bytes = extrap(lambda r: r["collectives"]["algorithm_bytes"])

    t_c = flops / PEAK_FLOPS
    t_m = mem_bytes / HBM_BW
    t_l = coll_bytes / LINK_BW
    bound = max(t_c, t_m, t_l)
    bn = {t_c: "compute", t_m: "memory", t_l: "collective"}[bound]
    mf = full["model_flops"]
    useful = mf / max(flops * n_dev, 1e-9)
    frac = (mf / n_dev / PEAK_FLOPS) / max(bound, 1e-12)
    return {
        "arch": arch, "shape": shape, "n_devices": n_dev,
        "flops_dev": flops, "mem_bytes_dev": mem_bytes,
        "coll_bytes_dev": coll_bytes,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "bound_s": bound, "bottleneck": bn,
        "model_flops": mf, "useful_ratio": useful,
        "roofline_fraction": frac,
        "mem_per_device_GiB": full["memory"]["per_device_total"] / 2 ** 30,
        "compile_s": full["compile_s"],
        "multi_ok": _load(f"{arch}__{shape}__multi", out_dir) is not None,
    }


def full_table(out_dir: Path = DRYRUN_DIR, tag: str = "") -> List[Dict]:
    from repro.configs.base import ARCH_IDS, cells_for

    rows = []
    for arch in ARCH_IDS:
        for shape in cells_for(arch):
            r = cell_roofline(arch, shape, out_dir, tag=tag)
            if r:
                rows.append(r)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound s | "
           "bottleneck | useful | roofline-frac | GiB/dev | multi-pod |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4g} | "
            f"{r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} | "
            f"{r['bound_s']:.4g} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['mem_per_device_GiB']:.2f} | "
            f"{'yes' if r['multi_ok'] else 'NO'} |\n"
        )
    return "".join(out)


def main() -> None:
    rows = full_table()
    print(markdown_table(rows))
    out = DRYRUN_DIR.parent / "roofline.md"
    out.write_text(markdown_table(rows))
    print(f"written {out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
