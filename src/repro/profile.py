"""Profile report over exported RIMMS traces (ISSUE 8).

``python -m repro.profile TRACE.json [TRACE2.json ...]`` prints, per
trace, a markdown report:

* **top-N ops by wall time** — wall-clock compute spans (pid 1) grouped
  by op;
* **top-N ops by modeled time** — the deterministic replay's compute
  spans (pid 2), same grouping, so wall vs modeled hot spots can be
  compared side by side;
* **critical path** — extracted from the trace's flow arrows (producer
  compute → consumer compute): the longest chain of modeled compute
  spans by summed duration, printed task by task;
* **divergence table** — the embedded wall/modeled calibration table
  (``doc["rimms"]["divergence"]``, written by
  :meth:`~repro.core.trace.TraceCollector.set_divergence`) rendered as
  markdown.

CI runs this over every smoke-bench trace and posts the output to the
job summary; a missing/malformed trace exits non-zero so the gate
fails fast.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

WALL_PID = 1
MODEL_PID = 2

__all__ = ["profile_report", "main"]


def _tid_tracks(events: List[dict]) -> Dict[Tuple[int, int], str]:
    return {
        (e["pid"], e["tid"]): e.get("args", {}).get("name", "")
        for e in events
        if e.get("ph") == "M" and e.get("name") == "thread_name"
    }


def _op_of(e: dict) -> str:
    return e.get("args", {}).get("op") or e.get("name") or "?"


def _top_ops(events: List[dict], pid: int, top: int
             ) -> List[Tuple[str, float, int]]:
    """(op, total_us, count) for compute spans of ``pid``, descending."""
    totals: Dict[str, List[float]] = {}
    for e in events:
        if (e.get("ph") != "X" or e.get("pid") != pid
                or e.get("cat") != "compute"):
            continue
        acc = totals.setdefault(_op_of(e), [0.0, 0])
        acc[0] += e.get("dur", 0.0)
        acc[1] += 1
    ranked = sorted(totals.items(), key=lambda kv: (-kv[1][0], kv[0]))
    return [(op, t, int(n)) for op, (t, n) in ranked[:top]]


def _critical_path(events: List[dict]) -> Tuple[List[dict], float]:
    """Longest chain of modeled compute spans linked by flow arrows.

    Flow events come in ``ph="s"`` / ``ph="f"`` pairs sharing an ``id``;
    each endpoint lands inside the compute span it decorates, so the
    span is recovered by (tid, timestamp) containment.  Returns the
    chain (span dicts, in order) and its summed duration in us.
    """
    spans = [e for e in events
             if e.get("ph") == "X" and e.get("pid") == MODEL_PID
             and e.get("cat") == "compute"]
    by_tid: Dict[int, List[Tuple[float, float, int]]] = {}
    for i, e in enumerate(spans):
        by_tid.setdefault(e["tid"], []).append(
            (e["ts"], e["ts"] + e.get("dur", 0.0), i))
    for lst in by_tid.values():
        lst.sort()

    def locate(tid: int, ts: float) -> Optional[int]:
        for t0, t1, i in by_tid.get(tid, ()):
            if t0 <= ts <= t1:
                return i
        return None

    starts: Dict[Any, int] = {}
    ends: Dict[Any, int] = {}
    for e in events:
        if e.get("cat") != "flow" or e.get("pid") != MODEL_PID:
            continue
        idx = locate(e["tid"], e["ts"])
        if idx is None:
            continue
        if e.get("ph") == "s":
            starts[e.get("id")] = idx
        elif e.get("ph") == "f":
            ends[e.get("id")] = idx
    preds: Dict[int, List[int]] = {}
    for fid, src in starts.items():
        dst = ends.get(fid)
        if dst is not None and dst != src:
            preds.setdefault(dst, []).append(src)

    # Longest path by summed span duration; spans are finite and flows
    # point forward in modeled time, so plain memoized recursion works
    # (with a visiting guard against malformed cyclic input).
    best: Dict[int, Tuple[float, Optional[int]]] = {}
    visiting: set = set()

    def cost(i: int) -> Tuple[float, Optional[int]]:
        if i in best:
            return best[i]
        if i in visiting:
            return (0.0, None)
        visiting.add(i)
        dur = spans[i].get("dur", 0.0)
        choice: Tuple[float, Optional[int]] = (dur, None)
        for p in preds.get(i, ()):
            c = cost(p)[0] + dur
            if c > choice[0]:
                choice = (c, p)
        visiting.discard(i)
        best[i] = choice
        return choice

    if not spans:
        return [], 0.0
    tail = max(range(len(spans)), key=lambda i: cost(i)[0])
    total = cost(tail)[0]
    chain: List[dict] = []
    cur: Optional[int] = tail
    while cur is not None:
        chain.append(spans[cur])
        cur = best[cur][1]
    chain.reverse()
    return chain, total


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.3f}ms"
    return f"{us:.1f}us"


def _divergence_markdown(table: Dict[str, dict]) -> List[str]:
    lines = [
        "| kind | op | pe kind | bucket | n | wall | modeled | "
        "ema | mean | p95 |",
        "|---|---|---|---|---:|---:|---:|---:|---:|---:|",
    ]
    for key in sorted(table):
        c = table[key]
        def r(v: Any) -> str:
            return f"{v:.3f}" if isinstance(v, (int, float)) else "-"
        lines.append(
            f"| {c.get('kind', '?')} | {c.get('op', '?')} "
            f"| {c.get('pe_kind', '?')} | {c.get('bucket', '?')} "
            f"| {c.get('count', 0)} | {_fmt_us(c.get('wall_s', 0) * 1e6)} "
            f"| {_fmt_us(c.get('model_s', 0) * 1e6)} "
            f"| {r(c.get('ema_ratio'))} | {r(c.get('mean_ratio'))} "
            f"| {r(c.get('p95_ratio'))} |")
    return lines


def profile_report(doc: dict, *, top: int = 10,
                   title: str = "trace") -> str:
    """The markdown profile report for one exported trace dict."""
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("not a RIMMS trace: missing traceEvents list")
    lines: List[str] = [f"## Profile: {title}", ""]

    for label, pid in (("wall", WALL_PID), ("modeled", MODEL_PID)):
        ranked = _top_ops(events, pid, top)
        lines.append(f"### Top ops by {label} time")
        lines.append("")
        if not ranked:
            lines.append(f"_no {label} compute spans_")
        else:
            lines.append("| op | total | spans | mean |")
            lines.append("|---|---:|---:|---:|")
            for op, total, n in ranked:
                lines.append(f"| {op} | {_fmt_us(total)} | {n} "
                             f"| {_fmt_us(total / n)} |")
        lines.append("")

    chain, total = _critical_path(events)
    lines.append("### Critical path (modeled, via flow arrows)")
    lines.append("")
    if not chain:
        lines.append("_no flow arrows in trace_")
    else:
        lines.append(f"{len(chain)} tasks, {_fmt_us(total)} summed "
                     f"compute:")
        lines.append("")
        for e in chain:
            lines.append(f"1. `{e.get('name', '?')}` "
                         f"({_op_of(e)}, {_fmt_us(e.get('dur', 0.0))})")
    lines.append("")

    div = doc.get("rimms", {}).get("divergence")
    lines.append("### Wall/modeled divergence")
    lines.append("")
    if not div:
        lines.append("_no divergence table embedded in trace_")
    else:
        lines.extend(_divergence_markdown(div))
    lines.append("")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.profile",
        description="Print a markdown profile report (top ops, critical "
                    "path, divergence table) for exported RIMMS traces.")
    ap.add_argument("traces", nargs="+", metavar="TRACE.json")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per top-ops table (default 10)")
    args = ap.parse_args(argv)
    status = 0
    for path in args.traces:
        try:
            with open(path) as f:
                doc = json.load(f)
            print(profile_report(doc, top=args.top, title=path))
        except (OSError, ValueError) as e:
            print(f"error: {path}: {e}", file=sys.stderr)
            status = 1
    return status


if __name__ == "__main__":
    raise SystemExit(main())
