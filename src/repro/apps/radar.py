"""The paper's radar signal-processing applications (§4.2–4.3) on the
RIMMS runtime: 2FFT, 2FZF, 3ZIP reference chains and the real-world
RC / PD / SAR workloads.

Every app builds (buffers, tasks) against a :class:`HeteContext`; the
caller runs them under a :class:`Runtime` with either the ``reference``
(host-owned) or ``rimms`` memory policy — the paper's comparisons fall
out of the transfer ledger.

PE kernels: numpy on the CPU PE; jitted jnp on accelerator PEs (the
Pallas zip/fft kernels are the TPU-deployment versions, validated in
tests; the emulated SoC uses the XLA path for speed).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as rimms
from repro.core.api import Session
from repro.core.hete import HeteContext, HeteData
from repro.core.runtime import Runtime, Task, make_emulated_soc

__all__ = [
    "register_kernels", "build_2fft", "build_2fzf", "build_3zip",
    "build_rc", "build_pd", "build_sar", "make_runtime", "make_session",
    "run_pipeline", "submit_2fzf",
]

C64 = np.complex64


# ---------------------------------------------------------------------------
# PE kernels — registered as per-kind op variants (ISSUE 4): importing
# this module fills the default registry, so `Session.emulated()` (and
# `register_kernels` for batch runtimes) get the radar op set.
# ---------------------------------------------------------------------------


@jax.jit
def _jfft(x):
    return jnp.fft.fft(x, axis=-1)


@jax.jit
def _jifft(x):
    return jnp.fft.ifft(x, axis=-1)


@jax.jit
def _jzip(a, b):
    return a * b


# Calibration input factories (ISSUE 10): representative inputs at a
# requested total byte size, so `session.calibrate()` can measure the
# radar ops' real kernels per PE kind.
def _calib_single_c64(rng, nbytes):
    n = max(nbytes // 8, 1)
    return [(rng.standard_normal(n)
             + 1j * rng.standard_normal(n)).astype(C64)]


def _calib_pair_c64(rng, nbytes):
    n = max(nbytes // 16, 1)
    return [(rng.standard_normal(n)
             + 1j * rng.standard_normal(n)).astype(C64) for _ in range(2)]


@rimms.op("fft", kinds=("cpu",), calib=_calib_single_c64)
def _fft_cpu(ins):
    return np.fft.fft(ins[0], axis=-1).astype(C64)


@rimms.op("ifft", kinds=("cpu",), calib=_calib_single_c64)
def _ifft_cpu(ins):
    return np.fft.ifft(ins[0], axis=-1).astype(C64)


@rimms.op("zip", kinds=("cpu",), calib=_calib_pair_c64)
def _zip_cpu(ins):
    return (ins[0] * ins[1]).astype(C64)


@rimms.op("fft", kinds=("acc", "gpu"))
def _fft_device(ins):
    return _jfft(ins[0])


@rimms.op("ifft", kinds=("acc", "gpu"))
def _ifft_device(ins):
    return _jifft(ins[0])


@rimms.op("zip", kinds=("acc", "gpu"))
def _zip_device(ins):
    return _jzip(ins[0], ins[1])


def register_kernels(rt: Runtime) -> None:
    """Install the radar op registry into a batch runtime (compat shim —
    sessions install the registry themselves)."""
    rimms.default_registry.install(rt)


def make_runtime(*, policy: str, scheduler: str = "round_robin",
                 n_cpu: int = 1, accelerators: Sequence[str] = ("gpu0",),
                 allocator: str = "nextfit", tracking: str = "flag",
                 backend: Optional[str] = None):
    """Build (Runtime, HeteContext) for an emulated SoC.  ``scheduler``
    may be any of :data:`repro.core.runtime.SCHEDULERS`, including the
    transfer-aware ``"heft"`` used by the graph executor; ``backend``
    is the kernel-execution backend (thread | process | auto)."""
    pes, ctx = make_emulated_soc(
        n_cpu=n_cpu, accelerators=tuple(accelerators), allocator=allocator,
        tracking=tracking, backend=backend,
    )
    rt = Runtime(pes, ctx, policy=policy, scheduler=scheduler,
                 backend=backend)
    register_kernels(rt)
    return rt, ctx


def make_session(*, policy: str = "rimms", scheduler: str = "heft",
                 n_cpu: int = 1, accelerators: Sequence[str] = ("gpu0",),
                 **kwargs) -> Session:
    """A streaming :class:`Session` over an emulated SoC with the radar
    op registry installed — the primary entry point for radar apps
    (``session.context`` / ``session.runtime`` expose the lower
    layers)."""
    return Session.emulated(
        policy=policy, scheduler=scheduler, n_cpu=n_cpu,
        accelerators=tuple(accelerators), **kwargs,
    )


def run_pipeline(rt: Runtime, tasks, *, mode: str = "serial",
                 scheduler: Optional[str] = None) -> float:
    """Execute a built task list either serially (CEDR-style submission
    order) or on the async task-graph executor (automatic DAG, per-PE
    queues, transfer/compute overlap).  Returns wall seconds."""
    # internal calls go through the private impls: the DeprecationWarning
    # on run/run_graph is for user code migrating to Session, not for the
    # compat helpers themselves
    if mode == "serial":
        return rt._run_impl(tasks)
    if mode == "graph":
        return rt._run_graph_impl(tasks, scheduler=scheduler)
    raise ValueError(f"unknown execution mode {mode!r} (serial|graph)")


def _fill(hd: HeteData, rng: np.random.Generator) -> None:
    hd.copies[list(hd.copies)[0]][...] = (
        rng.normal(size=hd.shape) + 1j * rng.normal(size=hd.shape)
    ).astype(C64)


# ---------------------------------------------------------------------------
# reference chains (Fig 4)
# ---------------------------------------------------------------------------


def build_2fft(ctx: HeteContext, n: int, *, pins=(None, None), seed=0):
    """FFT → IFFT (Fig 4a)."""
    rng = np.random.default_rng(seed)
    x = ctx.malloc((n,), C64)
    mid = ctx.malloc((n,), C64)
    out = ctx.malloc((n,), C64)
    _fill(x, rng)
    tasks = [
        Task("fft", [x], [mid], pin=pins[0], name="fft0"),
        Task("ifft", [mid], [out], pin=pins[1], name="ifft0"),
    ]
    return {"in": x, "mid": mid, "out": out}, tasks


def build_2fzf(ctx: HeteContext, n: int, *, pins=(None,) * 4, seed=0):
    """FFT, FFT → ZIP → IFFT (Fig 4b); the two FFTs run sequentially to
    isolate memory effects (paper §5.2)."""
    rng = np.random.default_rng(seed)
    a, b = ctx.malloc((n,), C64), ctx.malloc((n,), C64)
    fa, fb = ctx.malloc((n,), C64), ctx.malloc((n,), C64)
    z, out = ctx.malloc((n,), C64), ctx.malloc((n,), C64)
    _fill(a, rng)
    _fill(b, rng)
    tasks = [
        Task("fft", [a], [fa], pin=pins[0], name="fftA"),
        Task("fft", [b], [fb], pin=pins[1], name="fftB"),
        Task("zip", [fa, fb], [z], pin=pins[2], name="zip"),
        Task("ifft", [z], [out], pin=pins[3], name="ifft"),
    ]
    return {"a": a, "b": b, "out": out}, tasks


def submit_2fzf(session: Session, n: int, *, pins=(None,) * 4, seed=0,
                tag=""):
    """The 2FZF chain (Fig 4b) through the streaming session API: four
    submissions, zero explicit sync — ``out.result()`` is the only sync
    point.  ``tag`` disambiguates task names when many clients submit
    chains against one session (bench_stream)."""
    rng = np.random.default_rng(seed)
    a, b = session.malloc((n,), C64), session.malloc((n,), C64)
    _fill(a.hete, rng)
    _fill(b.hete, rng)
    fa = session.submit("fft", [a], pin=pins[0], name=f"fftA{tag}")
    fb = session.submit("fft", [b], pin=pins[1], name=f"fftB{tag}")
    z = session.submit("zip", [fa, fb], pin=pins[2], name=f"zip{tag}")
    out = session.submit("ifft", [z], pin=pins[3], name=f"ifft{tag}")
    return {"a": a, "b": b, "fa": fa, "fb": fb, "z": z, "out": out}


def build_3zip(ctx: HeteContext, n: int, *, pins=(None,) * 3, seed=0):
    """ZIP, ZIP → ZIP (Fig 4c)."""
    rng = np.random.default_rng(seed)
    bufs = [ctx.malloc((n,), C64) for _ in range(4)]
    for hd in bufs:
        _fill(hd, rng)
    x, y, out = (ctx.malloc((n,), C64) for _ in range(3))
    tasks = [
        Task("zip", [bufs[0], bufs[1]], [x], pin=pins[0], name="zip0"),
        Task("zip", [bufs[2], bufs[3]], [y], pin=pins[1], name="zip1"),
        Task("zip", [x, y], [out], pin=pins[2], name="zip2"),
    ]
    return {"ins": bufs, "out": out}, tasks


# ---------------------------------------------------------------------------
# real-world applications (§4.3): RC, PD, SAR
# ---------------------------------------------------------------------------


def build_rc(ctx: HeteContext, *, seed=0):
    """Radar Correlator: 2FZF data flow at 256 samples (paper §5.4)."""
    return build_2fzf(ctx, 256, seed=seed)


def _parallel_fzf(ctx, ways: int, n: int, *, use_fragment: bool, seed=0):
    """``ways`` parallel (FFT, FFT→ZIP→IFFT) instances of size n —
    the PD/SAR phase structure.  With ``use_fragment`` every data point
    is ONE hete_malloc fragmented ``ways`` times (§3.2.3); otherwise
    ``ways`` separate allocations per data point."""
    rng = np.random.default_rng(seed)

    def alloc_point():
        if use_fragment:
            parent = ctx.malloc((ways * n,), C64)
            parent.fragment(n)
            return parent, [parent[i] for i in range(ways)]
        parents = [ctx.malloc((n,), C64) for _ in range(ways)]
        return None, parents

    points = {name: alloc_point() for name in
              ("a", "b", "fa", "fb", "z", "out")}
    for name in ("a", "b"):
        for frag in points[name][1]:
            _fill(frag, rng)
    tasks = []
    for i in range(ways):
        a, b = points["a"][1][i], points["b"][1][i]
        fa, fb = points["fa"][1][i], points["fb"][1][i]
        z, out = points["z"][1][i], points["out"][1][i]
        tasks += [
            Task("fft", [a], [fa], name=f"fftA{i}"),
            Task("fft", [b], [fb], name=f"fftB{i}"),
            Task("zip", [fa, fb], [z], name=f"zip{i}"),
            Task("ifft", [z], [out], name=f"ifft{i}"),
        ]
    return points, tasks


def build_pd(ctx: HeteContext, *, ways: int = 128, n: int = 128,
             use_fragment: bool = True, seed=0):
    """Pulse Doppler: 128 parallel 2FZF instances at 128 samples
    (paper §5.4 / Fig 9)."""
    return _parallel_fzf(ctx, ways, n, use_fragment=use_fragment, seed=seed)


def build_sar(ctx: HeteContext, *, use_fragment: bool = True, seed=0,
              scale: int = 1):
    """SAR: phase 1 = 512-way FZF at 256 samples; phase 2 = 256-way FZF
    at 512 samples.  ``scale`` divides the way-counts for quick runs."""
    p1, t1 = _parallel_fzf(ctx, 512 // scale, 256,
                           use_fragment=use_fragment, seed=seed)
    p2, t2 = _parallel_fzf(ctx, 256 // scale, 512,
                           use_fragment=use_fragment, seed=seed + 1)
    return {"phase1": p1, "phase2": p2}, t1 + t2
