"""Numpy-only elementwise kernels — backend-parity fixtures (ISSUE 7).

Pure-numpy ``@rimms.op`` kernels registered for every PE kind, with no
jax anywhere in their import chain: a process PE worker shipping these
by reference spawns in "import numpy" time, which keeps the
thread-vs-process parity tests fast.  They are also bit-deterministic by
construction (same numpy call, same bytes) on any backend.

Module-level functions only — the process backend ships kernels by
pickle reference, so closures/lambdas would not survive the trip.
"""

from __future__ import annotations

import numpy as np

from repro.core.api import op

KINDS = ("cpu", "acc", "gpu")


@op("scale", kinds=KINDS)
def scale(ins, *, factor: float = 2.0):
    return np.asarray(ins[0]) * factor


@op("axpy", kinds=KINDS)
def axpy(ins, *, alpha: float = 1.0):
    return alpha * np.asarray(ins[0]) + np.asarray(ins[1])


@op("square", kinds=KINDS)
def square(ins):
    return np.square(np.asarray(ins[0]))


@op("csum", kinds=KINDS)
def csum(ins):
    return np.cumsum(np.asarray(ins[0]), dtype=np.float64)


@op("snooze", kinds=KINDS)
def snooze(ins, *, seconds: float = 0.05):
    """Sleep then pass through — wall-clock overlap fixtures."""
    import time

    time.sleep(seconds)
    return np.asarray(ins[0])


@op("boom", kinds=KINDS)
def boom(ins):
    """Deterministic failure — exception-propagation fixtures."""
    raise ValueError("boom kernel always fails")


@op("die", kinds=KINDS)
def die(ins):
    """Kill the executing process — worker-death fixtures.  On the
    thread backend this would kill the whole interpreter, so tests only
    run it under the process backend."""
    import os

    os._exit(17)
