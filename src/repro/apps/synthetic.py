"""Synthetic DAG workloads for the graph executor benchmarks/tests.

The radar chains (:mod:`repro.apps.radar`) are mostly linear per way;
these builders produce *fork-join* structures whose width is what the
async executor exploits: a shared source feeds ``ways`` independent
branches, whose results reduce pairwise back to one output.  All tasks
use the standard radar op set (``fft``/``ifft``/``zip``) so every
registered runtime kernel applies unchanged.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.apps.radar import _fill
from repro.core.hete import HeteContext, HeteData
from repro.core.runtime import Task

__all__ = ["build_fork_join", "build_diamonds", "submit_fork_join"]

C64 = np.complex64


def build_fork_join(
    ctx: HeteContext,
    *,
    ways: int = 4,
    n: int = 4096,
    depth: int = 2,
    seed: int = 0,
) -> Tuple[Dict[str, HeteData], List[Task]]:
    """Fork-join DAG: source FFT → ``ways`` parallel branches (each a
    ``depth``-long fft/zip chain) → pairwise zip reduction to one output.

    ``ways`` must be a power of two (for the clean reduction tree).
    Serial makespan grows with ``ways × depth``; critical path only with
    ``depth + log2(ways)`` — the gap is the executor's opportunity.
    """
    if ways < 1 or ways & (ways - 1):
        raise ValueError(f"ways must be a power of two, got {ways}")
    rng = np.random.default_rng(seed)
    src = ctx.malloc((n,), C64)
    _fill(src, rng)
    fsrc = ctx.malloc((n,), C64)
    tasks = [Task("fft", [src], [fsrc], name="src_fft")]

    branch_outs: List[HeteData] = []
    for w in range(ways):
        weight = ctx.malloc((n,), C64)
        _fill(weight, rng)
        cur = ctx.malloc((n,), C64)
        tasks.append(Task("zip", [fsrc, weight], [cur], name=f"fork{w}_zip"))
        for d in range(depth):
            nxt = ctx.malloc((n,), C64)
            op = "fft" if d % 2 == 0 else "ifft"
            tasks.append(Task(op, [cur], [nxt], name=f"branch{w}_{op}{d}"))
            cur = nxt
        branch_outs.append(cur)

    level = 0
    while len(branch_outs) > 1:
        nxt_outs: List[HeteData] = []
        for j in range(0, len(branch_outs), 2):
            merged = ctx.malloc((n,), C64)
            tasks.append(Task(
                "zip", [branch_outs[j], branch_outs[j + 1]], [merged],
                name=f"join{level}_{j // 2}",
            ))
            nxt_outs.append(merged)
        branch_outs = nxt_outs
        level += 1

    return {"src": src, "out": branch_outs[0]}, tasks


def submit_fork_join(
    session,
    *,
    ways: int = 4,
    n: int = 4096,
    depth: int = 2,
    seed: int = 0,
) -> Dict[str, "BufferFuture"]:
    """:func:`build_fork_join` through the streaming session API
    (ISSUE 4): identical DAG structure, buffer sizes, fill seeds and
    submission order, so a single-threaded session with static
    ``round_robin`` placement is bit-identical — outputs *and* per-pair
    copy counts — to batch ``run_graph``/serial ``run`` on the same
    build.  Returns ``{"src", "out"}`` futures; ``out.result()`` is the
    only sync point."""
    if ways < 1 or ways & (ways - 1):
        raise ValueError(f"ways must be a power of two, got {ways}")
    rng = np.random.default_rng(seed)
    src = session.malloc((n,), C64)
    _fill(src.hete, rng)
    fsrc = session.submit("fft", [src], name="src_fft")

    branch_outs = []
    for w in range(ways):
        weight = session.malloc((n,), C64)
        _fill(weight.hete, rng)
        cur = session.submit("zip", [fsrc, weight], name=f"fork{w}_zip")
        for d in range(depth):
            op = "fft" if d % 2 == 0 else "ifft"
            cur = session.submit(op, [cur], name=f"branch{w}_{op}{d}")
        branch_outs.append(cur)

    level = 0
    while len(branch_outs) > 1:
        nxt_outs = []
        for j in range(0, len(branch_outs), 2):
            nxt_outs.append(session.submit(
                "zip", [branch_outs[j], branch_outs[j + 1]],
                name=f"join{level}_{j // 2}",
            ))
        branch_outs = nxt_outs
        level += 1

    return {"src": src, "out": branch_outs[0]}


def build_diamonds(
    ctx: HeteContext,
    *,
    count: int = 8,
    n: int = 2048,
    seed: int = 0,
) -> Tuple[Dict[str, HeteData], List[Task]]:
    """``count`` independent diamond DAGs (fft → two zips → zip join) —
    maximal inter-diamond parallelism, for scheduler stress tests."""
    rng = np.random.default_rng(seed)
    outs: List[HeteData] = []
    tasks: List[Task] = []
    for c in range(count):
        a = ctx.malloc((n,), C64)
        _fill(a, rng)
        fa = ctx.malloc((n,), C64)
        left, right, out = (ctx.malloc((n,), C64) for _ in range(3))
        tasks += [
            Task("fft", [a], [fa], name=f"d{c}_top"),
            Task("zip", [fa, a], [left], name=f"d{c}_left"),
            Task("zip", [fa, fa], [right], name=f"d{c}_right"),
            Task("zip", [left, right], [out], name=f"d{c}_join"),
        ]
        outs.append(out)
    return {"outs": outs}, tasks
