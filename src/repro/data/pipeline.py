"""Deterministic, resumable synthetic data pipeline (host-side producer).

In RIMMS terms the pipeline is the CPU PE producing batches into host
memory; the training loop tracks each batch as a ``HeteData`` so device
ingestion happens exactly once and repeated consumers (eval replays,
repeated Computation regions à la the paper's PD app) hit the tracked
device copy instead of re-staging from host.

Determinism + resume: batch ``i`` is a pure function of (seed, i) — the
checkpoint stores only ``next_index``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    cfg: ArchConfig
    batch_size: int
    seq_len: int
    seed: int = 0
    next_index: int = 0

    def state(self) -> Dict:
        return {"seed": self.seed, "next_index": self.next_index}

    def restore(self, state: Dict) -> None:
        self.seed = int(state["seed"])
        self.next_index = int(state["next_index"])

    def _rng(self, index: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, index])
        )

    def batch_at(self, index: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, index) — the resume contract."""
        cfg = self.cfg
        rng = self._rng(index)
        B, S = self.batch_size, self.seq_len
        if cfg.family == "vlm":
            s_txt = S - cfg.n_patches
            tokens = rng.integers(0, cfg.vocab, (B, s_txt + 1), dtype=np.int32)
            out = {
                "tokens": tokens[:, :-1],
                "labels": tokens[:, 1:],
                "patch_embeds": rng.normal(
                    size=(B, cfg.n_patches, cfg.d_model)
                ).astype(np.float32),
            }
        elif cfg.family == "audio":
            tokens = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int32)
            out = {
                "tokens": tokens[:, :-1],
                "labels": tokens[:, 1:],
                "frames": rng.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(
                    np.float32
                ),
            }
        else:
            tokens = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int32)
            out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        return out

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.next_index)
        self.next_index += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self
