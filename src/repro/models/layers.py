"""Model primitives: norms, RoPE, GQA attention (chunked), MLPs, embeddings.

Conventions
-----------
* Params are plain dicts of ``jnp`` arrays, stored in ``param_dtype``
  (fp32) and cast to the compute dtype (bf16) at use.
* Softmax / norm statistics are computed in fp32.
* Full-sequence attention is *row-chunked* over queries (``q_chunk``):
  per chunk the full key range (or the local window slice) is scored and
  softmaxed — memory O(chunk × S) instead of O(S²).  The chunk loop is a
  ``lax.scan`` with an ``unroll_all`` escape hatch used by the roofline
  probes (DESIGN.md: scan bodies are counted once by XLA cost analysis,
  so probes compile fully unrolled).
* GQA: KV heads are repeated by the smallest factor making them
  shardable over the tensor-model axis (DESIGN.md §5); when no factor
  works (e.g. 40-head MHA on a 16-wide axis) K/V switch to a
  sequence-sharded layout over the model axis (pjit boundary shardings
  must divide evenly, so padding is not an option for cache args).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_rules, shard

# ---------------------------------------------------------------------------
# small utils
# ---------------------------------------------------------------------------


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / math.sqrt(fan_in)
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


def norm_apply(cfg, params, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def norm_init(cfg, key):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), pdtype(cfg))}
    return {
        "scale": jnp.ones((cfg.d_model,), pdtype(cfg)),
        "bias": jnp.zeros((cfg.d_model,), pdtype(cfg)),
    }


def norm_spec(cfg):
    if cfg.norm == "rmsnorm":
        return {"scale": P()}
    return {"scale": P(), "bias": P()}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, pos, theta: float):
    """x: (..., S, H, D); pos: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def kv_repeat_factor(cfg) -> int:
    """Smallest r with (kv·r) % tp == 0 and heads % (kv·r) == 0, else 1."""
    rules = current_rules()
    axes = rules.axes_for("heads")
    tp = rules.mesh_size(axes) if axes else 1
    kv, h = cfg.n_kv_heads, cfg.n_heads
    if tp <= 1 or kv % tp == 0:
        return 1
    r = 1
    while kv * r < max(tp, h) + 1:
        if (kv * r) % tp == 0 and h % (kv * r) == 0:
            return r
        r += 1
    return 1  # fall back to uneven sharding / replication


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_q: int       # query heads
    n_kv: int      # stored KV heads (after repeat)
    group: int     # queries per stored KV head
    head_dim: int


def attn_dims(cfg) -> AttnDims:
    rep = kv_repeat_factor(cfg)
    n_kv = cfg.n_kv_heads * rep
    return AttnDims(cfg.n_heads, n_kv, cfg.n_heads // n_kv, cfg.head_dim_)


def kv_heads_shardable(cfg) -> bool:
    """True if the (repeated) KV head count divides the TP axis."""
    rules = current_rules()
    axes = rules.axes_for("kv_heads")
    tp = rules.mesh_size(axes) if axes else 1
    return tp <= 1 or attn_dims(cfg).n_kv % tp == 0


def divisor_chunk(s: int, target: int) -> int:
    """Largest chunk ≤ target that divides s (handles e.g. 3840 labels)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def attention_init(cfg, key):
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), pdtype(cfg))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), pdtype(cfg))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), pdtype(cfg))
    return p


def attention_spec(cfg):
    s = {
        "wq": P("fsdp", "model"),
        "wk": P("fsdp", "model"),
        "wv": P("fsdp", "model"),
        "wo": P("model", "fsdp"),
    }
    if cfg.qkv_bias:
        s.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
    return s


def _project_qkv(cfg, params, x, pos, rope: bool = True):
    """x: (B,S,D) → q (B,S,Hq,hd), k/v (B,S,Hkv_eff,hd) with repeat."""
    dims = attn_dims(cfg)
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    B, S = x.shape[:2]
    q = q.reshape(B, S, dims.n_q, dims.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, dims.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, dims.head_dim)
    if rope and cfg.pos_embed == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    rep = dims.n_kv // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = shard(q, "batch", "seq", "heads", None)
    if kv_heads_shardable(cfg):
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
    else:  # MHA-ish archs on a wider TP axis: sequence-sharded KV
        k = shard(k, "batch", "model", None, None)
        v = shard(v, "batch", "model", None, None)
    return q, k, v


def _chunk_attend(q_c, k, v, q_pos, k_pos, window: int):
    """One query chunk against a key range. Shapes:
    q_c (B,C,Hkv,G,hd); k,v (B,T,Hkv,hd); q_pos (C,), k_pos (T,).
    Causal + optional window mask. fp32 softmax."""
    scale = 1.0 / math.sqrt(q_c.shape[-1])
    scores = jnp.einsum(
        "bckgd,btkd->bkgct", q_c, k, preferred_element_type=jnp.float32
    ) * scale
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgct,btkd->bckgd", probs.astype(q_c.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q_c.dtype)


def full_attention(cfg, q, k, v, *, pos0: int = 0, probe: bool = False):
    """Causal (optionally windowed) attention over a full sequence, row-
    chunked over queries. q: (B,S,Hq,hd) → (B,S,Hq*hd)."""
    dims = attn_dims(cfg)
    B, S = q.shape[:2]
    C = divisor_chunk(S, cfg.q_chunk)
    n_chunks = S // C
    qg = q.reshape(B, S, dims.n_kv, dims.group, dims.head_dim)

    win = cfg.window
    if win > 0 and win % C == 0 and S > win:
        # local attention: slice only the needed key range per chunk
        def chunk(i):
            q_c = jax.lax.dynamic_slice_in_dim(qg, i * C, C, axis=1)
            k0 = jnp.maximum(i * C - win, 0)
            span = win + C
            k_c = jax.lax.dynamic_slice_in_dim(k, k0, span, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, k0, span, axis=1)
            q_pos = pos0 + i * C + jnp.arange(C)
            k_pos = pos0 + k0 + jnp.arange(span)
            return _chunk_attend(q_c, k_c, v_c, q_pos, k_pos, win)
    else:
        def chunk(i):
            q_c = jax.lax.dynamic_slice_in_dim(qg, i * C, C, axis=1)
            q_pos = pos0 + i * C + jnp.arange(C)
            k_pos = pos0 + jnp.arange(S)
            return _chunk_attend(q_c, k, v, q_pos, k_pos, win)

    if probe or n_chunks == 1:
        out = jnp.concatenate([chunk(i) for i in range(n_chunks)], axis=1)
    else:
        # Nested remat: recompute each chunk's probs in the backward pass
        # so only one chunk's (C×S) scores are ever live (flash-attention
        # memory behaviour on the XLA path).
        outs = jax.lax.map(jax.checkpoint(chunk), jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(
            B, S, dims.n_kv, dims.group, dims.head_dim
        )
    return out.reshape(B, S, dims.n_q * dims.head_dim)


def decode_attention(cfg, q, k_cache, v_cache, kv_len, *, apply_window=True):
    """Single-token attention. q: (B,1,Hq,hd); caches (B,Smax,Hkv,hd);
    kv_len: (B,) valid lengths (new token already written).
    ``apply_window=False`` for ring-buffer caches whose slots are already
    window-resident."""
    dims = attn_dims(cfg)
    B = q.shape[0]
    Smax = k_cache.shape[1]
    qg = q.reshape(B, 1, dims.n_kv, dims.group, dims.head_dim)
    scale = 1.0 / math.sqrt(dims.head_dim)
    scores = jnp.einsum(
        "bckgd,btkd->bkgct", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # (B,Hkv,G,1,Smax)
    t = jnp.arange(Smax)
    mask = t[None, :] < kv_len[:, None]  # (B,Smax)
    if cfg.window > 0 and apply_window:
        mask &= t[None, :] >= kv_len[:, None] - cfg.window
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgct,btkd->bckgd", probs.astype(q.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
    return out.reshape(B, 1, dims.n_q * dims.head_dim)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_in": dense_init(ks[0], (d, f)),
            "w_gate": dense_init(ks[1], (d, f)),
            "w_out": dense_init(ks[2], (f, d)),
        }
    return {
        "w_in": dense_init(ks[0], (d, f)),
        "b_in": jnp.zeros((f,), pdtype(cfg)),
        "w_out": dense_init(ks[2], (f, d)),
        "b_out": jnp.zeros((d,), pdtype(cfg)),
    }


def mlp_spec(cfg):
    if cfg.act in ("swiglu", "geglu"):
        return {"w_in": P("fsdp", "model"), "w_gate": P("fsdp", "model"),
                "w_out": P("model", "fsdp")}
    return {"w_in": P("fsdp", "model"), "b_in": P("model"),
            "w_out": P("model", "fsdp"), "b_out": P()}


def mlp_apply(cfg, params, x):
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(x @ params["w_gate"].astype(dt)) * (x @ params["w_in"].astype(dt))
        h = shard(h, "batch", "seq", "ff")
        return h @ params["w_out"].astype(dt)
    h = jax.nn.gelu(x @ params["w_in"].astype(dt) + params["b_in"].astype(dt))
    h = shard(h, "batch", "seq", "ff")
    return h @ params["w_out"].astype(dt) + params["b_out"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def embed_init(cfg, key):
    ks = jax.random.split(key, 3)
    p = {"table": dense_init(ks[0], (cfg.vocab, cfg.d_model)) * 0.02 * math.sqrt(cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab))
    if cfg.pos_embed == "learned":
        # sized generously so assigned decode shapes (32k) fit
        p["pos"] = dense_init(ks[2], (65536, cfg.d_model)) * 0.02
    return p


def embed_spec(cfg):
    s = {"table": P("model", "fsdp")}
    if not cfg.tie_embeddings:
        s["head"] = P("fsdp", "model")
    if cfg.pos_embed == "learned":
        s["pos"] = P(None, "fsdp")
    return s


def embed_tokens(cfg, params, tokens, pos=None):
    x = jnp.take(params["table"], tokens, axis=0).astype(cdtype(cfg))
    if cfg.pos_embed == "learned" and pos is not None:
        x = x + jnp.take(params["pos"], pos, axis=0).astype(cdtype(cfg))
    return shard(x, "batch", "res_seq", "dmodel")


def lm_logits(cfg, params, x):
    if cfg.tie_embeddings:
        w = params["table"].astype(x.dtype).T
    else:
        w = params["head"].astype(x.dtype)
    logits = x @ w
    return shard(logits, "batch", "seq", "vocab")


def xent_loss(cfg, params, hidden, labels, *, probe: bool = False,
              chunk: int = 512):
    """Sequence-chunked softmax cross-entropy (keeps (B,C,V) logits
    bounded). hidden: (B,S,D); labels: (B,S) with -100 = ignore."""
    B, S, _ = hidden.shape
    C = divisor_chunk(S, chunk)
    n = S // C

    def piece(h_c, y_c):
        logits = lm_logits(cfg, params, h_c).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1
        )[..., 0]
        valid = (y_c >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    if probe or n == 1:
        parts = [piece(hidden[:, i * C:(i + 1) * C], labels[:, i * C:(i + 1) * C])
                 for i in range(n)]
        tot = sum(p[0] for p in parts)
        cnt = sum(p[1] for p in parts)
    else:
        hs = hidden.reshape(B, n, C, -1).swapaxes(0, 1)
        ys = labels.reshape(B, n, C).swapaxes(0, 1)
        piece_ckpt = jax.checkpoint(piece)  # don't keep logits for bwd

        def body(acc, xs):
            h_c, y_c = xs
            l, c = piece_ckpt(h_c, y_c)
            return (acc[0] + l, acc[1] + c), None

        (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ys))
    return tot / jnp.maximum(cnt, 1.0)
