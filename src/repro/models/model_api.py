"""Model assembly: block stacks → Model (init / loss / prefill / decode).

A model is a sequence of *stacks*; each stack repeats a *group pattern*
of blocks (e.g. ``("rec","rec","attn") × 8``) with parameters stacked on
a leading group axis and applied with ``lax.scan`` (or a python loop in
``probe`` mode — roofline probes need fully-unrolled HLO, DESIGN.md).

Families → stack plans:
  dense / vlm      [("dense",) × L]
  moe              [("moe",) × L]
  audio (whisper)  encoder [("enc",) × L_enc] + decoder [("cross",) × L]
  ssm (xlstm)      [("mlstm","slstm") × L/2]
  hybrid (rg)      [("rec","rec","attn") × 8, ("rec","rec") × 1]
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.sharding import shard
from . import layers as L
from .blocks import CrossLayer, DenseLayer, EncoderLayer, MoELayer
from .recurrent import MLSTMLayer, RGLRULayer, SLSTMLayer

BLOCKS = {
    "dense": DenseLayer,
    "moe": MoELayer,
    "enc": EncoderLayer,
    "cross": CrossLayer,
    "mlstm": MLSTMLayer,
    "slstm": SLSTMLayer,
    "rec": RGLRULayer,
    "attn": DenseLayer,
}


def stack_plan(cfg: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    if cfg.family in ("dense", "vlm"):
        pattern: Tuple[str, ...] = ("dense",)
    elif cfg.family == "moe":
        pattern = ("moe",)
    elif cfg.family == "audio":
        pattern = ("cross",)
    elif cfg.family in ("ssm", "hybrid"):
        pattern = cfg.block_pattern
    else:
        raise ValueError(f"unknown family {cfg.family}")
    k = len(pattern)
    full, rest = divmod(cfg.n_layers, k)
    plan = [(pattern, full)]
    if rest:
        plan.append((pattern[:rest], 1))
    return plan


# ---------------------------------------------------------------------------
# stack init / spec / apply
# ---------------------------------------------------------------------------


def _group_init(cfg, pattern, key):
    ks = jax.random.split(key, len(pattern))
    return {f"b{i}": BLOCKS[p].init(cfg, ks[i]) for i, p in enumerate(pattern)}


def _group_spec(cfg, pattern):
    return {f"b{i}": BLOCKS[p].spec(cfg) for i, p in enumerate(pattern)}


def _group_cache(cfg, pattern, batch, max_len):
    return {f"b{i}": BLOCKS[p].init_cache(cfg, batch, max_len)
            for i, p in enumerate(pattern)}


def _group_cache_spec(cfg, pattern):
    return {f"b{i}": BLOCKS[p].cache_spec(cfg) for i, p in enumerate(pattern)}


def _group_apply(cfg, pattern, params, x, *, mode, cache, pos, probe, extras):
    new_cache = {}
    for i, p in enumerate(pattern):
        c = cache.get(f"b{i}") if cache is not None else None
        x, nc = BLOCKS[p].apply(
            cfg, params[f"b{i}"], x,
            mode=mode, cache=c, pos=pos, probe=probe, extras=extras,
        )
        new_cache[f"b{i}"] = nc
    return x, (new_cache if (cache is not None or mode == "prefill") else None)


def _stack_apply(cfg, pattern, n_groups, params, x, *, mode, cache, pos,
                 probe, extras, remat):
    """params/cache leaves carry a leading (n_groups,) axis.

    Memory paths (§Perf iteration 1, EXPERIMENTS.md):
    * train   — scan over groups, remat'd body, no cache.
    * prefill — scan with cache as *output only* (ys): blocks construct
      their caches from scratch, so no zero-filled input cache is ever
      threaded through the loop (halves prefill cache traffic).
    * decode  — ``fori_loop`` with the stacked cache as loop *carry*,
      updated in place via dynamic_update_index: XLA aliases the donated
      cache buffer instead of double-buffering scan xs/ys (3× HBM-
      traffic / temp-memory reduction on 32k-KV decode cells).
    """
    gapply = functools.partial(
        _group_apply, cfg, pattern,
        mode=mode, pos=pos, probe=probe, extras=extras,
    )
    if probe or n_groups == 1:
        caches = []
        for g in range(n_groups):
            p_g = jax.tree.map(lambda a: a[g], params)
            c_g = jax.tree.map(lambda a: a[g], cache) if cache is not None else None
            x, nc = gapply(p_g, x, cache=c_g)
            caches.append(nc)
        new_cache = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
            if caches[0] is not None else None
        )
        return x, new_cache

    if mode == "train":
        def body(h, p_g):
            h2, _ = gapply(p_g, h, cache=None)
            return h2, None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params)
        return x, None

    if mode == "prefill":
        def body(h, p_g):
            h2, nc = gapply(p_g, h, cache=None)
            return h2, nc
        x, new_cache = jax.lax.scan(body, x, params)
        return x, new_cache

    # decode: in-place carry update
    def body(g, carry):
        h, full_cache = carry
        p_g = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, g, 0, keepdims=False),
            params,
        )
        c_g = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, g, 0, keepdims=False),
            full_cache,
        )
        h2, nc = gapply(p_g, h, cache=c_g)
        full_cache = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), g, 0
            ),
            full_cache, nc,
        )
        return (h2, full_cache)

    x, new_cache = jax.lax.fori_loop(0, n_groups, body, (x, cache))
    return x, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- init / specs ------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {"embed": L.embed_init(cfg, keys[0])}
        params["final_norm"] = L.norm_init(cfg, keys[1])
        stacks = []
        for si, (pattern, G) in enumerate(stack_plan(cfg)):
            gks = jax.random.split(keys[2 + si], G)
            stacks.append(jax.vmap(lambda k: _group_init(cfg, pattern, k))(gks))
        params["stacks"] = stacks
        if cfg.family == "audio":
            egks = jax.random.split(keys[6], cfg.n_enc_layers)
            params["enc_stack"] = jax.vmap(
                lambda k: _group_init(cfg, ("enc",), k)
            )(egks)
            params["enc_norm"] = L.norm_init(cfg, keys[7])
            params["enc_pos"] = L.dense_init(keys[5], (cfg.enc_seq, cfg.d_model)) * 0.02
        return params

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg

        def stacked(tree):
            return jax.tree.map(
                lambda s: P(*((None,) + tuple(s))), tree,
                is_leaf=lambda s: isinstance(s, P),
            )

        specs: Dict[str, Any] = {
            "embed": L.embed_spec(cfg),
            "final_norm": L.norm_spec(cfg),
            "stacks": [
                stacked(_group_spec(cfg, pattern))
                for pattern, _ in stack_plan(cfg)
            ],
        }
        if cfg.family == "audio":
            specs["enc_stack"] = stacked(_group_spec(cfg, ("enc",)))
            specs["enc_norm"] = L.norm_spec(cfg)
            specs["enc_pos"] = P(None, "fsdp")
        return specs

    # ---- caches ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        caches = []
        for pattern, G in stack_plan(cfg):
            one = _group_cache(cfg, pattern, batch, max_len)
            caches.append(
                jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), one)
            )
        return caches

    def cache_specs(self):
        cfg = self.cfg
        out = []
        for pattern, _ in stack_plan(cfg):
            tree = _group_cache_spec(cfg, pattern)
            out.append(jax.tree.map(
                lambda s: P(*((None,) + tuple(s))), tree,
                is_leaf=lambda s: isinstance(s, P),
            ))
        return out

    # ---- forward helpers ---------------------------------------------------
    def _embed_train(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        pos = jnp.arange(tokens.shape[1])[None, :]
        x = L.embed_tokens(cfg, params["embed"], tokens,
                           pos if cfg.pos_embed == "learned" else None)
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            x = shard(x, "batch", "res_seq", "dmodel")
        return x

    def _encode(self, params, frames, probe=False):
        cfg = self.cfg
        x = frames.astype(L.cdtype(cfg))
        x = x + params["enc_pos"].astype(x.dtype)[None]
        x = shard(x, "batch", "res_seq", "dmodel")
        x, _ = _stack_apply(
            cfg, ("enc",), cfg.n_enc_layers, params["enc_stack"], x,
            mode="train", cache=None, pos=None, probe=probe, extras=None,
            remat=True,
        )
        return L.norm_apply(cfg, params["enc_norm"], x)

    def _backbone(self, params, x, *, mode, caches, pos, probe, extras, remat):
        cfg = self.cfg
        new_caches = []
        for (pattern, G), sp, sc in zip(
            stack_plan(cfg), params["stacks"],
            caches if caches is not None else [None] * 8,
        ):
            x, nc = _stack_apply(
                cfg, pattern, G, sp, x, mode=mode, cache=sc, pos=pos,
                probe=probe, extras=extras, remat=remat,
            )
            new_caches.append(nc)
        x = L.norm_apply(cfg, params["final_norm"], x)
        has_caches = any(c is not None for c in new_caches)
        return x, (new_caches if has_caches else None)

    # ---- public API ------------------------------------------------------------
    def loss(self, params, batch, *, probe: bool = False, remat: bool = True):
        cfg = self.cfg
        x = self._embed_train(params, batch)
        extras = None
        if cfg.family == "audio":
            extras = {"enc": self._encode(params, batch["frames"], probe=probe)}
        x, _ = self._backbone(params, x, mode="train", caches=None, pos=None,
                              probe=probe, extras=extras, remat=remat)
        labels = batch["labels"]
        if cfg.family == "vlm":  # loss only over text positions
            x = x[:, -labels.shape[1]:]
        return L.xent_loss(cfg, params["embed"], x, labels, probe=probe)

    def prefill(self, params, batch, max_len: int, *, probe: bool = False):
        """Run the full prompt, returning (last-token logits, caches).

        Caches are *constructed* by the blocks (scan outputs), never
        threaded in as zero-filled inputs — §Perf iteration 1."""
        cfg = self.cfg
        x = self._embed_train(params, batch)
        extras = {"max_len": max_len}
        if cfg.family == "audio":
            extras["enc"] = self._encode(params, batch["frames"], probe=probe)
        x, caches = self._backbone(params, x, mode="prefill", caches=None,
                                   pos=None, probe=probe, extras=extras,
                                   remat=False)
        logits = L.lm_logits(cfg, params["embed"], x[:, -1:])
        return logits[:, 0], caches

    def decode_step(self, params, caches, token, pos):
        """token: (B,) int32; pos: (B,) int32 positions being generated."""
        cfg = self.cfg
        x = L.embed_tokens(
            cfg, params["embed"], token[:, None],
            pos[:, None] if cfg.pos_embed == "learned" else None,
        )
        x, caches = self._backbone(params, x, mode="decode", caches=caches,
                                   pos=pos, probe=False, extras=None,
                                   remat=False)
        logits = L.lm_logits(cfg, params["embed"], x)
        return logits[:, 0], caches

    # ---- accounting -----------------------------------------------------------
    def param_counts(self) -> Dict[str, float]:
        """total / active / embedding parameter counts (analytic, from
        abstract init shapes)."""
        shapes = jax.eval_shape(self.init, jax.random.key(0))
        total = active = embed = 0.0
        k_over_e = (
            self.cfg.top_k / self.cfg.n_experts if self.cfg.is_moe else 1.0
        )
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            n = float(np.prod(leaf.shape))
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            stacked = any(k == "stacks" for k in keys)
            n_eff = n
            is_embed = any(k in ("table", "head", "pos", "enc_pos") for k in keys)
            is_expert = any(k in ("w_in", "w_gate", "w_out") for k in keys) and any(
                k == "moe" for k in keys
            )
            total += n
            if is_embed:
                embed += n
                continue
            active += n_eff * (k_over_e if is_expert else 1.0)
        return {"total": total, "active": active, "embed": embed}

    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS per step: 6·N_active·tokens (train) or
        2·N_active·tokens (decode/prefill fwd-only), N excl. embeddings
        but incl. the LM head matmul."""
        counts = self.param_counts()
        n = counts["active"]
        head = 0.0 if self.cfg.family == "audio" else self.cfg.d_model * self.cfg.vocab
        n = n + head
        if shape.kind == "train":
            tokens = shape.seq_len * shape.global_batch
            return 6.0 * n * tokens
        if shape.kind == "prefill":
            tokens = shape.seq_len * shape.global_batch
            return 2.0 * n * tokens
        return 2.0 * n * shape.global_batch  # decode: one token / seq

    def recurrent_correction_flops(self, shape: ShapeSpec) -> float:
        """Analytic FLOPs hidden inside sequential while-loops (sLSTM),
        added to probe-derived HLO FLOPs (DESIGN.md)."""
        cfg = self.cfg
        if cfg.family != "ssm" or shape.kind == "decode":
            return 0.0
        n_slstm = sum(
            pattern.count("slstm") * G for pattern, G in stack_plan(cfg)
        )
        f = SLSTMLayer.recurrent_flops(cfg, shape.global_batch, shape.seq_len)
        mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd≈2x +remat fwd
        return n_slstm * f * mult


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# batch shape specs (abstract inputs for smoke tests and the dry-run)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for an (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            s_txt = S - cfg.n_patches
            d = {
                "tokens": jax.ShapeDtypeStruct((B, s_txt), i32),
                "labels": jax.ShapeDtypeStruct((B, s_txt), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
            }
        elif cfg.family == "audio":
            d = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
                "frames": jax.ShapeDtypeStruct(
                    (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
            }
        else:
            d = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if shape.kind == "prefill":
            d.pop("labels")
        return d
    # decode: one token; the KV/state cache is a separate argument
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
    }


def batch_sharding_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, P]:
    if shape.kind in ("train", "prefill"):
        out = {"tokens": P("batch", None)}
        if shape.kind == "train":
            out["labels"] = P("batch", None)
        if cfg.family == "vlm":
            out["patch_embeds"] = P("batch", None, None)
        if cfg.family == "audio":
            out["frames"] = P("batch", None, None)
        return out
    return {"token": P("batch"), "pos": P("batch")}
