from .model_api import Model, batch_sharding_specs, batch_specs, build_model, stack_plan

__all__ = ["Model", "batch_sharding_specs", "batch_specs", "build_model", "stack_plan"]
