"""Recurrent sequence mixers: mLSTM + sLSTM (xLSTM) and RG-LRU (Griffin /
RecurrentGemma).

TPU adaptation notes (recorded in DESIGN.md):

* **mLSTM** uses the chunkwise-parallel form: quadratic attention-like
  compute *within* a chunk (``cfg.rec_chunk`` tokens) and a first-order
  linear recurrence over chunk summaries evaluated with
  ``jax.lax.associative_scan`` — log-depth, no ``while`` loop, so XLA
  cost analysis counts it fully (important for §Roofline).
* **Gating**: we use sigmoid input gates instead of the paper's
  exponential gating + max-stabilizer.  Same compute/memory structure,
  unconditionally stable; a numerics ablation, not a systems change.
* **sLSTM** has a true nonlinear recurrence (h_{t-1} feeds the gates) —
  not chunkable.  It runs as a ``lax.scan`` over time; its FLOPs are
  added analytically in the roofline (see launch/roofline.py) because a
  while-loop body is counted once by cost analysis.
* **RG-LRU** is a diagonal linear recurrence → ``associative_scan``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard
from . import layers as L

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _causal_conv(x, kernel, buf=None):
    """Depthwise causal conv. x: (B,S,D); kernel: (W,D); buf: (B,W-1,D)
    carry-in for decode/prefill continuity (None → zero history).
    Returns (y, new_buf)."""
    B, S, D = x.shape
    W = kernel.shape[0]
    hist = jnp.zeros((B, W - 1, D), x.dtype) if buf is None else buf.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)  # (B, S+W-1, D)
    y = sum(
        xp[:, i : i + S] * kernel[i].astype(x.dtype)[None, None, :]
        for i in range(W)
    )
    new_buf = xp[:, -(W - 1):]
    return y, new_buf


def _linear_scan(a, b, probe: bool = False):
    """First-order linear recurrence h_j = a_j * h_{j-1} + b_j along axis 0
    via associative_scan (a broadcasts over b's trailing dims)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    return jax.lax.associative_scan(combine, (a, b), axis=0)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------


class MLSTMLayer:
    """Pre-norm mLSTM block: up-proj (pf=2) → conv → q,k,v + scalar head
    gates → chunkwise matrix-memory recurrence → gated output → down-proj.
    Carries its own expansion (cfg.d_ff == 0 for xlstm)."""

    @staticmethod
    def _dims(cfg):
        M = 2 * cfg.d_model
        return M, cfg.n_heads, M // cfg.n_heads

    @staticmethod
    def init(cfg, key):
        D = cfg.d_model
        M, H, m = MLSTMLayer._dims(cfg)
        ks = jax.random.split(key, 8)
        return {
            "norm": L.norm_init(cfg, ks[0]),
            "w_up": L.dense_init(ks[1], (D, 2 * M)),
            "conv": L.dense_init(ks[2], (cfg.conv_width, M)),
            "wq": L.dense_init(ks[3], (M, M)),
            "wk": L.dense_init(ks[4], (M, M)),
            "wv": L.dense_init(ks[5], (M, M)),
            "w_gates": L.dense_init(ks[6], (M, 2 * H)),
            "w_down": L.dense_init(ks[7], (M, D)),
            "out_scale": jnp.ones((M,), L.pdtype(cfg)),
        }

    @staticmethod
    def spec(cfg):
        return {
            "norm": L.norm_spec(cfg),
            "w_up": P("fsdp", "ff"),
            "conv": P(None, "ff"),
            "wq": P("fsdp", "ff"),
            "wk": P("fsdp", "ff"),
            "wv": P("fsdp", "ff"),
            "w_gates": P("fsdp", None),
            "w_down": P("ff", "fsdp"),
            "out_scale": P("ff"),
        }

    @staticmethod
    def init_cache(cfg, batch, max_len):
        M, H, m = MLSTMLayer._dims(cfg)
        return {
            "C": jnp.zeros((batch, H, m, m), jnp.float32),
            "n": jnp.zeros((batch, H, m), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, M), L.cdtype(cfg)),
        }

    @staticmethod
    def cache_spec(cfg):
        return {
            "C": P("batch", None, None, "ff"),
            "n": P("batch", None, None),
            "conv": P("batch", None, "ff"),
        }

    @staticmethod
    def _qkv_gates(cfg, params, xm, conv_buf):
        M, H, m = MLSTMLayer._dims(cfg)
        dt = xm.dtype
        u, new_buf = _causal_conv(xm, params["conv"], conv_buf)
        u = jax.nn.silu(u)
        B, S = xm.shape[:2]
        q = (u @ params["wq"].astype(dt)).reshape(B, S, H, m)
        k = (u @ params["wk"].astype(dt)).reshape(B, S, H, m)
        v = (xm @ params["wv"].astype(dt)).reshape(B, S, H, m)
        gates = (xm @ params["w_gates"].astype(dt)).astype(jnp.float32)
        gates = gates.reshape(B, S, H, 2)
        i = jax.nn.sigmoid(gates[..., 0])
        lf = jax.nn.log_sigmoid(gates[..., 1])
        q = q / math.sqrt(m)
        return q, k, v, i, lf, new_buf

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        D = cfg.d_model
        M, H, m = MLSTMLayer._dims(cfg)
        dt = x.dtype
        h_in = L.norm_apply(cfg, params["norm"], x)
        up = h_in @ params["w_up"].astype(dt)
        xm, z = up[..., :M], up[..., M:]
        xm = shard(xm, "batch", "seq", "ff")

        if mode == "decode":
            q, k, v, i, lf, new_buf = MLSTMLayer._qkv_gates(
                cfg, params, xm, cache["conv"]
            )
            q1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
            i1, f1 = i[:, 0], jnp.exp(lf[:, 0])  # (B,H)
            C = cache["C"] * f1[..., None, None] + (
                i1[..., None, None] * k1[..., :, None] * v1[..., None, :]
            )
            nv = cache["n"] * f1[..., None] + i1[..., None] * k1
            num = jnp.einsum("zha,zhae->zhe", q1, C)
            den = jnp.maximum(jnp.abs(jnp.einsum("zha,zha->zh", q1, nv)), 1.0)
            h = (num / den[..., None]).reshape(x.shape[0], 1, M).astype(dt)
            new_cache = {"C": C, "n": nv, "conv": new_buf}
        else:
            q, k, v, i, lf, new_buf = MLSTMLayer._qkv_gates(cfg, params, xm, None)
            B, S = x.shape[:2]
            c = L.divisor_chunk(S, cfg.rec_chunk)
            n = S // c

            def cs(t, fdt=jnp.float32):  # (B,S,H,...) -> (n,B,c,H,...)
                return (
                    t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1).astype(fdt)
                )

            qc, kc, vc, ic, lfc = cs(q), cs(k), cs(v), cs(i), cs(lf)
            cum = jnp.cumsum(lfc, axis=2)  # (n,B,c,H) inclusive
            a_chunk = jnp.exp(cum[:, :, -1])  # (n,B,H)
            # chunk summaries: ΔC = Σ_s exp(cum_end - cum_s) i_s k_s v_sᵀ
            w_s = jnp.exp(cum[:, :, -1:, :] - cum) * ic  # (n,B,c,H)
            dC = jnp.einsum("nzch,nzcha,nzche->nzhae", w_s, kc, vc)
            dn = jnp.einsum("nzch,nzcha->nzha", w_s, kc)
            # inter-chunk states via associative scan, shifted to "before"
            A, Cs = _linear_scan(a_chunk[..., None, None], dC)
            _, ns = _linear_scan(a_chunk[..., None], dn)
            zerosC = jnp.zeros_like(Cs[:1])
            C_in = jnp.concatenate([zerosC, Cs[:-1]], axis=0)
            n_in = jnp.concatenate([jnp.zeros_like(ns[:1]), ns[:-1]], axis=0)
            # intra-chunk attention-like term
            scores = jnp.einsum("nztha,nzsha->nzhts", qc, kc)
            dlt = cum[..., :, None, :] - cum[..., None, :, :]  # (n,B,t,s,H)
            mask = jnp.tril(jnp.ones((c, c), bool))
            w_ts = jnp.where(
                mask[None, None, :, :, None], jnp.exp(dlt), 0.0
            ) * ic[..., None, :, :]
            A_ts = scores * jnp.moveaxis(w_ts, -1, 2)  # (n,B,H,t,s)
            num = jnp.einsum("nzhts,nzsha->nztha", A_ts, vc)
            num = num + jnp.exp(cum)[..., None] * jnp.einsum(
                "nztha,nzhae->nzthe", qc, C_in
            )
            den = jnp.sum(A_ts, axis=-1).swapaxes(2, 3)  # (n,B,t,H)
            den = den + jnp.exp(cum) * jnp.einsum("nztha,nzha->nzth", qc, n_in)
            den = jnp.maximum(jnp.abs(den), 1.0)
            h = (num / den[..., None]).swapaxes(0, 1).reshape(B, S, M).astype(dt)
            new_cache = None
            if mode == "prefill":
                new_cache = {"C": Cs[-1], "n": ns[-1], "conv": new_buf}

        h = L.rms_norm(h, params["out_scale"])
        h = h * jax.nn.silu(z)
        out = h @ params["w_down"].astype(dt)
        return shard(x + out, "batch", "res_seq", "dmodel"), new_cache if mode != "train" else None


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block)
# ---------------------------------------------------------------------------


class SLSTMLayer:
    """Pre-norm sLSTM with per-head block-diagonal recurrence + gated FFN
    (pf=4/3).  The time recurrence is inherently sequential (lax.scan)."""

    @staticmethod
    def _dims(cfg):
        D = cfg.d_model
        H = cfg.n_heads
        f = int(round(D * 4 / 3 / 32)) * 32
        return D, H, D // H, f

    @staticmethod
    def init(cfg, key):
        D, H, hd, f = SLSTMLayer._dims(cfg)
        ks = jax.random.split(key, 6)
        return {
            "norm": L.norm_init(cfg, ks[0]),
            "w_gates": L.dense_init(ks[1], (D, 4 * D)),
            "r_gates": L.dense_init(ks[2], (4, H, hd, hd), in_axis=2),
            "b_gates": jnp.zeros((4 * D,), L.pdtype(cfg)),
            "w_up": L.dense_init(ks[3], (D, 2 * f)),
            "w_down": L.dense_init(ks[4], (f, D)),
            "out_scale": jnp.ones((D,), L.pdtype(cfg)),
        }

    @staticmethod
    def spec(cfg):
        return {
            "norm": L.norm_spec(cfg),
            "w_gates": P("fsdp", None),
            "r_gates": P(None, "heads", None, None),
            "b_gates": P(None),
            "w_up": P("fsdp", "ff"),
            "w_down": P("ff", "fsdp"),
            "out_scale": P(None),
        }

    @staticmethod
    def init_cache(cfg, batch, max_len):
        D = cfg.d_model
        z = jnp.zeros((batch, D), jnp.float32)
        return {"c": z, "h": z, "n": z}

    @staticmethod
    def cache_spec(cfg):
        s = P("batch", None)
        return {"c": s, "h": s, "n": s}

    @staticmethod
    def _step(cfg, params, pre_t, state):
        """pre_t: (B,4D) fp32 input preactivations; state: dict of (B,D)."""
        D, H, hd, _ = SLSTMLayer._dims(cfg)
        B = pre_t.shape[0]
        h_prev = state["h"].reshape(B, H, hd)
        rec = jnp.einsum(
            "bhd,ghde->gbhe", h_prev, params["r_gates"].astype(jnp.float32)
        ).reshape(4, B, D)
        g = pre_t.reshape(B, 4, D).swapaxes(0, 1) + rec + params["b_gates"].astype(
            jnp.float32
        ).reshape(4, 1, D)
        z = jnp.tanh(g[0])
        i = jax.nn.sigmoid(g[1])
        f = jax.nn.sigmoid(g[2])
        o = jax.nn.sigmoid(g[3])
        c = f * state["c"] + i * z
        n = f * state["n"] + i
        h = o * c / jnp.maximum(n, 1e-6)
        return {"c": c, "h": h, "n": n}

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        D, H, hd, f = SLSTMLayer._dims(cfg)
        dt = x.dtype
        B = x.shape[0]
        hin = L.norm_apply(cfg, params["norm"], x)
        pre = (hin @ params["w_gates"].astype(dt)).astype(jnp.float32)

        if mode == "decode":
            state = SLSTMLayer._step(cfg, params, pre[:, 0], cache)
            h_seq = state["h"][:, None].astype(dt)
            new_cache = state
        else:
            state0 = SLSTMLayer.init_cache(cfg, B, 0)

            def body(st, pre_t):
                st = SLSTMLayer._step(cfg, params, pre_t, st)
                return st, st["h"]

            state, hs = jax.lax.scan(body, state0, pre.swapaxes(0, 1))
            h_seq = hs.swapaxes(0, 1).astype(dt)  # (B,S,D)
            new_cache = state if mode == "prefill" else None

        h_seq = L.rms_norm(h_seq, params["out_scale"])
        up = h_seq @ params["w_up"].astype(dt)
        gate, val = up[..., :f], up[..., f:]
        out = (jax.nn.gelu(gate) * val) @ params["w_down"].astype(dt)
        return shard(x + out, "batch", "res_seq", "dmodel"), new_cache

    @staticmethod
    def recurrent_flops(cfg, batch: int, seq: int) -> float:
        """Analytic FLOPs of the sequential recurrence (counted once by
        XLA inside the while loop) — added as a roofline correction."""
        D, H, hd, _ = SLSTMLayer._dims(cfg)
        per_step = 4 * H * hd * hd * 2 * batch  # block-diag recurrent matvec
        elementwise = 12 * D * batch
        return seq * (per_step + elementwise)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------


class RGLRULayer:
    """Pre-norm Griffin recurrent block (conv + RG-LRU, gated) + GeGLU MLP."""

    C_FACTOR = 8.0

    @staticmethod
    def init(cfg, key):
        D = cfg.d_model
        ks = jax.random.split(key, 8)
        return {
            "norm1": L.norm_init(cfg, ks[0]),
            "w_x": L.dense_init(ks[1], (D, D)),
            "w_g": L.dense_init(ks[2], (D, D)),
            "conv": L.dense_init(ks[3], (cfg.conv_width, D)),
            "w_r": L.dense_init(ks[4], (D, D)),
            "w_i": L.dense_init(ks[5], (D, D)),
            "lam": jnp.full((D,), 2.0, L.pdtype(cfg)),  # softplus ≈ 2.1
            "w_o": L.dense_init(ks[6], (D, D)),
            "norm2": L.norm_init(cfg, ks[7]),
            "mlp": L.mlp_init(cfg, jax.random.fold_in(key, 99)),
        }

    @staticmethod
    def spec(cfg):
        return {
            "norm1": L.norm_spec(cfg),
            "w_x": P("fsdp", "ff"),
            "w_g": P("fsdp", "ff"),
            "conv": P(None, "ff"),
            "w_r": P("fsdp", "ff"),
            "w_i": P("fsdp", "ff"),
            "lam": P("ff"),
            "w_o": P("ff", "fsdp"),
            "norm2": L.norm_spec(cfg),
            "mlp": L.mlp_spec(cfg),
        }

    @staticmethod
    def init_cache(cfg, batch, max_len):
        D = cfg.d_model
        return {
            "h": jnp.zeros((batch, D), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, D), L.cdtype(cfg)),
        }

    @staticmethod
    def cache_spec(cfg):
        return {"h": P("batch", "ff"), "conv": P("batch", None, "ff")}

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        D = cfg.d_model
        dt = x.dtype
        hin = L.norm_apply(cfg, params["norm1"], x)
        xb = hin @ params["w_x"].astype(dt)
        gate = jax.nn.gelu(hin @ params["w_g"].astype(dt))
        conv_buf = cache["conv"] if (cache is not None and mode == "decode") else None
        u, new_buf = _causal_conv(xb, params["conv"], conv_buf)
        u = shard(u, "batch", "seq", "ff")
        r = jax.nn.sigmoid((u @ params["w_r"].astype(dt)).astype(jnp.float32))
        i = jax.nn.sigmoid((u @ params["w_i"].astype(dt)).astype(jnp.float32))
        log_a = -RGLRULayer.C_FACTOR * jax.nn.softplus(
            params["lam"].astype(jnp.float32)
        ) * r  # (B,S,D)
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
            i * u.astype(jnp.float32)
        )
        if mode == "decode":
            h_new = a[:, 0] * cache["h"] + b[:, 0]  # (B,D)
            hs = h_new[:, None]
            new_cache = {"h": h_new, "conv": new_buf}
        else:
            a_t = a.swapaxes(0, 1)  # (S,B,D)
            b_t = b.swapaxes(0, 1)
            _, hs_t = _linear_scan(a_t, b_t)
            hs = hs_t.swapaxes(0, 1)  # (B,S,D)
            new_cache = (
                {"h": hs[:, -1], "conv": new_buf} if mode == "prefill" else None
            )
        mix = (hs.astype(dt) * gate) @ params["w_o"].astype(dt)
        x = shard(x + mix, "batch", "res_seq", "dmodel")
        h2 = L.norm_apply(cfg, params["norm2"], x)
        x = x + L.mlp_apply(cfg, params["mlp"], h2)
        return shard(x, "batch", "res_seq", "dmodel"), new_cache
