"""Transformer layer blocks: dense GQA layers, cross-attention (enc-dec),
bidirectional encoder layers, and the sort-based MoE FFN.

Block protocol (shared with :mod:`repro.models.recurrent`): a block is a
namespace of pure functions

  init(cfg, key) -> params            one layer's params
  spec(cfg) -> pytree of PartitionSpec
  init_cache(cfg, batch, max_len) -> cache pytree (decode state) or {}
  apply(cfg, params, x, *, mode, cache, pos, probe, extras)
      -> (x, new_cache)

``mode`` ∈ {"train", "prefill", "decode"}; ``pos`` is (B,) — the index
at which the current token(s) start (prefill: all sequences start at 0
here; decode: the position being generated).  ``probe=True`` unrolls all
internal scans for roofline probes (DESIGN.md).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard, current_rules
from . import layers as L

# ---------------------------------------------------------------------------
# KV cache helpers
# ---------------------------------------------------------------------------


def kv_cache_init(cfg, batch: int, max_len: int):
    dims = L.attn_dims(cfg)
    length = min(max_len, cfg.window) if cfg.window > 0 else max_len
    shape = (batch, length, dims.n_kv, dims.head_dim)
    return {
        "k": jnp.zeros(shape, L.cdtype(cfg)),
        "v": jnp.zeros(shape, L.cdtype(cfg)),
    }


def kv_cache_spec(cfg):
    """Head-sharded when possible; otherwise sequence-sharded over the
    model axis (pjit boundary shardings must divide evenly — MHA archs
    like qwen1.5 (40 heads) / whisper (20) can't head-shard on 16)."""
    if L.kv_heads_shardable(cfg):
        s = P("batch", None, "kv_heads", None)
    else:
        s = P("batch", "model", None, None)
    return {"k": s, "v": s}


def _ring_fill(x, w):
    """Fill a ring buffer of length w from a full sequence (B,S,...):
    slot s gets the *last* position p < S with p % w == s."""
    S = x.shape[1]
    slot = jnp.arange(w)
    p = slot + w * ((S - 1 - slot) // w)
    p = jnp.clip(p, 0, S - 1)
    return jnp.take(x, p, axis=1)


def build_prefill_cache(cfg, k, v, max_len):
    """Construct a fresh KV cache from full-sequence K/V (prefill builds
    caches as outputs — no zero input cache is threaded through the
    layer loop; §Perf iteration 1)."""
    dt = L.cdtype(cfg)
    S = k.shape[1]
    if cfg.window > 0:
        w = min(cfg.window, max_len)
        return {"k": _ring_fill(k, w).astype(dt),
                "v": _ring_fill(v, w).astype(dt)}
    if S == max_len:
        return {"k": k.astype(dt), "v": v.astype(dt)}
    B = k.shape[0]
    shape = (B, max_len) + k.shape[2:]
    return {"k": jnp.zeros(shape, dt).at[:, :S].set(k.astype(dt)),
            "v": jnp.zeros(shape, dt).at[:, :S].set(v.astype(dt))}


def _cache_write_token(cfg, cache, k_new, v_new, pos):
    """Write one token at pos (B,) — rolling ring buffer if windowed."""
    slot = pos % cache["k"].shape[1] if cfg.window > 0 else pos
    b = jnp.arange(k_new.shape[0])
    k = cache["k"].at[b, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[b, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    return {"k": k, "v": v}


def _decode_self_attention(cfg, q, cache, pos):
    """Self-attention against the cache.  Windowed archs use a ring
    buffer: every stored slot is inside the window by construction (slots
    are overwritten in position order), so we mask only by validity and
    skip the positional window mask (keys carry their RoPE phase from
    write time; attention is permutation-invariant over keys)."""
    kv_len = pos + 1  # tokens written so far
    if cfg.window > 0:
        win = cache["k"].shape[1]
        valid = jnp.minimum(kv_len, win)
        return L.decode_attention(cfg, q, cache["k"], cache["v"], valid,
                                  apply_window=False)
    return L.decode_attention(cfg, q, cache["k"], cache["v"], kv_len)


# ---------------------------------------------------------------------------
# Dense decoder layer (attn + MLP) — llama/yi/command-r/qwen + VLM backbone
# ---------------------------------------------------------------------------


class DenseLayer:
    @staticmethod
    def init(cfg, key):
        ks = jax.random.split(key, 4)
        return {
            "norm1": L.norm_init(cfg, ks[0]),
            "attn": L.attention_init(cfg, ks[1]),
            "norm2": L.norm_init(cfg, ks[2]),
            "mlp": L.mlp_init(cfg, ks[3]),
        }

    @staticmethod
    def spec(cfg):
        return {
            "norm1": L.norm_spec(cfg),
            "attn": L.attention_spec(cfg),
            "norm2": L.norm_spec(cfg),
            "mlp": L.mlp_spec(cfg),
        }

    @staticmethod
    def init_cache(cfg, batch, max_len):
        return kv_cache_init(cfg, batch, max_len)

    @staticmethod
    def cache_spec(cfg):
        return kv_cache_spec(cfg)

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        h = L.norm_apply(cfg, params["norm1"], x)
        if mode == "decode":
            q, k, v = L._project_qkv(cfg, params["attn"], h, pos[:, None])
            cache = _cache_write_token(cfg, cache, k, v, pos)
            attn = _decode_self_attention(cfg, q, cache, pos)
        else:
            B, S = x.shape[:2]
            positions = jnp.arange(S)[None, :]
            q, k, v = L._project_qkv(cfg, params["attn"], h, positions)
            if mode == "prefill":
                cache = build_prefill_cache(cfg, k, v, extras["max_len"])
            attn = L.full_attention(cfg, q, k, v, probe=probe)
        x = x + attn @ params["attn"]["wo"].astype(x.dtype)
        x = shard(x, "batch", "res_seq", "dmodel")
        h = L.norm_apply(cfg, params["norm2"], x)
        x = x + L.mlp_apply(cfg, params["mlp"], h)
        return shard(x, "batch", "res_seq", "dmodel"), cache


# ---------------------------------------------------------------------------
# Bidirectional encoder layer (whisper encoder)
# ---------------------------------------------------------------------------


class EncoderLayer:
    init = DenseLayer.init
    spec = DenseLayer.spec

    @staticmethod
    def init_cache(cfg, batch, max_len):
        return {}

    @staticmethod
    def cache_spec(cfg):
        return {}

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        h = L.norm_apply(cfg, params["norm1"], x)
        B, S = x.shape[:2]
        positions = jnp.arange(S)[None, :]
        q, k, v = L._project_qkv(cfg, params["attn"], h, positions, rope=False)
        # bidirectional: single-shot softmax per q chunk with full mask
        dims = L.attn_dims(cfg)
        qg = q.reshape(B, S, dims.n_kv, dims.group, dims.head_dim)
        scale = 1.0 / math.sqrt(dims.head_dim)
        scores = jnp.einsum("bckgd,btkd->bkgct", qg, k,
                            preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bkgct,btkd->bckgd", probs.astype(x.dtype), v,
                          preferred_element_type=jnp.float32).astype(x.dtype)
        attn = attn.reshape(B, S, dims.n_q * dims.head_dim)
        x = x + attn @ params["attn"]["wo"].astype(x.dtype)
        h = L.norm_apply(cfg, params["norm2"], x)
        x = x + L.mlp_apply(cfg, params["mlp"], h)
        return shard(x, "batch", "res_seq", "dmodel"), cache


# ---------------------------------------------------------------------------
# Cross-attention decoder layer (whisper decoder)
# ---------------------------------------------------------------------------


class CrossLayer:
    @staticmethod
    def init(cfg, key):
        ks = jax.random.split(key, 6)
        return {
            "norm1": L.norm_init(cfg, ks[0]),
            "attn": L.attention_init(cfg, ks[1]),
            "norm_x": L.norm_init(cfg, ks[2]),
            "xattn": L.attention_init(cfg, ks[3]),
            "norm2": L.norm_init(cfg, ks[4]),
            "mlp": L.mlp_init(cfg, ks[5]),
        }

    @staticmethod
    def spec(cfg):
        return {
            "norm1": L.norm_spec(cfg),
            "attn": L.attention_spec(cfg),
            "norm_x": L.norm_spec(cfg),
            "xattn": L.attention_spec(cfg),
            "norm2": L.norm_spec(cfg),
            "mlp": L.mlp_spec(cfg),
        }

    @staticmethod
    def init_cache(cfg, batch, max_len):
        c = kv_cache_init(cfg, batch, max_len)
        dims = L.attn_dims(cfg)
        xshape = (batch, cfg.enc_seq, dims.n_kv, dims.head_dim)
        c["xk"] = jnp.zeros(xshape, L.cdtype(cfg))
        c["xv"] = jnp.zeros(xshape, L.cdtype(cfg))
        return c

    @staticmethod
    def cache_spec(cfg):
        s = kv_cache_spec(cfg)
        s["xk"] = P("batch", None, "kv_heads", None)
        s["xv"] = P("batch", None, "kv_heads", None)
        return s

    @staticmethod
    def _cross_kv(cfg, params, enc):
        dims = L.attn_dims(cfg)
        dt = enc.dtype
        B, T = enc.shape[:2]
        k = (enc @ params["wk"].astype(dt)).reshape(B, T, cfg.n_kv_heads, dims.head_dim)
        v = (enc @ params["wv"].astype(dt)).reshape(B, T, cfg.n_kv_heads, dims.head_dim)
        rep = dims.n_kv // cfg.n_kv_heads
        if rep > 1:
            k, v = jnp.repeat(k, rep, axis=2), jnp.repeat(v, rep, axis=2)
        return k, v

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        B = x.shape[0]
        # -- causal self attention ---------------------------------------
        h = L.norm_apply(cfg, params["norm1"], x)
        if mode == "decode":
            q, k, v = L._project_qkv(cfg, params["attn"], h, pos[:, None], rope=False)
            cache = dict(cache)
            sc = _cache_write_token(cfg, {"k": cache["k"], "v": cache["v"]}, k, v, pos)
            cache.update(sc)
            attn = L.decode_attention(cfg, q, cache["k"], cache["v"], pos + 1)
        else:
            S = x.shape[1]
            positions = jnp.arange(S)[None, :]
            q, k, v = L._project_qkv(cfg, params["attn"], h, positions, rope=False)
            if mode == "prefill":
                cache = build_prefill_cache(cfg, k, v, extras["max_len"])
            attn = L.full_attention(cfg, q, k, v, probe=probe)
        x = x + attn @ params["attn"]["wo"].astype(x.dtype)
        # -- cross attention ------------------------------------------------
        h = L.norm_apply(cfg, params["norm_x"], x)
        dims = L.attn_dims(cfg)
        S = x.shape[1]
        dt = x.dtype
        q = (h @ params["xattn"]["wq"].astype(dt)).reshape(B, S, dims.n_q, dims.head_dim)
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
        else:
            enc = extras["enc"]
            xk, xv = CrossLayer._cross_kv(cfg, params["xattn"], enc)
            if mode == "prefill":
                cache = dict(cache) if cache else {}
                cache["xk"] = xk.astype(L.cdtype(cfg))
                cache["xv"] = xv.astype(L.cdtype(cfg))
        qg = q.reshape(B, S, dims.n_kv, dims.group, dims.head_dim)
        scale = 1.0 / math.sqrt(dims.head_dim)
        scores = jnp.einsum("bckgd,btkd->bkgct", qg, xk,
                            preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(scores, axis=-1)
        xa = jnp.einsum("bkgct,btkd->bckgd", probs.astype(dt), xv,
                        preferred_element_type=jnp.float32).astype(dt)
        xa = xa.reshape(B, S, dims.n_q * dims.head_dim)
        x = x + xa @ params["xattn"]["wo"].astype(dt)
        # -- MLP ----------------------------------------------------------------
        h = L.norm_apply(cfg, params["norm2"], x)
        x = x + L.mlp_apply(cfg, params["mlp"], h)
        return shard(x, "batch", "res_seq", "dmodel"), cache


# ---------------------------------------------------------------------------
# MoE FFN (sort-based token dispatch, capacity drop) + MoE layer
# ---------------------------------------------------------------------------


def moe_init(cfg, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (d, e)),
        "w_in": L.dense_init(ks[1], (e, d, f), in_axis=1),
        "w_gate": L.dense_init(ks[2], (e, d, f), in_axis=1),
        "w_out": L.dense_init(ks[3], (e, f, d), in_axis=1),
    }


def moe_spec(cfg):
    return {
        "router": P(None, None),
        "w_in": P("experts", "fsdp", None),
        "w_gate": P("experts", "fsdp", None),
        "w_out": P("experts", None, "fsdp"),
    }


def _moe_groups(n_tokens: int) -> int:
    """Dispatch group count = number of batch shards, so every sort /
    scatter stays shard-local (§Perf iteration 2: GSPMD partitions the
    ungrouped global sort/scatter by replicating the token stream, which
    was the dominant collective + memory blowup in the baseline)."""
    rules = current_rules()
    axes = rules.axes_for("batch")
    g = rules.mesh_size(axes) if axes else 1
    while g > 1 and n_tokens % g:
        g //= 2
    return max(g, 1)


def moe_apply(cfg, params, x):
    """Sort-based MoE dispatch, grouped per batch shard: local top-k →
    local argsort → local scatter into (G, E, C, D) capacity buffers →
    expert-sharded grouped matmul → local combine.  The only cross-shard
    traffic is the expert-parallel boundary on the buffers, which XLA
    lowers to all-to-all / all-reduce on the model axis.  Dropped tokens
    (over capacity) contribute nothing."""
    B, S, D = x.shape
    N = B * S
    K, E = cfg.top_k, cfg.n_experts
    G = _moe_groups(N)
    T = N // G
    capacity = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))
    dt = x.dtype

    xg = shard(x.reshape(G, T, D), "batch", None, None)
    logits = (xg @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G,T,E)
    vals, eidx = jax.lax.top_k(probs, K)  # (G,T,K)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)

    te = eidx.reshape(G, T * K)
    tw = vals.reshape(G, T * K)
    order = jnp.argsort(te, axis=1)  # stable, per group
    se = jnp.take_along_axis(te, order, axis=1)
    sw = jnp.take_along_axis(tw, order, axis=1)
    si = order // K  # source token within the group

    garange = jnp.arange(G)[:, None]
    counts = jnp.zeros((G, E), jnp.int32).at[garange, se].add(1)
    offsets = jnp.cumsum(counts, axis=1) - counts  # exclusive, per group
    pos = jnp.arange(T * K)[None, :] - jnp.take_along_axis(offsets, se, axis=1)
    keep = pos < capacity
    dest_e = jnp.where(keep, se, E)  # E = drop row (OOB, mode="drop")
    dest_p = jnp.where(keep, pos, 0)

    def scatter_group(xf, de, dp, sidx):
        buf = jnp.zeros((E, capacity, D), dt)
        return buf.at[de, dp].set(xf[sidx], mode="drop")

    buf = jax.vmap(scatter_group)(xg, dest_e, dest_p, si)  # (G,E,C,D)
    buf = shard(buf, "batch", "experts", None, None)

    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(dt))
    ) * jnp.einsum("gecd,edf->gecf", buf, params["w_in"].astype(dt))
    h = shard(h, "batch", "experts", None, None)
    y = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(dt))
    y = shard(y, "batch", "experts", None, None)

    def combine_group(yg, de, dp, sidx, w, kp):
        safe_e = jnp.minimum(de, E - 1)
        y_tok = yg[safe_e, dp] * (w * kp)[:, None].astype(dt)
        return jnp.zeros((T, D), dt).at[sidx].add(y_tok)

    out = jax.vmap(combine_group)(y, dest_e, dest_p, si, sw, keep)
    out = shard(out, "batch", None, None)
    return out.reshape(B, S, D)


class MoELayer:
    @staticmethod
    def init(cfg, key):
        ks = jax.random.split(key, 4)
        return {
            "norm1": L.norm_init(cfg, ks[0]),
            "attn": L.attention_init(cfg, ks[1]),
            "norm2": L.norm_init(cfg, ks[2]),
            "moe": moe_init(cfg, ks[3]),
        }

    @staticmethod
    def spec(cfg):
        return {
            "norm1": L.norm_spec(cfg),
            "attn": L.attention_spec(cfg),
            "norm2": L.norm_spec(cfg),
            "moe": moe_spec(cfg),
        }

    init_cache = staticmethod(kv_cache_init)

    @staticmethod
    def cache_spec(cfg):
        return kv_cache_spec(cfg)

    @staticmethod
    def apply(cfg, params, x, *, mode, cache=None, pos=None, probe=False,
              extras=None):
        h = L.norm_apply(cfg, params["norm1"], x)
        if mode == "decode":
            q, k, v = L._project_qkv(cfg, params["attn"], h, pos[:, None])
            cache = _cache_write_token(cfg, cache, k, v, pos)
            attn = _decode_self_attention(cfg, q, cache, pos)
        else:
            S = x.shape[1]
            positions = jnp.arange(S)[None, :]
            q, k, v = L._project_qkv(cfg, params["attn"], h, positions)
            if mode == "prefill":
                cache = build_prefill_cache(cfg, k, v, extras["max_len"])
            attn = L.full_attention(cfg, q, k, v, probe=probe)
        x = x + attn @ params["attn"]["wo"].astype(x.dtype)
        h = L.norm_apply(cfg, params["norm2"], x)
        x = x + moe_apply(cfg, params["moe"], h)
        return shard(x, "batch", "res_seq", "dmodel"), cache
