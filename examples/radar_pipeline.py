"""Radar applications end-to-end (paper Table 2, shrunk) on the
streaming session API: RC, PD and SAR chains submitted through a
Session on GPU-only and 3CPU+1GPU configurations, reference vs RIMMS —
plus multi-client streaming: concurrent submitter threads sharing one
session, windowed-HEFT placement, and the modeled Gantt.

Run:  PYTHONPATH=src python examples/radar_pipeline.py
"""

import functools
import threading

from repro.apps.radar import (build_pd, build_rc, build_sar, make_session,
                              submit_2fzf)


def bench(builder, policy, n_cpu, accelerators):
    """Run one app's task build through a session (tasks stream in
    submission order; round_robin keeps the paper's placement)."""
    session = make_session(policy=policy, scheduler="round_robin",
                           n_cpu=n_cpu, accelerators=accelerators)
    # App builders produce (buffers, Task lists) against the context;
    # stream the tasks through the session via wrapped buffers.
    bufs, tasks = builder(session.context)
    for t in tasks:
        session.submit(t.op, t.inputs, out=t.outputs, pin=t.pin,
                       name=t.name, **t.params)
    session.barrier()  # jit warmup round
    session.ledger.reset()
    t0 = session.report()["wall_s"]
    for t in tasks:
        session.submit(t.op, t.inputs, out=t.outputs, pin=t.pin,
                       name=t.name, **t.params)
    session.barrier()
    wall = session.report()["wall_s"] - t0
    snap = session.ledger.snapshot()
    session.close()
    session.runtime.close()
    return wall, snap


def main():
    apps = [
        ("RC ", build_rc),
        ("PD ", functools.partial(build_pd, ways=32, n=128)),
        ("SAR", functools.partial(build_sar, scale=16)),
    ]
    print(f"{'app':4s} {'config':10s} {'ref ms':>9s} {'rimms ms':>9s} "
          f"{'spdup':>6s} {'copies':>12s} {'modeled spdup':>13s}")
    for name, builder in apps:
        for cfg_name, n_cpu, accs in (("gpu-only", 0, ("gpu0",)),
                                      ("3cpu-1gpu", 3, ("gpu0",))):
            ref_w, ref_l = bench(builder, "reference", n_cpu, accs)
            rim_w, rim_l = bench(builder, "rimms", n_cpu, accs)
            print(
                f"{name:4s} {cfg_name:10s} {ref_w*1e3:9.2f} {rim_w*1e3:9.2f} "
                f"{ref_w/max(rim_w,1e-12):5.2f}x "
                f"{ref_l['total_copies']:5d}->{rim_l['total_copies']:<5d} "
                f"{ref_l['modeled_seconds']/max(rim_l['modeled_seconds'],1e-12):12.2f}x"
            )

    # --- multi-client streaming: 4 clients share one 2-accelerator
    # session; windowed HEFT places the interleaved chains --------------
    print("\n4 concurrent clients x 4 radar chains on one 2-accelerator "
          "session (windowed HEFT):")
    session = make_session(policy="rimms", scheduler="heft", n_cpu=0,
                           accelerators=("gpu0", "gpu1"))

    def client(c):
        for k in range(4):
            bufs = submit_2fzf(session, 2048, seed=c * 10 + k,
                               tag=f"_c{c}k{k}")
            bufs["out"].result()  # each client blocks only on its own work

    threads = [threading.Thread(target=client, args=(c,)) for c in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    session.barrier()
    rep = session.report()
    print(f"  {rep['n_tasks']} tasks streamed, modeled makespan "
          f"{rep['makespan_model']*1e3:.3f} ms, per-PE busy: "
          + ", ".join(f"{pe}={s*1e3:.3f}ms"
                      for pe, s in sorted(rep["per_pe_busy_model_s"].items())))
    print("  stream schedule (modeled Gantt):")
    print(rep["timeline"].gantt(64))
    session.close()
    session.runtime.close()


if __name__ == "__main__":
    main()
