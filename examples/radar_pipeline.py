"""Radar applications end-to-end (paper Table 2, shrunk): RC, PD and SAR
through the task runtime on GPU-only and 3CPU+1GPU configurations,
reference vs RIMMS — plus the async task-graph executor (serial vs graph
modeled makespan, transfer/compute overlap).

Run:  PYTHONPATH=src python examples/radar_pipeline.py
"""

import functools

from repro.apps.radar import build_pd, build_rc, build_sar, make_runtime, run_pipeline


def bench(builder, policy, n_cpu, accelerators, *, mode="serial",
          scheduler="round_robin"):
    rt, ctx = make_runtime(policy=policy, n_cpu=n_cpu,
                           accelerators=accelerators, scheduler=scheduler)
    bufs, tasks = builder(ctx)
    run_pipeline(rt, tasks, mode=mode)  # warmup
    ctx.ledger.reset()
    wall = run_pipeline(rt, tasks, mode=mode)
    return wall, ctx.ledger.snapshot(), rt


def main():
    apps = [
        ("RC ", build_rc),
        ("PD ", functools.partial(build_pd, ways=32, n=128)),
        ("SAR", functools.partial(build_sar, scale=16)),
    ]
    print(f"{'app':4s} {'config':10s} {'ref ms':>9s} {'rimms ms':>9s} "
          f"{'spdup':>6s} {'copies':>12s} {'modeled spdup':>13s}")
    for name, builder in apps:
        for cfg_name, n_cpu, accs in (("gpu-only", 0, ("gpu0",)),
                                      ("3cpu-1gpu", 3, ("gpu0",))):
            ref_w, ref_l, _ = bench(builder, "reference", n_cpu, accs)
            rim_w, rim_l, _ = bench(builder, "rimms", n_cpu, accs)
            print(
                f"{name:4s} {cfg_name:10s} {ref_w*1e3:9.2f} {rim_w*1e3:9.2f} "
                f"{ref_w/max(rim_w,1e-12):5.2f}x "
                f"{ref_l['total_copies']:5d}->{rim_l['total_copies']:<5d} "
                f"{ref_l['modeled_seconds']/max(rim_l['modeled_seconds'],1e-12):12.2f}x"
            )

    # --- async graph executor: PD on two accelerators --------------------
    print("\nPD (32-way) on 2 accelerators — serial vs task-graph executor:")
    builder = functools.partial(build_pd, ways=32, n=128)
    _, _, rt_s = bench(builder, "rimms", 0, ("gpu0", "gpu1"), mode="serial")
    _, _, rt_g = bench(builder, "rimms", 0, ("gpu0", "gpu1"), mode="graph",
                       scheduler="heft")
    sm, gm = rt_s.last_makespan_model, rt_g.last_makespan_model
    print(f"  modeled makespan: serial {sm*1e3:.3f} ms -> graph {gm*1e3:.3f} ms "
          f"({sm/max(gm,1e-12):.2f}x)")
    print("  graph schedule (modeled Gantt):")
    print(rt_g.timeline.gantt(64))


if __name__ == "__main__":
    main()
