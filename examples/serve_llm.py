"""Serving driver: multi-tenant continuous batching on the RIMMS Session.

A small dense LM serves two tenants' prompt streams through
:class:`repro.serve.session_engine.SessionServeEngine`: every tenant is
a QoS client with its own decode weight and KV page quota, KV pages live
in runtime-managed page-group buffers, and the engine reports per-tenant
decode latency percentiles + SLO burn rates from the deterministic QoS
replay.  ``--legacy`` runs the same workload through the hand-managed
:class:`repro.serve.engine.ServeEngine` instead — both engines generate
bit-identical token streams.

Run:  PYTHONPATH=src python examples/serve_llm.py [--legacy]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.session_engine import SessionServeEngine


def make_requests(vocab: int, max_new: int):
    rng = np.random.default_rng(0)
    lens = (4, 7, 3, 9, 5, 6, 4, 8)
    return [(rng.integers(1, vocab, size=n).tolist(), max_new)
            for n in lens]


def serve_legacy(cfg, params, work):
    eng = ServeEngine(cfg, params, max_batch=4, page_size=16, num_pages=256,
                      max_pages_per_seq=16, allocator="bitset")
    reqs = [eng.submit(p, m) for p, m in work]
    eng.run()
    print(f"page pool: {eng.pool.free_pages} free of {eng.pool.num_pages} "
          f"(fragment-allocs={eng.pool.fragment_allocs}, "
          f"fallbacks={eng.pool.fallback_allocs})")
    return reqs


def serve_session(cfg, params, work):
    with SessionServeEngine(cfg, params, max_batch=4, page_size=16,
                            num_pages=256, max_pages_per_seq=16,
                            allocator="bitset") as eng:
        # two tenants: "pro" gets 4x the decode weight and most of the
        # KV page budget; "free" runs under a tight quota.
        eng.tenant("pro", weight=4.0, quota_pages=192,
                   slo_latency_s=1.0, slo_target=0.99)
        eng.tenant("free", weight=1.0, quota_pages=32,
                   slo_latency_s=1.0, slo_target=0.99)
        reqs = [eng.submit(p, m, tenant=("pro" if i % 2 == 0 else "free"))
                for i, (p, m) in enumerate(work)]
        eng.run()
        rep = eng.qos_report()
        for name in ("pro", "free"):
            pct = rep["latency_percentiles"][name]
            slo = rep["slo"][name]
            print(f"  tenant {name}: {pct['count']} decode substeps, "
                  f"modeled p95 {pct['p95'] * 1e6:.1f}us, "
                  f"slo burn rate {slo['burn_rate']:.3f}")
        print(f"  kv spill bytes: {eng.kv.spill_bytes()}")
    return reqs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--legacy", action="store_true",
                    help="use the hand-managed ServeEngine instead of "
                         "the Session-backed engine")
    ap.add_argument("--tokens", type=int, default=8,
                    help="max new tokens per request")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("llama3_8b").smoke(), name="serve-demo", dtype="float32"
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    work = make_requests(cfg.vocab, args.tokens)

    t0 = time.perf_counter()
    if args.legacy:
        reqs = serve_legacy(cfg, params, work)
    else:
        reqs = serve_session(cfg, params, work)
    wall = time.perf_counter() - t0

    assert all(r.done for r in reqs)
    total_new = sum(len(r.generated) for r in reqs)
    eng_name = "legacy" if args.legacy else "session"
    print(f"served {len(reqs)} requests / {total_new} tokens on the "
          f"{eng_name} engine in {wall:.2f}s "
          f"({total_new / wall:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
