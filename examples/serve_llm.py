"""Serving driver: batched requests through the RIMMS paged-KV engine.

A small dense LM serves a stream of prompts with continuous batching;
KV pages come from the paper's marking systems (bitset block tables) and
are recycled as requests complete.

Run:  PYTHONPATH=src python examples/serve_llm.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = dataclasses.replace(
        get_config("llama3_8b").smoke(), name="serve-demo", dtype="float32"
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=4, page_size=16, num_pages=256,
                      max_pages_per_seq=16, allocator="bitset")

    rng = np.random.default_rng(0)
    reqs = [
        eng.submit(rng.integers(1, cfg.vocab, size=l).tolist(),
                   max_new_tokens=8)
        for l in (4, 7, 3, 9, 5, 6, 4, 8)
    ]
    t0 = time.perf_counter()
    steps = 0
    while any(not r.done for r in reqs):
        eng.step()
        steps += 1
    wall = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {total_new} tokens in {steps} "
          f"engine steps, {wall:.2f}s "
          f"({total_new/wall:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.generated}")
    print(f"page pool: {eng.pool.free_pages} free of {eng.pool.num_pages} "
          f"(fragment-allocs={eng.pool.fragment_allocs}, "
          f"fallbacks={eng.pool.fallback_allocs})")


if __name__ == "__main__":
    main()
