"""Quickstart: the RIMMS streaming session API on an emulated SoC.

The session is the primary entry point (ISSUE 4): ``@rimms.op`` kernels
register per-PE-kind variants, ``Session.malloc``/``Session.submit``
return BufferFutures that extend a live task DAG, and the runtime owns
placement, movement and completion — ``result()`` is the only sync
point.  The ledger shows the eliminated copies vs the host-owned
reference flow (paper Fig 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.apps.radar import make_session, submit_2fzf
from repro.core import api as rimms


# A custom op: one decorator per PE-kind variant — no register_kernel,
# no Task lists.  (The radar import above already registered fft/ifft/zip
# variants the same way.)
@rimms.op("scale", kinds=("cpu", "gpu"))
def scale(ins, *, k=2.0):
    return (ins[0] * k).astype(np.complex64)


def run_policy(policy: str):
    """One 2FZF radar chain streamed through a session under ``policy``
    on the paper's ACC-ACC scenario (FFT engine + ZIP engine, no CPU
    PE); returns (output, ledger snapshot, placements)."""
    with make_session(policy=policy, scheduler="round_robin", n_cpu=0,
                      accelerators=("fft_acc0", "zip_acc0")) as s:
        bufs = submit_2fzf(s, 256, seed=42)
        out = bufs["out"].result()  # the only sync point
        snapshot = s.ledger.snapshot()
        placements = list(s.runtime.task_log)
    s.runtime.close()
    return out, snapshot, placements


def main():
    # --- the session API tour --------------------------------------------
    with make_session(accelerators=("gpu0",)) as s:
        M, N = 8, 128
        inp = s.malloc((M * N,), np.complex64)     # hete_Malloc
        inp.hete.fragment(N)                       # fragment into M inputs
        inp.hete[3].data[:] = 1.0 + 0j             # indexed fragment access
        print(f"allocated {M}x{N} complex buffer, fragment 3 sum =",
              inp.hete[3].data.sum())

        sig = s.malloc((N,), np.complex64)
        sig.data[:] = np.exp(2j * np.pi * np.arange(N) * 4 / N)
        f = s.submit("fft", [sig])                 # deferred: returns a future
        g = s.submit("scale", [f], k=0.5)          # chains without waiting
        back = s.submit("ifft", [g])
        np.testing.assert_allclose(back.result(), 0.5 * sig.data, atol=1e-4)
        print("fft -> scale(custom op) -> ifft chain ✓ "
              f"({len(s.runtime.task_log)} tasks streamed)")

        inp.free()                                 # free-after-last-use
        sig.free()
    s.runtime.close()

    # --- reference vs RIMMS on the 2FZF radar chain ----------------------
    results = {}
    for policy in ("reference", "rimms"):
        out, ledger, placements = run_policy(policy)
        results[policy] = out
        print(f"\n[{policy:9s}] copies={ledger['total_copies']} "
              f"bytes={ledger['total_bytes']} "
              f"modeled={ledger['modeled_seconds']*1e6:.1f}us")
        for pair, n in ledger["by_pair"].items():
            print(f"    {pair}: {n}")
        print(f"    placements: {placements}")
    np.testing.assert_allclose(results["reference"], results["rimms"],
                               atol=1e-4)
    print("\nreference == rimms output ✓ (fewer copies, same math)")


if __name__ == "__main__":
    main()
