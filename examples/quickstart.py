"""Quickstart: the RIMMS API on an emulated heterogeneous SoC.

Mirrors the paper's Listing 4: hete_Malloc + fragment + task execution
with runtime-managed data movement — and shows the ledger evidence of
eliminated copies vs the host-owned reference flow (Fig 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.apps.radar import build_2fzf, make_runtime
from repro.core.hete import hete_sync


def run_policy(policy: str):
    rt, ctx = make_runtime(policy=policy, accelerators=("fft_acc0", "zip_acc0"))
    bufs, tasks = build_2fzf(ctx, n=256, seed=42)
    rt.run(tasks)  # warmup/compile
    ctx.ledger.reset()
    wall = rt.run(tasks)
    out = hete_sync(bufs["out"], context=ctx)
    return out, ctx.ledger.snapshot(), wall, rt.task_log[-4:]


def main():
    # --- Listing-4 flavoured API tour -----------------------------------
    from repro.core.hete import HeteContext

    ctx = HeteContext()
    M, N = 8, 128
    inp = ctx.malloc((M * N,), np.complex64)   # hete_Malloc
    inp.fragment(N)                            # fragment into M FFT inputs
    inp[3].data[:] = 1.0 + 0j                  # indexed fragment access
    print(f"allocated {M}x{N} complex buffer, fragment 3 sum =",
          inp[3].data.sum())
    ctx.free(inp)                              # hete_Free

    # --- reference vs RIMMS on the 2FZF radar chain ----------------------
    results = {}
    for policy in ("reference", "rimms"):
        out, ledger, wall, placement = run_policy(policy)
        results[policy] = out
        print(f"\n[{policy:9s}] copies={ledger['total_copies']} "
              f"bytes={ledger['total_bytes']} "
              f"modeled={ledger['modeled_seconds']*1e6:.1f}us "
              f"wall={wall*1e6:.1f}us")
        for pair, n in ledger["by_pair"].items():
            print(f"    {pair}: {n}")
    np.testing.assert_allclose(results["reference"], results["rimms"],
                               atol=1e-4)
    print("\nreference == rimms output ✓ (fewer copies, same math)")


if __name__ == "__main__":
    main()
