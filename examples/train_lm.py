"""End-to-end training driver: a llama-family LM through the full stack
(pipeline → RIMMS-staged batches → jitted train step → checkpoints,
preemption-safe).

Presets:
  --preset tiny   (default)  ~1M params, 60 steps — finishes on CPU in ~a minute
  --preset 100m              ~100M params, 300 steps — the deliverable-scale
                              run for a real machine (works on CPU, slowly)

Run:  PYTHONPATH=src python examples/train_lm.py [--preset tiny] [--steps N]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.train.loop import Trainer, TrainerConfig

PRESETS = {
    "tiny": dict(d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
                 head_dim=16, d_ff=128, vocab=512, batch=2, seq=64,
                 steps=60),
    "100m": dict(d_model=768, n_layers=12, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=32000, batch=8, seq=512,
                 steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = dataclasses.replace(
        get_config("llama3_8b"),
        name=f"llama-{args.preset}",
        d_model=p["d_model"], n_layers=p["n_layers"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"], d_ff=p["d_ff"],
        vocab=p["vocab"], q_chunk=128,
    )
    steps = args.steps or p["steps"]
    trainer = Trainer(
        cfg, batch_size=p["batch"], seq_len=p["seq"],
        tcfg=TrainerConfig(steps=steps, ckpt_every=max(steps // 4, 10),
                           ckpt_dir=args.ckpt_dir, log_every=5),
    )
    trainer.install_signal_handlers()
    report = trainer.run()
    print("\nstep  loss     grad_norm  s/step")
    for m in report["metrics"]:
        print(f"{m['step']:5d} {m['loss']:8.4f} {m['grad_norm']:9.4f} "
              f"{m['sec_per_step']:7.3f}")
    first, last = report["metrics"][0]["loss"], report["metrics"][-1]["loss"]
    best = min(m["loss"] for m in report["metrics"])
    print(f"\nloss {first:.4f} → {last:.4f} (best {best:.4f}) over "
          f"{report['final_step']} steps ({report['wall_s']:.1f}s wall, "
          f"{report['straggler_events']} straggler events)")
    print("batch transfers (RIMMS ledger):", report["transfers"]["by_pair"])
    # NB: synthetic uniform tokens have an entropy floor of ln(vocab)
    # (~6.24 nats at vocab=512) — the demo checks stability, not fit.
    assert best <= first + 0.05, "training diverged"


if __name__ == "__main__":
    main()
