"""Checkpoint format: atomicity, retention, roundtrip, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"m": jnp.zeros((3, 4)), "step": jnp.asarray(5)},
    }


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 7, t, extra={"note": "x"})
    restored, step, extra = restore_checkpoint(tmp_path, t)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_partial_tmp(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate a crash mid-write of step 2
    broken = tmp_path / "step_00000002.tmp"
    (broken / "arrays").mkdir(parents=True)
    assert latest_step(tmp_path) == 1
    restored, step, _ = restore_checkpoint(tmp_path, t)
    assert step == 1
    # next save garbage-collects the stale tmp
    save_checkpoint(tmp_path, 3, t)
    assert not broken.exists()


def test_retention(tmp_path):
    t = tree()
    for s in range(1, 6):
        save_checkpoint(tmp_path, s, t, keep=2)
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_00000004", "step_00000005"]


def test_elastic_restore_onto_mesh(tmp_path):
    """Checkpoints store global logical arrays → restore onto any mesh."""
    from repro.distributed.compat import make_mesh

    t = tree()
    save_checkpoint(tmp_path, 2, t)
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, step, _ = restore_checkpoint(tmp_path, t, shardings=sh)
    assert step == 2
    w = restored["params"]["w"]
    assert w.sharding == NamedSharding(mesh, P())
