"""Training-loop fault tolerance: checkpoint/restart determinism,
preemption safety, straggler detection, pipeline resume."""

import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.train.loop import Trainer, TrainerConfig

CFG = get_config("llama3_8b").smoke()


def make_trainer(tmp_path, steps=6, ckpt_every=3, seed=0):
    t = Trainer(
        CFG, batch_size=2, seq_len=16,
        tcfg=TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                           ckpt_dir=str(tmp_path / "ckpt"), log_every=1,
                           seed=seed),
    )
    return t


def test_pipeline_deterministic_resume():
    p = TokenPipeline(CFG, 2, 16, seed=7)
    b0, b1 = next(p), next(p)
    q = TokenPipeline(CFG, 2, 16, seed=7)
    q.restore(p.state())  # state points at batch 2
    next(p)
    # a fresh pipeline restored from state produces the same stream
    r = TokenPipeline(CFG, 2, 16, seed=7)
    np.testing.assert_array_equal(r.batch_at(0)["tokens"], b0["tokens"])
    np.testing.assert_array_equal(r.batch_at(1)["tokens"], b1["tokens"])


def test_train_runs_and_logs(tmp_path):
    t = make_trainer(tmp_path, steps=4, ckpt_every=10)
    report = t.run()
    assert report["final_step"] == 4
    losses = [m["loss"] for m in report["metrics"]]
    assert all(np.isfinite(l) for l in losses)
    # RIMMS ledger saw exactly one host→device ingest per batch leaf
    assert report["transfers"]["total_copies"] == 4 * 2  # tokens+labels


def test_checkpoint_restart_bitwise_resume(tmp_path):
    # run 6 steps straight
    t1 = make_trainer(tmp_path / "a", steps=6, ckpt_every=100)
    r1 = t1.run()
    # run 3 steps, "crash", restart a fresh trainer, run to 6
    t2 = make_trainer(tmp_path / "b", steps=3, ckpt_every=3)
    t2.run()
    t3 = make_trainer(tmp_path / "b", steps=6, ckpt_every=3)
    assert t3.maybe_restore()
    assert t3.step == 3
    r3 = t3.run()
    l1 = [m for m in r1["metrics"] if m["step"] == 6][0]["loss"]
    l3 = [m for m in r3["metrics"] if m["step"] == 6][0]["loss"]
    np.testing.assert_allclose(l1, l3, rtol=1e-5)


def test_preemption_checkpoints_and_exits(tmp_path):
    t = make_trainer(tmp_path, steps=100, ckpt_every=1000)
    orig = t.on_straggler
    calls = []

    def stop_after_two(step, dt, med):
        calls.append(step)

    t.on_straggler = stop_after_two
    # preempt via the signal-handler flag after 2 steps
    steps_done = []

    real_stage = t._stage_batch

    def staged(b):
        if t.step >= 2:
            t.request_preemption()
        return real_stage(b)

    t._stage_batch = staged
    report = t.run()
    assert report["preempted"]
    assert report["final_step"] < 100
    from repro.train.checkpoint import latest_step
    assert latest_step(t.tcfg.ckpt_dir) == report["final_step"]


def test_straggler_detection():
    t = Trainer(CFG, 2, 16, tcfg=TrainerConfig(steps=8, ckpt_every=100,
                                               ckpt_dir="/tmp/unused_ck",
                                               straggler_factor=0.0))
    # factor 0 → every step after the 5th is a "straggler"
    report = t.run()
    assert report["straggler_events"] > 0
