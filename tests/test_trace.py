"""Tracing + metrics subsystem (ISSUE 6): ring buffers, Perfetto
export, histograms, and the trace_lint invariant checker."""

import json
import threading

import numpy as np

from repro.apps.radar import build_2fzf, make_runtime, make_session, submit_2fzf
from repro.core import api as rimms
from repro.core.trace import (
    MODEL_PID,
    WALL_PID,
    Histogram,
    MetricsRegistry,
    TraceCollector,
    global_collector,
    trace,
    trace_lint,
)


# ---------------------------------------------------------------------------
# collector mechanics
# ---------------------------------------------------------------------------


def test_ring_buffer_bounds_memory_and_counts_drops():
    tc = TraceCollector(capacity_per_thread=4)
    for i in range(10):
        tc.instant(f"e{i}", "test", "t")
    assert tc.event_count() == 4
    assert tc.drops() == 6
    # drops surface as a lint violation: the trace is incomplete
    assert any("dropped" in v for v in trace_lint(tc.export()))


def test_disabled_collector_records_nothing():
    tc = TraceCollector()
    tc.pause()
    tc.instant("e", "test", "t")
    tc.span("s", "test", "t", 0.0, 1.0)
    tc.transfer("ctx0", "host", "gpu0", 128, 0.1)
    assert tc.event_count() == 0
    tc.resume()
    tc.instant("e", "test", "t")
    assert tc.event_count() == 1


def test_per_thread_rings_need_no_lock_on_hot_path():
    tc = TraceCollector(capacity_per_thread=1 << 12)
    n, threads = 1000, 4

    def emit(k):
        for i in range(n):
            tc.instant(f"t{k}.{i}", "test", f"thr:{k}")

    ts = [threading.Thread(target=emit, args=(k,)) for k in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert tc.event_count() == n * threads
    assert tc.drops() == 0


def test_export_structure_is_perfetto_loadable():
    tc = TraceCollector()
    t0 = tc.now()
    tc.span("work", "compute", "pe:gpu0", t0, t0 + 0.001, {"task": "work"})
    tc.instant("evict", "memory", "mem:gpu0", {"nbytes": 64})
    doc = tc.export()
    json.dumps(doc)  # must be JSON-serializable
    evs = doc["traceEvents"]
    pids = {e["pid"] for e in evs}
    assert WALL_PID in pids
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"pe:gpu0", "mem:gpu0"} <= names
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 1 and xs[0]["dur"] > 0 and xs[0]["cat"] == "compute"
    insts = [e for e in evs if e["ph"] == "i"]
    assert insts and all(e["s"] == "t" for e in insts)
    assert doc["rimms"]["drops"] == 0


def test_modeled_and_wall_land_in_separate_process_groups():
    rt, ctx = make_runtime(policy="rimms", accelerators=("gpu0",))
    with trace(context=ctx) as tc:
        _, tasks = build_2fzf(ctx, 64, pins=("gpu0",) * 4)
        rt.run(tasks)
        doc = tc.export()
    by_pid = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X":
            by_pid.setdefault(e["pid"], set()).add(e["cat"])
    assert "compute" in by_pid[WALL_PID]
    assert "compute" in by_pid[MODEL_PID]
    assert trace_lint(doc) == []
    assert ctx.tracer is None  # detached on exit


def test_global_trace_attaches_new_contexts():
    assert global_collector() is None
    with trace() as tc:
        assert global_collector() is tc
        rt, ctx = make_runtime(policy="rimms", accelerators=("gpu0",))
        assert ctx.tracer is tc
    assert global_collector() is None


def test_eviction_instants_under_pressure():
    import numpy as np_
    from repro.core.hete import HeteContext, MemorySpace, hete_malloc
    from repro.core.locations import Location

    acc = Location("device", "acc0")
    with trace() as tc:
        ctx = HeteContext(tracking="flag")
        ctx.register_space(MemorySpace(
            acc, capacity=4096, allocator="nextfit",
            ingest=lambda a: a.copy(), egress=lambda a: np_.asarray(a),
        ))
        for _ in range(4):
            hd = hete_malloc((512,), np_.float32, context=ctx)
            v = ctx.ensure(hd, acc)
            ctx.mark_written(hd, acc, v + 1.0)
        doc = tc.export()
    assert ctx.ledger.total_evictions > 0
    evicts = [e for e in doc["traceEvents"]
              if e.get("ph") == "i" and e.get("name") in ("evict", "spill_to_peer")]
    assert len(evicts) == ctx.ledger.total_evictions
    assert all(e["cat"] == "memory" for e in evicts)
    assert trace_lint(doc) == []


# ---------------------------------------------------------------------------
# session end-to-end
# ---------------------------------------------------------------------------


def test_session_trace_end_to_end(tmp_path):
    sess = make_session(trace=True)
    try:
        submit_2fzf(sess, 64)
        sess.barrier()
        rep = sess.qos_report()
        pct = rep["latency_percentiles"]
        assert pct, "per-client percentiles missing"
        for stats in pct.values():
            assert 0.0 < stats["p50"] <= stats["p95"] <= stats["p99"]
            assert stats["count"] > 0
        assert rep["metrics"]["submits"]["value"] == 4
        sess.close()
        path = tmp_path / "session.json"
        doc = sess.export_trace(str(path))
        assert path.exists()
        assert trace_lint(str(path)) == []
        cats = {e.get("cat") for e in doc["traceEvents"]}
        # full lifecycle: submit -> qos -> stage -> compute -> transfer
        assert {"submit", "qos", "stage", "compute", "transfer"} <= cats
        tenant_tracks = [
            e for e in doc["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"
            and "tenant:" in e["args"]["name"]
        ]
        assert tenant_tracks
    finally:
        sess.runtime.close()


def test_session_export_without_tracer_raises():
    sess = make_session()
    try:
        submit_2fzf(sess, 64)
        sess.barrier()
        try:
            sess.export_trace()
            raise AssertionError("expected RuntimeError")
        except RuntimeError:
            pass
    finally:
        sess.close()
        sess.runtime.close()


def test_trace_reexported_through_api():
    assert rimms.trace is trace
    assert rimms.trace_lint is trace_lint


# ---------------------------------------------------------------------------
# trace_lint negative cases
# ---------------------------------------------------------------------------


def _doc(events, rimms_meta=None):
    return {"traceEvents": events, "rimms": rimms_meta or {}}


def test_lint_flags_negative_duration():
    doc = _doc([{"ph": "X", "name": "bad", "cat": "compute",
                 "pid": 1, "tid": 1, "ts": 5.0, "dur": -1.0}])
    assert any("negative duration" in v for v in trace_lint(doc))


def test_lint_flags_overlapping_compute_spans():
    doc = _doc([
        {"ph": "M", "name": "thread_name", "pid": 2, "tid": 1,
         "args": {"name": "run0/pe:gpu0"}},
        {"ph": "X", "name": "a", "cat": "compute", "pid": 2, "tid": 1,
         "ts": 0.0, "dur": 10.0},
        {"ph": "X", "name": "b", "cat": "compute", "pid": 2, "tid": 1,
         "ts": 5.0, "dur": 10.0},
    ])
    assert any("overlap" in v for v in trace_lint(doc))
    # stage spans may overlap (prefetch/double-buffering): not flagged
    doc_stage = _doc([
        {"ph": "X", "name": "a", "cat": "stage", "pid": 2, "tid": 1,
         "ts": 0.0, "dur": 10.0},
        {"ph": "X", "name": "b", "cat": "stage", "pid": 2, "tid": 1,
         "ts": 5.0, "dur": 10.0},
    ])
    assert trace_lint(doc_stage) == []


def test_lint_flags_ledger_mismatch():
    meta = {"ledgers": {"ctx0": {"per_link": {
        "host->gpu0": {"copies": 2, "bytes": 256, "modeled_s": 0.0}},
        "bytes_moved": 256}}}
    # only one traced copy of 128 B against a ledger claiming 2/256
    doc = _doc([
        {"ph": "i", "name": "copy", "cat": "transfer", "pid": 1, "tid": 1,
         "ts": 0.0, "s": "t",
         "args": {"ctx": "ctx0", "src": "host", "dst": "gpu0",
                  "nbytes": 128, "epoch": 0}},
    ], meta)
    assert any("conservation" in v for v in trace_lint(doc))


def test_lint_flags_compute_before_staging_done():
    doc = _doc([
        {"ph": "M", "name": "thread_name", "pid": 2, "tid": 1,
         "args": {"name": "run0/pe:gpu0:stage"}},
        {"ph": "M", "name": "thread_name", "pid": 2, "tid": 2,
         "args": {"name": "run0/pe:gpu0"}},
        {"ph": "X", "name": "t", "cat": "stage", "pid": 2, "tid": 1,
         "ts": 0.0, "dur": 10.0, "args": {"node": 0}},
        {"ph": "X", "name": "t", "cat": "compute", "pid": 2, "tid": 2,
         "ts": 5.0, "dur": 10.0, "args": {"node": 0}},
    ])
    assert any("causality" in v for v in trace_lint(doc))


def test_lint_conservation_nets_out_preattach_baseline():
    rt, ctx = make_runtime(policy="rimms", accelerators=("gpu0",))
    _, tasks = build_2fzf(ctx, 64, pins=("gpu0",) * 4)
    rt.run(tasks)  # untraced copies accumulate first
    with trace(context=ctx) as tc:
        _, tasks2 = build_2fzf(ctx, 64, pins=("gpu0",) * 4, seed=1)
        rt.run(tasks2)
        assert trace_lint(tc.export()) == []


def test_lint_conservation_across_ledger_reset():
    rt, ctx = make_runtime(policy="rimms", accelerators=("gpu0",))
    with trace(context=ctx) as tc:
        _, tasks = build_2fzf(ctx, 64, pins=("gpu0",) * 4)
        rt.run(tasks)
        ctx.ledger.reset()  # opens a fresh conservation epoch
        _, tasks2 = build_2fzf(ctx, 64, pins=("gpu0",) * 4, seed=1)
        rt.run(tasks2)
        assert trace_lint(tc.export()) == []


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_percentiles_match_numpy_within_bucket_error():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=-8.0, sigma=1.5, size=5000)
    h = Histogram("lat")
    for x in xs:
        h.record(float(x))
    for q in (50, 95, 99):
        got = h.percentile(q)
        want = float(np.percentile(xs, q))
        assert abs(got - want) / want < 0.03, (q, got, want)
    assert h.count == len(xs)
    assert abs(h.mean - xs.mean()) / xs.mean() < 1e-9


def test_histogram_edge_cases():
    h = Histogram()
    # empty histogram: no samples -> percentile is None, not a raise
    assert h.percentile(50) is None and h.mean == 0.0
    h.record(0.0)
    h.record(-1.0)
    assert h.percentile(99) == 0.0  # non-positive values -> zero bucket
    h2 = Histogram()
    h2.record(4.2)
    assert h2.percentile(50) == 4.2  # single sample clamps to min/max


def test_metrics_registry_create_or_get_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    assert reg.counter("a").value == 3  # same instrument back
    reg.gauge("g").set(1.5)
    reg.histogram("h").record(2.0)
    try:
        reg.gauge("a")
        raise AssertionError("expected TypeError")
    except TypeError:
        pass
    snap = reg.snapshot()
    assert snap["a"] == {"type": "counter", "value": 3}
    assert snap["g"]["value"] == 1.5
    assert snap["h"]["count"] == 1
    assert reg.histograms() == [("h", reg.histogram("h"))]
