"""Serving engine: paged decode must match the dense-cache decode path;
pool pages recycle across requests (continuous batching)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("llama3_8b").smoke(), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    return cfg, model, params


def dense_greedy(model, params, prompt, n_new):
    """Reference generation through the dense-cache decode path."""
    B = 1
    toks = list(prompt)
    caches = model.init_cache(B, 128)
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray([toks], jnp.int32)}, max_len=128
    )
    out = []
    pos = len(toks)
    tok = int(jnp.argmax(logits[0]))
    for _ in range(n_new):
        out.append(tok)
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([tok], jnp.int32),
            jnp.asarray([pos], jnp.int32),
        )
        tok = int(jnp.argmax(logits[0]))
        pos += 1
    return out


def test_engine_matches_dense_path(setup):
    cfg, model, params = setup
    prompt = [5, 9, 2, 7]
    n_new = 6
    want = dense_greedy(model, params, prompt, n_new)
    eng = ServeEngine(cfg, params, max_batch=2, page_size=8, num_pages=64,
                      max_pages_per_seq=16)
    req = eng.submit(prompt, max_new_tokens=n_new)
    eng.run()
    assert req.done
    assert req.generated == want


def test_engine_batched_requests_and_page_recycling(setup):
    cfg, model, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, page_size=8, num_pages=32,
                      max_pages_per_seq=8)
    free0 = eng.pool.free_pages
    reqs = [eng.submit([i + 1, i + 2, i + 3], max_new_tokens=4)
            for i in range(5)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.pool.free_pages == free0  # all pages returned
    # each request individually matches the dense path
    for r in reqs[:2]:
        want = dense_greedy(model, params, r.prompt, 4)
        assert r.generated == want


def test_engine_rejects_recurrent_families(setup):
    cfg, model, params = setup
    bad = dataclasses.replace(cfg, family="ssm")
    with pytest.raises(ValueError, match="dense"):
        ServeEngine(bad, params)
