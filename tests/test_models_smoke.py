"""Per-arch smoke tests (deliverable f): reduced same-family configs run a
forward/train step on CPU with shape + finiteness asserts, plus
prefill↔decode consistency (validates cache/state handoff — for the
recurrent archs this checks chunkwise-parallel == stepwise math)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models import batch_specs, build_model
from repro.optim.adamw import adamw_init
from repro.train.step import build_train_step


def make_batch(cfg, shape, seed=0):
    specs = batch_specs(cfg, shape)
    key = jax.random.key(seed)
    out = {}
    for k, s in sorted(specs.items()):
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels", "token") else max(
                shape.seq_len - 1, 1)
            out[k] = jax.random.randint(sub, s.shape, 0, hi, jnp.int32)
        else:
            out[k] = jax.random.normal(sub, s.shape, s.dtype)
    return out


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return request.param, cfg, model, params


def test_forward_loss_finite(arch_setup):
    aid, cfg, model, params = arch_setup
    batch = make_batch(cfg, SHAPES["train_4k"].smoke())
    loss = model.loss(params, batch, remat=False)
    assert np.isfinite(float(loss)), aid
    assert float(loss) > 0


def test_train_step_updates_params(arch_setup):
    aid, cfg, model, params = arch_setup
    batch = make_batch(cfg, SHAPES["train_4k"].smoke())
    step = build_train_step(model, remat=True, microbatches=2)
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, aid


def test_decode_step_shapes_and_finite(arch_setup):
    aid, cfg, model, params = arch_setup
    B, max_len = 2, 64
    caches = model.init_cache(B, max_len)
    tok = jnp.array([1, 2], jnp.int32)
    pos = jnp.array([5, 5], jnp.int32)
    logits, caches = model.decode_step(params, caches, tok, pos)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), aid


def test_prefill_decode_consistency(arch_setup):
    """next-token logits after prefill(prompt[:-1]) + decode(prompt[-1])
    must match prefill(prompt) — exercises KV/state handoff."""
    aid, cfg, model, params = arch_setup
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg32)
    shape = SHAPES["prefill_32k"].smoke()
    batch = make_batch(cfg32, shape)
    B, S = batch["tokens"].shape
    full_logits, _ = model.prefill(params, batch, max_len=S + 8)

    b1 = dict(batch)
    b1["tokens"] = batch["tokens"][:, :-1]
    logits1, caches = model.prefill(params, b1, max_len=S + 8)
    # sequence position of the final token (VLM: patches prefix the seq)
    pos_last = S - 1 + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits2, _ = model.decode_step(
        params, caches, batch["tokens"][:, -1],
        jnp.full((B,), pos_last, jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(logits2, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_param_counts_sane(arch_setup):
    aid, cfg, model, params = arch_setup
    counts = model.param_counts()
    n_leaves = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    assert counts["total"] == pytest.approx(float(n_leaves))
    if cfg.is_moe:
        assert counts["active"] < counts["total"] - counts["embed"] + 1
