"""Session-backed serving engine: token streams bit-identical to the
legacy engine, KV spill through the runtime eviction path, tenant
quotas/backpressure, and serving telemetry."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.session_engine import SessionServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("llama3_8b").smoke(), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    return cfg, model, params


def make_work(vocab, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [([int(t) for t in rng.integers(1, vocab, int(rng.integers(2, 7)))],
             int(rng.integers(2, 6)))
            for _ in range(n)]


def legacy_tokens(cfg, params, work, max_batch=3, **kw):
    eng = ServeEngine(cfg, params, max_batch=max_batch, page_size=8,
                      num_pages=64, max_pages_per_seq=8, **kw)
    reqs = [eng.submit(p, m) for p, m in work]
    eng.run()
    return [r.generated for r in reqs]


def test_bit_identical_to_legacy_multi_tenant(setup):
    cfg, model, params = setup
    work = make_work(cfg.vocab)
    want = legacy_tokens(cfg, params, work)
    with SessionServeEngine(cfg, params, max_batch=3, page_size=8,
                            num_pages=64, max_pages_per_seq=8,
                            pages_per_group=8) as eng:
        reqs = [eng.submit(p, m, tenant=["a", "b"][i % 2])
                for i, (p, m) in enumerate(work)]
        eng.run()
        assert all(r.done for r in reqs)
        assert [r.generated for r in reqs] == want
        # runtime managed the KV: pages all recycled, tasks all traced
        assert eng.kv.used_pages == 1  # scratch page only
        rep = eng.qos_report()
        assert {"a", "b", "prefill"} <= set(rep["latency_percentiles"])


def test_spill_under_pressure_is_bit_identical(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    # enough churn that the nextfit cursor cycles every page group: the
    # resident KV working set then exceeds the shrunken arena
    work = [([int(t) for t in rng.integers(1, cfg.vocab,
                                           int(rng.integers(1, 9)))],
             int(rng.integers(1, 7)))
            for _ in range(28)]
    want = legacy_tokens(cfg, params, work, allocator="nextfit",
                         max_batch=4)
    with SessionServeEngine(cfg, params, max_batch=4, page_size=8,
                            num_pages=64, max_pages_per_seq=8,
                            pages_per_group=4, allocator="nextfit",
                            arena_bytes=150_000) as eng:
        reqs = [eng.submit(p, m, tenant=["a", "b"][i % 2])
                for i, (p, m) in enumerate(work)]
        eng.run()
        # cold page groups were evicted to host (dirty write-back through
        # the runtime coherence path) and re-staged — same tokens out.
        assert eng.kv.spill_bytes() > 0
        assert [r.generated for r in reqs] == want


def test_tenant_quota_defers_without_blocking_others(setup):
    cfg, model, params = setup
    work = make_work(cfg.vocab, n=4, seed=2)
    with SessionServeEngine(cfg, params, max_batch=4, page_size=8,
                            num_pages=64, max_pages_per_seq=8,
                            pages_per_group=8) as eng:
        eng.tenant("capped", quota_pages=2)
        reqs = [eng.submit(p, m, tenant="capped") for p, m in work[:3]]
        other = eng.submit(*work[3], tenant="open")
        eng.run()
        # quota forced serialization, not starvation: everything finishes
        assert all(r.done for r in reqs) and other.done
        assert int(eng.session.metrics.counter(
            "serve_quota_deferrals").value) > 0
        assert eng.kv.pool.tenant_pages("capped") == 0


def test_pool_exhaustion_backpressure_is_clean(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(4)
    # every request needs 2 pages (10 prompt + 4 new tokens, page=8)
    work = [([int(t) for t in rng.integers(1, cfg.vocab, 10)], 4)
            for _ in range(6)]
    want = legacy_tokens(cfg, params, work)
    # 7 usable pages → only 3 of the 4 slots can hold a sequence:
    # admission must defer cleanly, not corrupt — and the tokens still
    # match the unconstrained legacy run.
    with SessionServeEngine(cfg, params, max_batch=4, page_size=8,
                            num_pages=8, max_pages_per_seq=8,
                            pages_per_group=4) as eng:
        reqs = [eng.submit(p, m) for p, m in work]
        eng.run()
        assert all(r.done for r in reqs)
        assert [r.generated for r in reqs] == want
        assert int(eng.session.metrics.counter(
            "serve_pool_backpressure").value) > 0


def test_eos_mid_page_frees_and_matches_legacy(setup):
    cfg, model, params = setup
    work = make_work(cfg.vocab, n=3, seed=0)
    # pick an eos that actually fires mid-stream: the first generated
    # token of the first request, reused as eos for a longer rerun
    probe = legacy_tokens(cfg, params, work)
    eos = probe[0][0]
    long_work = [(p, 6) for p, _ in work]
    want = legacy_tokens(cfg, params, long_work, eos_id=eos)
    assert any(len(t) < 6 for t in want), "eos never fired; bad probe"
    with SessionServeEngine(cfg, params, max_batch=3, page_size=8,
                            num_pages=64, max_pages_per_seq=8,
                            pages_per_group=8, eos_id=eos) as eng:
        reqs = [eng.submit(p, m) for p, m in long_work]
        eng.run()
        assert [r.generated for r in reqs] == want
        assert eng.kv.used_pages == 1  # early-stopped pages recycled too


def test_prompt_longer_than_max_pages_rejected(setup):
    cfg, model, params = setup
    long_prompt = list(range(1, 40))  # 39 + 4 tokens > 2 pages * 8
    for ctor in (
        lambda: ServeEngine(cfg, params, page_size=8, num_pages=64,
                            max_pages_per_seq=2),
        lambda: SessionServeEngine(cfg, params, page_size=8, num_pages=64,
                                   max_pages_per_seq=2),
    ):
        eng = ctor()
        with pytest.raises(ValueError, match="max_pages_per_seq"):
            eng.submit(long_prompt, max_new_tokens=4)
        if isinstance(eng, SessionServeEngine):
            eng.close()


def test_serving_metrics_and_slo_exported(setup):
    cfg, model, params = setup
    work = make_work(cfg.vocab, n=3, seed=1)
    with SessionServeEngine(cfg, params, max_batch=3, page_size=8,
                            num_pages=64, max_pages_per_seq=8,
                            pages_per_group=8) as eng:
        eng.tenant("t0", slo_latency_s=60.0, slo_target=0.99)
        reqs = [eng.submit(p, m, tenant="t0") for p, m in work]
        eng.run()
        total = sum(len(r.generated) for r in reqs)
        m = eng.session.metrics
        assert int(m.counter("serve_tokens_generated").value) == total
        assert int(m.counter("serve_requests_completed").value) == len(work)
        text = eng.session.metrics_text()
        for name in ("serve_tokens_generated", "serve_requests_completed",
                     "serve_kv_pages_resident", "serve_kv_spill_bytes"):
            assert name in text
        slo = eng.qos_report()["slo"]["t0"]
        assert slo["violations"] == 0 and not slo["breached"]


def test_session_engine_rejects_recurrent_families(setup):
    cfg, model, params = setup
    bad = dataclasses.replace(cfg, family="ssm")
    with pytest.raises(ValueError, match="dense"):
        SessionServeEngine(bad, params)
