"""Optimizer + gradient compression unit/property tests.

The property test uses ``hypothesis`` when available; without it a
deterministic fallback covers the same bounded-error assertion (see
``requirements-dev.txt`` for the full dev toolchain).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (
    compress_grads,
    decompress_grads,
    ef_compress_tree,
    init_compression_state,
)
from repro.optim.schedule import cosine_schedule


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-2


def test_grad_clip_caps_update_norm():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, g, opt, params)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_schedule_warmup_and_decay():
    assert float(cosine_schedule(jnp.asarray(0), warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(jnp.asarray(10), warmup=10, total=100)) == pytest.approx(1.0)
    end = float(cosine_schedule(jnp.asarray(100), warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-6)


def _check_compression_bounded_error(vals):
    g = jnp.asarray(np.array(vals, np.float32))
    codes, scales = compress_grads(g)
    deq = decompress_grads(codes, scales, g.shape)
    blockmax = float(jnp.max(jnp.abs(g))) if g.size else 0.0
    assert float(jnp.max(jnp.abs(deq - g))) <= blockmax / 127.0 + 1e-6


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                    max_size=300))
    def test_compression_bounded_error(vals):
        _check_compression_bounded_error(vals)
else:
    def test_compression_bounded_error():
        pytest.importorskip("hypothesis")


def test_compression_bounded_error_fallback():
    """Deterministic coverage of the bounded-error property — always
    runs, so the core assertion holds even without hypothesis."""
    rng = np.random.default_rng(7)
    for size in (1, 3, 64, 300):
        _check_compression_bounded_error(
            (rng.uniform(-1e3, 1e3, size=size)).tolist())
    _check_compression_bounded_error([0.0, 0.0, 0.0])
    _check_compression_bounded_error([1e3, -1e3, 5e-7])


def test_error_feedback_converges():
    """With EF, the *accumulated* quantization error stays bounded and the
    mean compressed gradient tracks the true gradient."""
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=512).astype(np.float32) * 1e-3)}
    state = init_compression_state(g)
    total_sent = jnp.zeros_like(g["w"])
    steps = 20
    for _ in range(steps):
        sent, state = ef_compress_tree(g, state)
        total_sent = total_sent + sent["w"]
    # sum of transmitted grads ≈ steps * g (error feedback is unbiased)
    np.testing.assert_allclose(
        np.asarray(total_sent), steps * np.asarray(g["w"]),
        atol=2 * float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-6,
    )
