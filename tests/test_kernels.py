"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU; same code targets TPU v5e)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)


def crandn(*shape):
    return (rng.normal(size=shape) + 1j * rng.normal(size=shape)).astype(
        np.complex64
    )


# ---------------------------------------------------------------- zip ----
@pytest.mark.parametrize("shape", [(64,), (3, 300), (2, 5, 129)])
def test_zip_kernel(shape):
    from repro.kernels.zip import ops, ref

    a, b = crandn(*shape), crandn(*shape)
    np.testing.assert_allclose(
        ops.zip_mul(a, b), ref.zip_mul(a, b), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------- fft ----
@pytest.mark.parametrize("n", [2, 8, 64, 256, 1024, 2048, 8192])
def test_fft_kernel_sizes(n):
    from repro.kernels.fft import ops, ref

    x = crandn(4, n)
    tol = 3e-3 if n >= 2048 else 5e-4
    np.testing.assert_allclose(ops.fft(x), ref.fft(x), rtol=tol, atol=tol * n ** 0.5)


def test_ifft_roundtrip():
    from repro.kernels.fft import ops

    x = crandn(8, 512)
    np.testing.assert_allclose(
        ops.fft(ops.fft(x), forward=False), x, atol=1e-3
    )


def test_fft_batch_padding():
    from repro.kernels.fft import ops, ref

    x = crandn(3, 128)  # rows not a multiple of BLOCK_ROWS
    np.testing.assert_allclose(ops.fft(x), ref.fft(x), rtol=1e-3, atol=1e-3)


# ------------------------------------------------------ flash attention ----
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,d,bq,bk,dtype",
    [
        (2, 256, 4, 2, 64, 128, 128, jnp.float32),
        (1, 512, 2, 1, 128, 128, 256, jnp.float32),
        (2, 128, 4, 4, 64, 64, 64, jnp.bfloat16),
        (1, 384, 2, 2, 64, 128, 128, jnp.float32),  # ragged block count
    ],
)
def test_flash_attention_sweep(B, S, Hq, Hkv, d, bq, bk, dtype):
    from repro.kernels.flash_attention import ops, ref

    q = jnp.asarray(rng.normal(size=(B, S, Hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), dtype)
    got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    kr = jnp.repeat(k, Hq // Hkv, axis=2)
    vr = jnp.repeat(v, Hq // Hkv, axis=2)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * Hq, S, d)

    want = ref.attention(to_bh(q), to_bh(kr), to_bh(vr)).reshape(
        B, Hq, S, d
    ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_non_causal():
    from repro.kernels.flash_attention import ops, ref

    q = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(2, 128, 64)
    want = ref.attention(to_bh(q), to_bh(k), to_bh(v), causal=False)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want.reshape(1, 2, 128, 64).transpose(0, 2, 1, 3)),
        rtol=2e-4, atol=2e-4,
    )


# ------------------------------------------------------ paged attention ----
@pytest.mark.parametrize(
    "B,hq,hkv,d,P,page,npg",
    [(2, 4, 4, 64, 16, 8, 4), (4, 8, 2, 64, 32, 16, 6), (1, 2, 1, 128, 8, 4, 2)],
)
def test_paged_attention_sweep(B, hq, hkv, d, P, page, npg):
    from repro.kernels.paged_attention import ops, ref

    q = jnp.asarray(rng.normal(size=(B, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, hkv, d)), jnp.float32)
    bt = jnp.asarray(
        np.stack([rng.choice(P, npg, replace=False) for _ in range(B)])
        .astype(np.int32)
    )
    ln = jnp.asarray(rng.integers(1, npg * page + 1, size=(B,)).astype(np.int32))
    got = ops.paged_attention(q, kp, vp, bt, ln)
    want = ref.paged_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- rg_lru ----
@pytest.mark.parametrize("B,S,D", [(2, 32, 128), (3, 64, 200), (1, 128, 256)])
def test_rg_lru_sweep(B, S, D):
    from repro.kernels.rg_lru import ops, ref

    a = jnp.asarray(rng.uniform(0.3, 0.999, size=(B, S, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    hs, hN = ops.rg_lru_scan(a, b, h0)
    ws, wN = ref.rg_lru_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ws), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hN), np.asarray(wN), rtol=1e-4,
                               atol=1e-4)


def test_rg_lru_matches_sequential_loop():
    from repro.kernels.rg_lru import ops

    B, S, D = 1, 16, 128
    a = jnp.asarray(rng.uniform(0.5, 0.9, size=(B, S, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    h0 = jnp.zeros((B, D), jnp.float32)
    hs, _ = ops.rg_lru_scan(a, b, h0)
    h = np.zeros((B, D), np.float32)
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        np.testing.assert_allclose(np.asarray(hs[:, t]), h, rtol=1e-5,
                                   atol=1e-5)


# --------------------------------------------------------------- mlstm ----
@pytest.mark.parametrize("B,S,H,m,chunk", [(2, 64, 2, 128, 16),
                                           (1, 32, 4, 64, 8),
                                           (1, 128, 1, 128, 64)])
def test_mlstm_chunkwise_sweep(B, S, H, m, chunk):
    import math

    from repro.kernels.mlstm import ops, ref

    q = jnp.asarray(rng.normal(size=(B, S, H, m)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, m)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, S, H, m)), jnp.float32)
    ig = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, H)), jnp.float32)
    lf = jnp.asarray(np.log(rng.uniform(0.5, 0.95, size=(B, S, H))),
                     jnp.float32)
    got = ops.mlstm_chunkwise(q, k, v, ig, lf, chunk=chunk)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, m)

    def g_bh(x):
        return x.transpose(0, 2, 1).reshape(B * H, S)

    want = ref.mlstm_sequential(
        to_bh(q / math.sqrt(m)), to_bh(k), to_bh(v), g_bh(ig), g_bh(lf)
    ).reshape(B, H, S, m).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)
