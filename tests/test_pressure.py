"""Capacity-pressure subsystem (ISSUE 2): transparent eviction,
spill-to-host write-back, pin/protect semantics, spill counters, and the
executor's persistent worker pool + capacity-aware prefetch."""

import numpy as np
import pytest

from repro.core.allocator import AllocError
from repro.core.hete import HeteContext, MemorySpace, hete_sync
from repro.core.locations import HOST, Location

ACC = Location("device", "acc0")


def make_ctx(capacity=4096, tracking="flag", allocator="nextfit"):
    ctx = HeteContext(tracking=tracking)
    ctx.register_space(MemorySpace(
        ACC, capacity=capacity, allocator=allocator,
        ingest=lambda a: a.copy(), egress=lambda a: np.asarray(a),
    ))
    return ctx


# ---------------------------------------------------------------------------
# eviction engine
# ---------------------------------------------------------------------------


def test_pinned_exhaustion_raises_allocerror():
    """Eviction retries until only pinned bytes remain, then surfaces a
    genuine AllocError naming the pinned working set."""
    ctx = make_ctx(capacity=4096)
    a = ctx.malloc((2048,), np.uint8)
    b = ctx.malloc((2048,), np.uint8)
    ctx.ensure(a, ACC)
    ctx.ensure(b, ACC)
    c = ctx.malloc((2048,), np.uint8)
    with a.pinned(ACC), b.pinned(ACC):
        with pytest.raises(AllocError, match="pinned"):
            ctx.ensure(c, ACC)
    ctx.ensure(c, ACC)  # pins released → one victim spills, c fits
    assert ctx.ledger.total_evictions == 1


def test_unpin_without_pin_raises():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.uint8)
    with pytest.raises(ValueError):
        ctx.unpin(hd, ACC)


def test_clean_eviction_copies_nothing():
    """A clean replica (flag at host) is dropped without any write-back
    copy; only the re-ensure pays a host→device transfer."""
    ctx = make_ctx(capacity=4096)
    a = ctx.malloc((4096,), np.uint8)
    a.data[:] = 7
    ctx.ensure(a, ACC)  # 1 copy host→acc; flag stays HOST (read)
    assert ctx.ledger.total_copies == 1
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, ACC)  # evicts a (clean): no write-back copy
    snap = ctx.ledger.snapshot()
    assert snap["total_evictions"] == 1
    assert snap["writeback_bytes"] == 0
    assert snap["total_copies"] == 2  # just the two host→acc stagings
    assert snap["spill_stall_s"] == 0.0


def test_dirty_eviction_writes_back_and_roundtrips():
    """Evicted-then-re-ensured buffer round-trips bit-identically, and
    the ledger shows exactly the expected copies: host→acc staging,
    acc→host write-back, host→acc re-fetch."""
    ctx = make_ctx(capacity=4096)
    rng = np.random.default_rng(0)
    a = ctx.malloc((4096,), np.uint8)
    a.data[:] = rng.integers(0, 255, 4096, dtype=np.uint8)
    v = ctx.ensure(a, ACC)
    payload = (np.asarray(v) ^ 0xFF).astype(np.uint8)
    ctx.mark_written(a, ACC, payload)  # device owns the only valid copy
    assert ctx.ledger.total_copies == 1

    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, ACC)  # forces eviction of dirty a → write-back
    snap = ctx.ledger.snapshot()
    assert snap["total_evictions"] == 1
    assert snap["writeback_bytes"] == 4096
    assert snap["by_pair"]["device:acc0->host:cpu"] == 1
    assert snap["spill_stall_s"] > 0.0
    assert a.last_location == HOST and ACC not in a.copies

    ctx.free(b)
    back = ctx.ensure(a, ACC)  # re-ensure: host→acc re-fetch
    np.testing.assert_array_equal(np.asarray(back), payload)
    np.testing.assert_array_equal(a.data, payload)
    assert ctx.ledger.snapshot()["by_pair"]["host:cpu->device:acc0"] == 3


def test_dirty_fragment_writeback_keeps_parent_coherent():
    """Evicting a parent whose *fragments* were written on the device
    must gather through the zero-copy host views: parent bytes coherent,
    fragment aliasing preserved."""
    ctx = make_ctx(capacity=4096)
    parent = ctx.malloc((1024,), np.float32)  # 4096 B
    parent.data[:] = 1.0
    frags = parent.fragment(256)
    v0 = ctx.ensure(frags[0], ACC)
    ctx.mark_written(frags[0], ACC, np.asarray(v0) * 5.0)
    v2 = ctx.ensure(frags[2], ACC)
    ctx.mark_written(frags[2], ACC, np.asarray(v2) * 9.0)

    other = ctx.malloc((1024,), np.float32)
    ctx.ensure(other, ACC)  # evicts parent: per-fragment write-back
    snap = ctx.ledger.snapshot()
    assert snap["total_evictions"] == 1
    assert snap["writeback_bytes"] == 2 * 256 * 4  # only dirty fragments

    # parent host bytes coherent, views still aliased
    np.testing.assert_allclose(parent.data[:256], 5.0)
    np.testing.assert_allclose(parent.data[256:512], 1.0)
    np.testing.assert_allclose(parent.data[512:768], 9.0)
    for f in frags:
        assert f.last_location == HOST and ACC not in f.copies
    np.testing.assert_allclose(hete_sync(frags[2], context=ctx), 9.0)
    # fragment views still write through to the parent
    frags[1].data[:] = 3.0
    np.testing.assert_allclose(parent.data[256:512], 3.0)


def test_lru_victim_order_with_access_clock():
    """Least-recently-touched resident is evicted first; a flag-hit read
    counts as a touch."""
    ctx = make_ctx(capacity=8192)
    a = ctx.malloc((4096,), np.uint8)
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(a, ACC)
    ctx.ensure(b, ACC)
    # touch a *after* b so b becomes the LRU victim
    ctx.mark_written(a, ACC, np.ones((4096,), np.uint8))
    ctx.ensure(a, ACC)  # flag hit → access-clock touch
    c = ctx.malloc((4096,), np.uint8)
    ctx.ensure(c, ACC)
    assert ACC not in b.copies      # b evicted
    assert ACC in a.copies          # a survived (recently touched)
    assert ctx.ledger.snapshot()["writeback_bytes"] == 0  # b was clean


def test_explicit_evict_api():
    ctx = make_ctx(capacity=8192)
    a = ctx.malloc((4096,), np.uint8)
    ctx.ensure(a, ACC)
    assert ctx.evict(a, ACC) is True
    assert ctx.evict(a, ACC) is False  # not resident any more
    arena = ctx.spaces[ACC].arena
    assert arena.used_bytes == 0
    with a.pinned(ACC):
        ctx.ensure(a, ACC)
        assert ctx.evict(a, ACC) is False  # pinned


def test_eviction_under_cached_tracking_drops_replica():
    ctx = make_ctx(capacity=4096, tracking="cached")
    a = ctx.malloc((4096,), np.uint8)
    a.data[:] = 3
    ctx.ensure(a, ACC)
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, ACC)  # evicts a's replica
    assert ACC not in a.valid_at
    v = ctx.ensure(a, ACC)  # must re-copy, not serve the dropped replica
    np.testing.assert_array_equal(np.asarray(v), a.data)


def test_clean_eviction_does_not_revalidate_stale_host_copy():
    """Regression: evicting a clean replica while a *third* location owns
    the flag must not add HOST to valid_at — the host bytes are stale."""
    ACC2 = Location("device", "acc1")
    ctx = make_ctx(capacity=4096, tracking="cached")
    ctx.register_space(MemorySpace(
        ACC2, capacity=1 << 20, allocator="nextfit",
        ingest=lambda a: a.copy(), egress=lambda a: np.asarray(a),
    ))
    a = ctx.malloc((4096,), np.uint8)
    ctx.ensure(a, ACC)  # clean replica on ACC
    ctx.mark_written(a, ACC2, np.full((4096,), 9, np.uint8))  # ACC2 owns
    ctx.ensure(a, ACC)  # re-replicate on ACC (cached keeps both)
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, ACC)  # evicts a's CLEAN ACC replica (flag on ACC2)
    assert HOST not in a.valid_at  # host still stale, not revalidated
    np.testing.assert_array_equal(ctx.sync(a), 9)  # pulls from ACC2


def test_protected_bytes_deferred_under_prefetch_guard():
    """Inside prefetch_guard, protected (queued-reader) bytes are not
    evictable: the reservation defers instead of spilling them."""
    from repro.core.hete import PrefetchDeferred

    ctx = make_ctx(capacity=4096)
    a = ctx.malloc((4096,), np.uint8)
    ctx.ensure(a, ACC)
    ctx.protect(a, ACC)
    b = ctx.malloc((4096,), np.uint8)
    with ctx.prefetch_guard():
        with pytest.raises(PrefetchDeferred):
            ctx.ensure(b, ACC)
    assert ctx.ledger.snapshot()["prefetch_deferrals"] == 1
    ctx.unprotect(a, ACC)
    ctx.ensure(b, ACC)  # demand staging may now evict a
    assert ACC not in a.copies


def test_allocator_tags_name_residents():
    ctx = make_ctx(capacity=8192)
    a = ctx.malloc((4096,), np.uint8)
    ctx.ensure(a, ACC)
    arena = ctx.spaces[ACC].arena
    assert list(arena.tags().values()) == [id(a)]


# ---------------------------------------------------------------------------
# runtime + executor integration
# ---------------------------------------------------------------------------


def _pressure_runtime(arena_bytes, **kw):
    from repro.core.runtime import make_emulated_soc
    from repro.apps.radar import register_kernels
    from repro.core.runtime import Runtime

    pes, ctx = make_emulated_soc(
        n_cpu=0, accelerators=("gpu0",), arena_bytes=arena_bytes,
    )
    rt = Runtime(pes, ctx, policy="rimms", scheduler=kw.get(
        "scheduler", "round_robin"))
    register_kernels(rt)
    return rt, ctx


def _radar_tasks(ctx, ways=4, n=512, seed=0):
    from repro.apps.radar import _parallel_fzf

    return _parallel_fzf(ctx, ways, n, use_fragment=True, seed=seed)


def test_serial_pipeline_bit_identical_under_pressure():
    """A radar pipeline whose working set exceeds the arena completes
    with outputs bit-identical to an unconstrained run (serial mode)."""
    ways, n = 4, 512
    parent_bytes = ways * n * 8  # complex64
    roomy, _ = _pressure_runtime(arena_bytes=64 << 20)
    tight, _ = _pressure_runtime(arena_bytes=3 * parent_bytes)

    pts_r, tasks_r = _radar_tasks(roomy.context, ways, n)
    pts_t, tasks_t = _radar_tasks(tight.context, ways, n)
    roomy.run(tasks_r)
    tight.run(tasks_t)
    assert tight.context.ledger.total_evictions > 0
    out_r = hete_sync(pts_r["out"][0], context=roomy.context)
    out_t = hete_sync(pts_t["out"][0], context=tight.context)
    np.testing.assert_array_equal(out_r, out_t)
    # spill stalls surfaced in the timeline + modeled makespan
    assert tight.timeline.total_spill_s > 0.0
    assert tight.last_makespan_model > roomy.last_makespan_model


def test_graph_pipeline_bit_identical_under_pressure():
    """Graph mode (prefetch + protection) under the same pressure."""
    ways, n = 4, 512
    parent_bytes = ways * n * 8
    roomy, _ = _pressure_runtime(arena_bytes=64 << 20)
    tight, _ = _pressure_runtime(arena_bytes=3 * parent_bytes)

    pts_r, tasks_r = _radar_tasks(roomy.context, ways, n)
    pts_t, tasks_t = _radar_tasks(tight.context, ways, n)
    roomy.run_graph(tasks_r)
    tight.run_graph(tasks_t)
    assert tight.context.ledger.total_evictions > 0
    out_r = hete_sync(pts_r["out"][0], context=roomy.context)
    out_t = hete_sync(pts_t["out"][0], context=tight.context)
    np.testing.assert_array_equal(out_r, out_t)
    # all protection claims released at run end
    assert not tight.context._protected


def test_worker_pool_persists_across_run_graph_calls():
    import threading

    rt, ctx = _pressure_runtime(arena_bytes=64 << 20)
    _, tasks1 = _radar_tasks(ctx, 2, 256, seed=1)
    rt.run_graph(tasks1)
    pool = rt._worker_pool
    assert pool is not None and pool.runs_served == 1
    before = threading.active_count()
    _, tasks2 = _radar_tasks(ctx, 2, 256, seed=2)
    rt.run_graph(tasks2)
    assert rt._worker_pool is pool and pool.runs_served == 2
    assert threading.active_count() == before  # no new threads spun up
    rt.close()
    assert rt._worker_pool is None
