"""TransferLedger + Timeline evidence plumbing (ISSUE 6 satellites):
fresh_ledger semantics, snapshot/reset round-trip, Gantt transfer
lanes."""

from repro.core.instrument import (
    Timeline,
    TimelineEvent,
    TransferEvent,
    TransferLedger,
    fresh_ledger,
)
from repro.core.locations import BandwidthModel, Location

HOST = Location("host", "cpu")
GPU = Location("device", "gpu0")


def _ledger():
    return TransferLedger(bandwidth_model=BandwidthModel())


# ---------------------------------------------------------------------------
# fresh_ledger: reset on entry, counts KEPT on exit (documented semantics)
# ---------------------------------------------------------------------------


def test_fresh_ledger_resets_on_entry_and_keeps_counts_on_exit():
    led = _ledger()
    led.record(HOST, GPU, 1024)
    assert led.total_copies == 1
    with fresh_ledger(led) as inner:
        assert inner is led
        assert led.total_copies == 0  # pre-existing counts cleared
        led.record(HOST, GPU, 2048)
        led.record(GPU, HOST, 512)
    # the block's evidence survives the exit — nothing is restored
    assert led.total_copies == 2
    assert led.total_bytes == 2560


def test_fresh_ledger_defaults_to_module_global():
    from repro.core.instrument import ledger as global_ledger

    snap = global_ledger.snapshot()  # pre-experiment evidence, caller-kept
    with fresh_ledger() as led:
        assert led is global_ledger
        assert led.total_copies == 0
    assert snap["total_copies"] >= 0  # snapshot unaffected by the reset


# ---------------------------------------------------------------------------
# snapshot()/reset() round-trip
# ---------------------------------------------------------------------------


def test_snapshot_reset_round_trip():
    led = _ledger()
    led.record(HOST, GPU, 1000)
    led.record(HOST, GPU, 1000)
    led.record(GPU, HOST, 500)
    led.record_eviction(GPU, 256, writeback_bytes=128, stall_s=0.25)
    led.record_flag_check(3)
    snap = led.snapshot()
    assert snap["total_copies"] == 3
    assert snap["total_bytes"] == 2500
    assert snap["by_pair"] == {"device:gpu0->host:cpu": 1,
                               "host:cpu->device:gpu0": 2}
    assert snap["per_link"]["host:cpu->device:gpu0"]["copies"] == 2
    assert snap["per_link"]["host:cpu->device:gpu0"]["bytes"] == 2000
    assert snap["total_evictions"] == 1
    assert snap["writeback_bytes"] == 128
    assert snap["flag_checks"] == 3

    led.reset()
    clean = led.snapshot()
    assert clean["total_copies"] == 0
    assert clean["total_bytes"] == 0
    assert clean["by_pair"] == {}
    assert clean["per_link"] == {}
    assert clean["total_evictions"] == 0
    assert clean["flag_checks"] == 0

    # counting resumes from zero after the reset
    led.record(HOST, GPU, 64)
    after = led.snapshot()
    assert after["total_copies"] == 1
    assert after["per_link"] == {
        "host:cpu->device:gpu0": {
            "copies": 1, "bytes": 64,
            "modeled_s": after["per_link"]["host:cpu->device:gpu0"]["modeled_s"],
        }
    }


# ---------------------------------------------------------------------------
# Timeline.gantt(): transfer lanes and overlap marks
# ---------------------------------------------------------------------------


def _compute(task, pe, t0, t1):
    return TimelineEvent(task=task, pe=pe, wall_start=0.0, wall_end=0.0,
                         model_start=t0, model_end=t1,
                         transfer_s=0.0, compute_s=t1 - t0)


def test_gantt_renders_transfers_only_timeline():
    tl = Timeline()
    tl.add_transfer(TransferEvent(link="host->gpu0", task="t0",
                                  nbytes=1024, model_start=0.0,
                                  model_end=0.5))
    txt = tl.gantt(40)
    assert txt != "(empty timeline)"
    assert "host->gpu0" in txt
    assert "=" in txt  # link-busy lane rendered


def test_gantt_marks_overlap_within_a_lane_with_plus():
    tl = Timeline()
    tl.add(_compute("a", "gpu0", 0.0, 0.6))
    tl.add(_compute("b", "gpu0", 0.4, 1.0))  # overlaps a on the same PE
    txt = tl.gantt(40)
    assert "+" in txt
    assert "#" in txt


def test_gantt_compute_and_transfer_lanes_coexist():
    tl = Timeline()
    tl.add(_compute("a", "gpu0", 0.2, 1.0))
    tl.add_transfer(TransferEvent(link="host->gpu0", task="a",
                                  nbytes=4096, model_start=0.0,
                                  model_end=0.2))
    txt = tl.gantt(48)
    lines = txt.splitlines()
    assert any(ln.lstrip().startswith("gpu0") and "#" in ln for ln in lines)
    assert any("host->gpu0" in ln and "=" in ln for ln in lines)
    assert "(modeled)" in lines[-1]
