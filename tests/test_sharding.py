"""Logical-axis rules, divisibility guards, spec resolution."""

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import make_mesh
from repro.distributed.sharding import (
    MULTI_POD_RULES, SINGLE_POD_RULES, AxisRules, resolve_spec,
)


def mesh_1x1():
    return make_mesh((1, 1), ("data", "model"))


def test_spec_resolution_basic():
    rules = AxisRules(dict(SINGLE_POD_RULES), mesh=None)
    assert rules.spec("batch", None, "heads") == P(("data",), None, ("model",))


def test_divisibility_guard_replicates():
    mesh = mesh_1x1()
    rules = AxisRules(dict(SINGLE_POD_RULES), mesh=mesh)
    # fake a 16-wide axis by checking the arithmetic path directly
    rules16 = AxisRules(dict(SINGLE_POD_RULES), mesh=mesh)
    rules16.mesh_size = lambda axes: 16
    assert rules16.entry("heads", 40) is None  # 40 % 16 != 0 → replicate
    assert rules16.entry("heads", 32) is not None
    assert ("heads", 40, ("model",)) in rules16.dropped


def test_multi_pod_batch_axes():
    rules = AxisRules(dict(MULTI_POD_RULES), mesh=None)
    assert rules.axes_for("batch") == ("pod", "data")


def test_resolve_spec_with_dims():
    mesh = mesh_1x1()
    rules = AxisRules(dict(SINGLE_POD_RULES), mesh=mesh)
    p = resolve_spec(P("batch", "vocab"), rules, (8, 100))
    # canonical tuple entries — same form as AxisRules.spec, so the two
    # spec-building paths compare equal on every jax version
    assert p == P(("data",), ("model",))
    assert p == rules.spec("batch", "vocab", dims=(8, 100))


def test_unknown_logical_axis_raises():
    rules = AxisRules(dict(SINGLE_POD_RULES), mesh=None)
    with pytest.raises(KeyError):
        rules.axes_for("bogus")


def test_shard_noop_without_mesh():
    import jax.numpy as jnp
    from repro.distributed.sharding import shard

    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x
