"""Multi-tenant QoS (ISSUE 5): DRR weighted shares, backpressure
windows, per-tenant arena quotas, fairness reporting, interference-aware
placement, the deterministic QoS replay, and the SessionClosedError
shutdown audit."""

import threading
import time
import types

import numpy as np
import pytest

from repro.apps.radar import make_session, submit_2fzf
from repro.core import api as rimms
from repro.core.api import SessionClosedError
from repro.core.graph import TaskNode
from repro.core.hete import AllocError, HeteContext, MemorySpace
from repro.core.instrument import TransferLedger, jain_index
from repro.core.locations import Location
from repro.core.qos import (
    BackpressureFull, QoSManager, QuotaExceeded, fair_replay,
)
from repro.core.runtime import Task


# ---------------------------------------------------------------------------
# synthetic fair_replay fixtures
# ---------------------------------------------------------------------------


def _stub_rt(pes=("pe0",)):
    return types.SimpleNamespace(
        pes=[types.SimpleNamespace(name=p) for p in pes]
    )


def _chain(nodes, records, client, count, comp=1.0, pe="pe0"):
    """Append ``count`` independent one-op tasks for ``client``."""
    for _ in range(count):
        i = len(nodes)
        nodes.append(TaskNode(i, Task("op", [], [], client=client)))
        records[i] = (pe, (), comp, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)


def test_fair_replay_weighted_shares_converge():
    """DRR weights are reflected in admitted service: with weights 3:1
    and equal task costs, client A finishes ~3x B's tasks in any prefix
    of the virtual schedule (past the initial window transient)."""
    nodes, records = [], {}
    _chain(nodes, records, "A", 30)
    _chain(nodes, records, "B", 30)
    qos = {"clients": {"A": {"weight": 3.0, "window": 30},
                       "B": {"weight": 1.0, "window": 30}},
           "global_window": 2, "quantum_bytes": 1}
    _, makespan, finish, release = fair_replay(
        _stub_rt(), nodes, records, None, qos)
    assert makespan == 60.0  # one PE, unit tasks, work-conserving
    a_done = max(finish[i] for i in range(30))  # A's last finish
    b_by_then = sum(1 for i in range(30, 60) if finish[i] <= a_done)
    # A finished all 30 by a_done; B should have ~10 (weight ratio 3:1),
    # burst-boundary transient gives a little slack.
    assert 8 <= b_by_then <= 14, (a_done, b_by_then)


def test_fair_replay_equal_weights_interleave_evenly():
    nodes, records = [], {}
    _chain(nodes, records, "A", 20)
    _chain(nodes, records, "B", 20)
    qos = {"clients": {"A": {"weight": 1.0, "window": 20},
                       "B": {"weight": 1.0, "window": 20}},
           "global_window": 2, "quantum_bytes": 1}
    _, _, finish, _ = fair_replay(_stub_rt(), nodes, records, None, qos)
    a_done = max(finish[i] for i in range(20))
    b_done = max(finish[i] for i in range(20, 40))
    assert abs(a_done - b_done) <= 2.0  # neither client starved


def test_fair_replay_window_bounds_backlog():
    """A small backpressure window keeps a flooding client from
    occupying the PE ahead of a light client's task; a huge window (the
    pre-QoS behaviour) starves it."""
    def light_finish(heavy_window):
        nodes, records = [], {}
        _chain(nodes, records, "heavy", 12)
        _chain(nodes, records, "light", 1)
        qos = {"clients": {
            "heavy": {"weight": 1.0, "window": heavy_window},
            "light": {"weight": 1.0, "window": 4},
        }, "quantum_bytes": 1}
        _, _, finish, _ = fair_replay(_stub_rt(), nodes, records, None, qos)
        return finish[12]

    assert light_finish(heavy_window=12) == 13.0  # FCFS: behind everything
    assert light_finish(heavy_window=2) == 3.0  # windowed: behind 2


def test_fair_replay_is_deterministic_and_respects_deps():
    nodes, records = [], {}
    _chain(nodes, records, "A", 6)
    # B's second task depends on its first
    i0 = len(nodes)
    nodes.append(TaskNode(i0, Task("op", [], [], client="B")))
    records[i0] = ("pe0", (), 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
    i1 = len(nodes)
    nodes.append(TaskNode(i1, Task("op", [], [], client="B"), deps={i0}))
    nodes[i0].dependents.add(i1)
    records[i1] = ("pe0", (), 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
    qos = {"clients": {"A": {"weight": 1.0, "window": 2},
                       "B": {"weight": 1.0, "window": 4}},
           "quantum_bytes": 1}
    runs = [fair_replay(_stub_rt(), nodes, records, None, qos)
            for _ in range(2)]
    assert runs[0][1] == runs[1][1]
    assert runs[0][2] == runs[1][2]  # identical finish maps
    assert runs[0][2][i1] > runs[0][2][i0]  # dep ordering holds


# ---------------------------------------------------------------------------
# backpressure: submit blocks at the window limit, unblocks on completion
# ---------------------------------------------------------------------------


def _gated_registry(gate):
    reg = rimms.OpRegistry()

    @rimms.op("wait", kinds=("cpu",), registry=reg)
    def wait_kernel(ins):
        gate.wait(30)
        return ins[0]

    return reg


def test_submit_blocks_at_window_limit_and_unblocks():
    gate = threading.Event()
    s = rimms.Session.emulated(accelerators=(), n_cpu=1,
                               scheduler="round_robin",
                               registry=_gated_registry(gate))
    try:
        c = s.client("tenant", window=2)
        x = c.malloc((8,), np.float32)
        f1 = c.submit("wait", [x])
        f2 = c.submit("wait", [x])
        # window full: nowait raises instead of blocking
        with pytest.raises(BackpressureFull, match="tenant"):
            c.submit("wait", [x], nowait=True)
        # blocking submit parks until a completion frees the window
        state = {"submitted": None}

        def blocked():
            state["submitted"] = c.submit("wait", [x])

        t = threading.Thread(target=blocked)
        t.start()
        time.sleep(0.15)
        assert state["submitted"] is None  # still backpressured
        assert c.state.inflight == 2
        # Another client with window room is NOT backpressured just
        # because this tenant has waiters: nowait admits via a real DRR
        # pass instead of raising.
        other = s.client("other", window=4)
        y = other.malloc((8,), np.float32)
        f_other = other.submit("wait", [y], nowait=True)
        gate.set()  # kernels complete -> slots free -> submit proceeds
        t.join(timeout=30)
        assert state["submitted"] is not None
        f1.result(timeout=30)
        f2.result(timeout=30)
        f_other.result(timeout=30)
        state["submitted"].result(timeout=30)
        s.barrier()
        # admission stalls were attributed to the tenant
        rep = s.ledger.fairness_report()
        assert rep["clients"]["tenant"]["stall_s"] > 0.0
    finally:
        gate.set()
        s.close()
        s.runtime.close()


def test_failed_tasks_release_window_slots():
    reg = rimms.OpRegistry()

    @rimms.op("boom", kinds=("cpu",), registry=reg)
    def boom(ins):
        raise RuntimeError("kernel exploded")

    with rimms.Session.emulated(accelerators=(), n_cpu=1,
                                scheduler="round_robin",
                                registry=reg) as s:
        c = s.client("t", window=2)
        x = c.malloc((4,), np.float32)
        futs = [c.submit("boom", [x]) for _ in range(6)]  # > window
        for f in futs:
            with pytest.raises(RuntimeError, match="exploded"):
                f.result(timeout=30)
        assert c.state.inflight == 0


# ---------------------------------------------------------------------------
# per-tenant arena quotas
# ---------------------------------------------------------------------------


def test_quota_alloc_error_is_per_tenant():
    """Tenant A exhausting its quota fails alone — tenant B's identical
    work on the same arena keeps completing."""
    s = make_session(policy="rimms", scheduler="round_robin", n_cpu=0,
                     accelerators=("gpu0",), arena_bytes=1 << 20)
    try:
        a = s.client("A", quota_bytes=100 << 10)
        b = s.client("B")
        n = 1 << 15  # 256 KiB complex64 buffers: far over A's quota
        xa = a.malloc((n,), np.complex64)
        fa = a.submit("fft", [xa], pin="gpu0")
        with pytest.raises(QuotaExceeded) as ei:
            fa.result(timeout=60)
        assert ei.value.tenant == "A"
        assert isinstance(ei.value, AllocError)
        # B is unaffected: same size, same arena, no quota
        xb = b.malloc((n,), np.complex64)
        xb.data[:] = 1.0
        out = b.submit("fft", [xb], pin="gpu0").result(timeout=60)
        np.testing.assert_allclose(
            out, np.fft.fft(xb.data).astype(np.complex64), atol=1e-3)
        s.barrier()
    finally:
        s.close()
        s.runtime.close()


def test_quota_evicts_own_buffers_first_to_stay_under_budget():
    """A tenant at quota recycles its *own* arena bytes (evicting its
    LRU buffer) rather than failing, as long as something of its own is
    evictable."""
    s = make_session(policy="rimms", scheduler="round_robin", n_cpu=0,
                     accelerators=("gpu0",), arena_bytes=2 << 20)
    try:
        # one chain in flight (input+output, 512 KiB) fits; the idle
        # buffers of earlier chains do not
        a = s.client("A", quota_bytes=600 << 10)
        n = 1 << 15  # 256 KiB
        outs = []
        for k in range(3):  # serial chains: earlier buffers are idle
            x = a.malloc((n,), np.complex64)
            x.data[:] = k + 1
            outs.append(a.submit("fft", [x], pin="gpu0"))
            outs[-1].result(timeout=60)
        s.barrier()
        assert all(np.all(np.isfinite(o.result(timeout=5))) for o in outs)
        assert s.ledger.client_evictions["A"] > 0  # recycled its own bytes
        assert s.context.tenant_bytes("A", Location("device", "gpu0")) \
            <= 600 << 10
    finally:
        s.close()
        s.runtime.close()


def test_capacity_eviction_prefers_over_quota_tenant():
    """General capacity pressure picks the over-quota tenant's buffer
    first, even when another tenant's buffer is older in LRU order."""
    ctx = HeteContext()
    dev = Location("device", "d0")
    ctx.register_space(MemorySpace(
        dev, capacity=64 << 10, block_size=4096,
        ingest=lambda v: v.copy(), egress=lambda v: np.asarray(v),
    ))
    hb = ctx.malloc((24 << 10,), np.uint8, owner="B")  # older touch (LRU)
    ctx.ensure(hb, dev)
    ha = ctx.malloc((24 << 10,), np.uint8, owner="A")
    ctx.ensure(ha, dev)
    ctx.set_quota("A", 8 << 10)  # A is now over quota
    hc = ctx.malloc((24 << 10,), np.uint8, owner="B")
    ctx.ensure(hc, dev)  # needs an eviction: plain LRU would pick B's
    assert dev not in ha.extents  # over-quota A was preferred
    assert dev in hb.extents
    assert ctx.ledger.client_evictions["A"] == 1


def test_spill_to_peer_respects_peer_arena_quota():
    """The runtime's own eviction path must not push a tenant over its
    budget in a peer arena: write-back falls back to host when the
    cheaper peer spill would exceed the owner's quota there."""
    from repro.core.topology import TopologyBandwidthModel, build_preset

    g0, g1 = Location("device", "gpu0"), Location("device", "gpu1")
    ctx = HeteContext()
    ctx.ledger.bandwidth_model = TopologyBandwidthModel(
        build_preset("nvlink_mesh", [g0, g1]))
    for loc, cap in ((g0, 4096), (g1, 1 << 20)):
        ctx.register_space(MemorySpace(
            loc, capacity=cap, ingest=lambda v: v.copy(),
            egress=lambda v: np.asarray(v)))
    a = ctx.malloc((4096,), np.uint8, owner="A")
    a.data[:] = 7
    v = ctx.ensure(a, g0)
    payload = (np.asarray(v) ^ 0xFF).astype(np.uint8)
    ctx.mark_written(a, g0, payload)  # dirty on gpu0
    ctx.set_quota("A", 2048)  # a fresh 4096 B peer extent would exceed
    b = ctx.malloc((4096,), np.uint8, owner="B")
    ctx.ensure(b, g0)  # evicts a: peer link is cheaper, but quota says host
    snap = ctx.ledger.snapshot()
    assert snap["spills_to_peer"] == 0
    assert a.last_location.kind == "host"
    assert ctx.tenant_bytes("A", g1) == 0
    np.testing.assert_array_equal(a.data, payload)  # written back intact


# ---------------------------------------------------------------------------
# fairness report
# ---------------------------------------------------------------------------


def test_jain_index():
    assert jain_index([]) == 1.0
    assert jain_index([5.0, 5.0, 5.0]) == 1.0
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1 / 3)
    assert 0.5 < jain_index([2.0, 1.0]) < 1.0


def test_fairness_report_fields_and_weight_normalization():
    led = TransferLedger()
    led.record_client_task("a", 100, 2.0)
    led.record_client_task("b", 50, 1.0)
    led.record_client_stall("b", 0.25)
    led.record_client_failure("b")
    led.record_eviction(Location("device", "d0"), 64, 64, 0.0, owner="a")
    rep = led.fairness_report()
    assert set(rep) == {"clients", "n_clients", "jain_index"}
    assert rep["n_clients"] == 2
    row = rep["clients"]["a"]
    assert set(row) == {"tasks", "bytes", "service_model_s", "stall_s",
                        "evictions", "failures", "weight"}
    assert row["tasks"] == 1 and row["bytes"] == 100
    assert row["evictions"] == 1
    assert rep["clients"]["b"]["stall_s"] == 0.25
    assert rep["clients"]["b"]["failures"] == 1
    # unequal raw service -> index < 1; weights 2:1 normalize it back
    assert rep["jain_index"] < 1.0
    weighted = led.fairness_report(weights={"a": 2.0, "b": 1.0})
    assert weighted["jain_index"] == pytest.approx(1.0)
    # subset selection
    only_a = led.fairness_report(clients=["a"])
    assert only_a["n_clients"] == 1 and only_a["jain_index"] == 1.0
    led.reset()
    assert led.fairness_report()["n_clients"] == 0


# ---------------------------------------------------------------------------
# bit-identical outputs under contention vs solo
# ---------------------------------------------------------------------------


def _run_light(session, chains, n):
    rows = []
    for k in range(chains):
        bufs = submit_2fzf(session, n, pins=("gpu0",) * 4, seed=100 + k,
                           tag=f"_k{k}")
        rows.append(bufs["out"].result(timeout=120).copy())
    return rows


def test_bit_identical_under_contention_vs_solo():
    """QoS changes when work runs, never what it computes: a light
    client's chains are bitwise identical with and without a heavy
    tenant flooding the same session."""
    n, chains = 1 << 10, 3

    solo = make_session(policy="rimms", scheduler="round_robin", n_cpu=0,
                        accelerators=("gpu0", "gpu1"))
    solo.client("light", window=4)
    solo_rows = _run_light(solo, chains, n)
    solo.barrier()
    solo.close()
    solo.runtime.close()

    mix = make_session(policy="rimms", scheduler="round_robin", n_cpu=0,
                       accelerators=("gpu0", "gpu1"))
    mix.client("light", window=4)
    mix.client("heavy", weight=0.25, window=4)
    stop = threading.Event()
    errors = []

    def heavy():
        try:
            k = 0
            while not stop.is_set() and k < 12:
                submit_2fzf(mix, n, pins=("gpu0",) * 4, seed=900 + k,
                            tag=f"_h{k}")
                k += 1
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    ht = threading.Thread(target=heavy, name="heavy")
    ht.start()
    light_thread_rows = []

    def light():
        try:
            light_thread_rows.extend(_run_light(mix, chains, n))
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    lt = threading.Thread(target=light, name="light")
    lt.start()
    lt.join(timeout=120)
    stop.set()
    ht.join(timeout=120)
    assert not errors
    mix.barrier()
    mix.close()
    mix.runtime.close()

    assert len(light_thread_rows) == chains
    for got, want in zip(light_thread_rows, solo_rows):
        assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# interference-aware heft placement
# ---------------------------------------------------------------------------


def test_interference_charges_other_clients_prorated():
    s = make_session(scheduler="heft", n_cpu=0,
                     accelerators=("gpu0", "gpu1"))
    try:
        ex = s._stream
        hd = s.context.malloc((16,), np.complex64)
        task = Task("fft", [hd], [], client="A")
        gpu0 = s.runtime.by_name["gpu0"]
        # co-pending: B could use either gpu (charge est/2), a second A
        # task charges nothing (self-delay is not interference), C is
        # pinned elsewhere
        ex._copending = {
            7: ("B", frozenset({"gpu0", "gpu1"})),
            8: ("A", frozenset({"gpu0"})),
            9: ("C", frozenset({"gpu1"})),
        }
        assert ex._interference(task, gpu0, est=1.0) == pytest.approx(0.5)
        gpu1 = s.runtime.by_name["gpu1"]
        assert ex._interference(task, gpu1, est=1.0) == pytest.approx(1.5)
        # no attribution -> no charge (batch engine behaviour unchanged)
        assert ex._interference(Task("fft", [hd], []), gpu0, 1.0) == 0.0
        ex._copending = {}
        assert ex._interference(task, gpu0, 1.0) == 0.0
    finally:
        s.close()
        s.runtime.close()


def test_interference_spreads_two_clients_across_equal_pes():
    """Two clients' simultaneous independent chains on two equal
    accelerators: interference-aware heft serves both PEs (no client
    pile-up on one device)."""
    s = make_session(scheduler="heft", n_cpu=0,
                     accelerators=("gpu0", "gpu1"))
    try:
        a, b = s.client("A"), s.client("B")
        for cl, tag in ((a, "a"), (b, "b")):
            for k in range(4):
                x = cl.malloc((1 << 12,), np.complex64)
                x.data[:] = k + 1
                cl.submit("fft", [x], name=f"fft_{tag}{k}")
        s.barrier()
        used = {pe for _, pe in s.runtime.task_log}
        assert used == {"gpu0", "gpu1"}
    finally:
        s.close()
        s.runtime.close()


# ---------------------------------------------------------------------------
# SessionClosedError: shutdown path under concurrent submitters
# ---------------------------------------------------------------------------


def test_submit_and_malloc_after_close_raise_session_closed():
    s = make_session(accelerators=("gpu0",))
    s.close()
    with pytest.raises(SessionClosedError):
        s.malloc((8,))
    with pytest.raises(SessionClosedError):
        s.submit("fft", [np.zeros(8, np.complex64)])
    # and it is still the RuntimeError("... closed") contract
    with pytest.raises(RuntimeError, match="closed"):
        s.submit("fft", [np.zeros(8, np.complex64)])
    s.runtime.close()


def test_submit_after_runtime_close_raises_not_hangs():
    """A dead worker pool must surface as SessionClosedError, never as
    a silently enqueued task that no thread will ever run."""
    s = make_session(accelerators=("gpu0",), n_cpu=0,
                     scheduler="round_robin")
    x = s.submit("fft", [np.ones(64, np.complex64)])
    x.result(timeout=30)
    s.barrier()
    s.runtime.close()  # pool gone, session not closed by the user
    with pytest.raises(SessionClosedError):
        s.submit("fft", [np.ones(64, np.complex64)])
    s.close()


def test_concurrent_submitters_race_close_cleanly():
    """N threads submit in a loop while the main thread closes the
    session: every submission either completes normally or raises
    SessionClosedError — nothing hangs, nothing lands on a dead pool."""
    s = make_session(accelerators=("gpu0", "gpu1"), n_cpu=0,
                     scheduler="round_robin")
    unexpected = []
    done = []

    def submitter(i):
        futs = []
        try:
            for k in range(200):
                futs.append(s.submit("fft", [np.ones(256, np.complex64)],
                                     name=f"s{i}_{k}"))
        except SessionClosedError:
            pass
        except BaseException as e:  # pragma: no cover - diagnostic
            unexpected.append(e)
        finally:
            done.append(len(futs))

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    s.close()
    for t in threads:
        t.join(timeout=60)
    assert not unexpected
    assert len(done) == 4
    # everything admitted before the close completed (close drains)
    rep = s.report()
    assert rep["n_completed"] + rep["n_failed"] == rep["n_tasks"]
    s.runtime.close()


# ---------------------------------------------------------------------------
# session-level QoS report plumbing
# ---------------------------------------------------------------------------


def test_qos_report_latencies_and_fairness():
    s = make_session(policy="rimms", scheduler="round_robin", n_cpu=0,
                     accelerators=("gpu0", "gpu1"))
    try:
        a = s.client("A", window=4)
        b = s.client("B", window=4)
        fa = a.submit("fft", [np.ones(1 << 10, np.complex64)], pin="gpu0")
        fb = b.submit("fft", [np.ones(1 << 10, np.complex64)], pin="gpu1")
        fa.result(timeout=30)
        fb.result(timeout=30)
        s.barrier()
        rep = s.qos_report()
        assert rep["makespan_model"] > 0
        for f in (fa, fb):
            assert f.node is not None
            assert rep["release_model"][f.node] == 0.0
            assert rep["finish_model"][f.node] > 0.0
        fairness = rep["fairness"]
        assert set(fairness["clients"]) >= {"A", "B"}
        assert fairness["jain_index"] == pytest.approx(1.0)
        assert rep["qos"]["clients"]["A"]["window"] == 4
    finally:
        s.close()
        s.runtime.close()


def test_qos_manager_client_update_and_validation():
    q = QoSManager(default_window=8)
    a = q.client("a", weight=2.0)
    assert a.window == 8 and a.weight == 2.0
    assert q.client("a", window=3) is a and a.window == 3
    with pytest.raises(ValueError):
        q.client("bad", weight=0.0)
    with pytest.raises(ValueError):
        q.client("bad2", window=0)
    params = q.params()
    assert params["clients"]["a"] == {"weight": 2.0, "window": 3,
                                      "quota_bytes": None, "think_s": 0.0,
                                      "slo_latency_s": None,
                                      "slo_target": 0.99}
