"""Integration: the multi-pod dry-run pipeline end to end (subprocess —
the 512-host-device XLA flag must be set before jax initializes, so it
cannot run in this test process)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("cell", [("xlstm-350m", "decode_32k")])
def test_dryrun_cell_subprocess(tmp_path, cell):
    arch, shape = cell
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path),
         "--no-skip-existing"],
        cwd=REPO, capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": str(REPO / "src"),
             "XLA_FLAGS": ""},  # dryrun.py sets its own device-count flag
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = tmp_path / f"{arch.replace('-', '_')}__{shape}__single.json"
    rec = json.loads(out.read_text())
    assert "error" not in rec, rec.get("error")
    assert rec["n_devices"] == 256  # single-pod = 16×16
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["per_device_total"] > 0
    assert rec["collectives"]["algorithm_bytes"] >= 0


def test_roofline_table_generation():
    """Dry-run artifacts (when generated) must yield a full roofline table.

    The artifacts are products of `python -m repro.launch.dryrun --sweep
    --probes` (128 proof-compiles, hours of CPU) and are not committed;
    without them this test skips rather than fails."""
    from repro.configs.base import ARCH_IDS, cells_for
    from repro.launch.roofline import DRYRUN_DIR, full_table, markdown_table

    if not any(DRYRUN_DIR.glob("*.json")):
        pytest.skip(
            "no dry-run artifacts under experiments/dryrun "
            "(generate with: python -m repro.launch.dryrun --sweep --probes)"
        )
    rows = full_table()
    expected = sum(len(cells_for(a)) for a in ARCH_IDS)
    assert len(rows) == expected == 32
    md = markdown_table(rows)
    assert md.count("\n") == len(rows) + 2
    # every cell proof-compiled on the multi-pod mesh too
    assert all(r["multi_ok"] for r in rows)
    # every cell has a dominant bottleneck classified
    assert all(r["bottleneck"] in ("compute", "memory", "collective")
               for r in rows)
