"""End-to-end system behaviour tests: the paper's workflow (allocate →
fragment → heterogeneous task graph → RIMMS policy) and the framework
workflow (pipeline → train → checkpoint → serve) glued together."""

import dataclasses

import numpy as np

from repro.apps.radar import build_sar, make_runtime
from repro.core.hete import hete_sync


def test_paper_end_to_end_sar():
    """SAR (two-phase FZF) through both policies: same numerics, fewer
    copies under RIMMS, on a 2-accelerator SoC."""
    outs = {}
    copies = {}
    for policy in ("reference", "rimms"):
        rt, ctx = make_runtime(policy=policy,
                               accelerators=("fft_acc0", "zip_acc0"))
        bufs, tasks = build_sar(ctx, scale=64, seed=11)  # 8-way + 4-way
        rt.run(tasks)
        outs[policy] = hete_sync(bufs["phase1"]["out"][1][0], context=ctx).copy()
        copies[policy] = ctx.ledger.total_copies
    np.testing.assert_allclose(outs["reference"], outs["rimms"], atol=1e-4)
    assert copies["rimms"] < copies["reference"]


def test_framework_end_to_end_train_then_serve(tmp_path):
    """Train a tiny LM for a few steps (checkpointed), restore the params
    and serve a request with the paged engine — full lifecycle."""

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    from repro.train.checkpoint import restore_checkpoint
    from repro.train.loop import Trainer, TrainerConfig

    cfg = dataclasses.replace(get_config("llama3_8b").smoke(),
                              dtype="float32")
    trainer = Trainer(cfg, batch_size=2, seq_len=16,
                      tcfg=TrainerConfig(steps=3, ckpt_every=3,
                                         ckpt_dir=str(tmp_path)))
    report = trainer.run()
    assert report["final_step"] == 3

    model = build_model(cfg)
    like = {"params": trainer.params, "opt": trainer.opt_state}
    restored, step, _ = restore_checkpoint(tmp_path, like)
    assert step == 3
    eng = ServeEngine(cfg, restored["params"], max_batch=2)
    req = eng.submit([1, 2, 3], max_new_tokens=3)
    eng.run()
    assert req.done and len(req.generated) == 3
    assert all(0 <= t < cfg.vocab for t in req.generated)
