"""Measured calibration + autotuning (ISSUE 10): table persistence and
merge, CostModel integration, deterministic variant dispatch, tuned
Pallas variants' bit-identity, and the process-backend calibration path
with cross-process metric drain."""

import json

import numpy as np
import pytest

import repro.apps.elemwise as elemwise
from repro.core.api import OpRegistry, Session
from repro.core.calibrate import (
    DEFAULT_VARIANT, FORMAT, CalibrationTable, calibrate,
    resolve_calibration,
)
from repro.core.graph import CostModel


# module-level kernels: the process backend ships fns by pickle
# reference, and the registry rejects closures changing between variants
def _double(ins):
    return np.asarray(ins[0]) * 2.0


def _double_alt(ins):
    return (np.asarray(ins[0]) * 2.0) + 0.0


def _make_f64(rng, nbytes):
    return [rng.standard_normal(max(nbytes // 8, 1))]


# ---------------------------------------------------------------------------
# CalibrationTable persistence + merge
# ---------------------------------------------------------------------------


def test_table_save_load_roundtrip(tmp_path):
    t = CalibrationTable()
    t.record("fft", "default", "cpu", 1 << 20, 1e-3)
    t.record("fft", "block64", "cpu", 1 << 20, 5e-4, identical=True)
    t.set_winner("fft", "cpu", 1 << 20, "block64", speedup=2.0,
                 median_s=5e-4)
    t.meta["host"] = "testbox"
    t.divergence = {"cells": {}}
    path = tmp_path / "calib.json"
    t.save(str(path))

    doc = json.loads(path.read_text())
    assert doc["format"] == FORMAT

    back = CalibrationTable.load(str(path))
    assert back.state() == t.state()
    assert back.best_variant("fft", "cpu", 1 << 20) == "block64"
    assert back.meta["host"] == "testbox"
    assert back.divergence == {"cells": {}}


def test_table_load_rejects_unknown_format(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"format": "rimms-calib-v999"}))
    with pytest.raises(ValueError, match="format"):
        CalibrationTable.load(str(path))


def test_table_merge_count_weights_cells_and_keeps_best_winner():
    a = CalibrationTable()
    b = CalibrationTable()
    a.record("zip", "default", "cpu", 4096, 1e-3)
    b.record("zip", "default", "cpu", 4096, 3e-3)
    a.set_winner("zip", "cpu", 4096, "default", speedup=1.0, median_s=1e-3)
    b.set_winner("zip", "cpu", 4096, "fast", speedup=1.5, median_s=2e-3)
    a.merge(b)
    cell = a.cell("zip", "cpu", 4096)
    assert cell["count"] == 2
    assert abs(cell["median_s"] - 2e-3) < 1e-12  # count-weighted mean
    # b's winner is SLOWER (2e-3 > 1e-3): the existing winner stays
    assert a.winner("zip", "cpu", 4096)["variant"] == "default"

    c = CalibrationTable()
    c.set_winner("zip", "cpu", 4096, "fast", speedup=4.0, median_s=25e-5)
    a.merge(c.state())  # merge accepts a raw state dict too
    assert a.winner("zip", "cpu", 4096)["variant"] == "fast"


def test_resolve_calibration_forms(tmp_path, monkeypatch):
    assert resolve_calibration(None) is None
    t = CalibrationTable()
    assert resolve_calibration(t) is t
    path = tmp_path / "c.json"
    t.record("fft", "default", "cpu", 1024, 1e-4)
    t.save(str(path))
    assert len(resolve_calibration(str(path))) == 1
    # "auto": empty table when the env var points nowhere...
    monkeypatch.delenv("RIMMS_CALIBRATION", raising=False)
    assert len(resolve_calibration("auto")) == 0
    # ...and the file's contents when it does
    monkeypatch.setenv("RIMMS_CALIBRATION", str(path))
    assert len(resolve_calibration("auto")) == 1


# ---------------------------------------------------------------------------
# CostModel integration
# ---------------------------------------------------------------------------


def test_cost_model_uses_measured_cell_and_falls_back_on_missing():
    t = CalibrationTable()
    nb = 1 << 20
    t.record("fft", "default", "gpu", nb, 2e-3)
    cm = CostModel(calibration=t)
    # measured bucket: linear interpolation off the measured cell
    measured = cm.prior_estimate("fft", "gpu", nb)
    assert abs(measured - 2e-3) < 1e-9
    # missing bucket (different size class) → the historical prior
    prior = CostModel().prior_estimate("fft", "gpu", 1 << 10)
    assert cm.prior_estimate("fft", "gpu", 1 << 10) == prior
    # missing kind → prior as well
    assert (cm.prior_estimate("fft", "cpu", nb)
            == CostModel().prior_estimate("fft", "cpu", nb))
    # detach restores the prior everywhere
    cm.set_calibration(None)
    assert cm.prior_estimate("fft", "gpu", nb) == CostModel().prior_estimate(
        "fft", "gpu", nb)


# ---------------------------------------------------------------------------
# deterministic variant dispatch from a fixed table
# ---------------------------------------------------------------------------


def _variant_session(table):
    reg = OpRegistry()
    reg.register("double", "cpu", _double, calib=_make_f64)
    reg.register("double", "cpu", _double_alt, variant="alt")
    return Session.emulated(n_cpu=1, accelerators=(), registry=reg,
                            calibration=table)


def test_runtime_dispatches_winner_variant_from_fixed_table():
    n = 1024  # float64 → 8 KiB bucket
    table = CalibrationTable()
    table.record("double", "default", "cpu", 8 * n, 1e-3)
    table.record("double", "alt", "cpu", 8 * n, 5e-4, identical=True)
    table.set_winner("double", "cpu", 8 * n, "alt", speedup=2.0,
                     median_s=5e-4)
    session = _variant_session(table)
    try:
        x = np.arange(n, dtype=np.float64)
        out = session.submit("double", [x]).result(timeout=60)
        session.barrier()
        assert [v for (o, _k, v) in session.runtime.variant_log
                if o == "double"] == ["alt"]
        np.testing.assert_array_equal(np.asarray(out), x * 2.0)
    finally:
        session.close()


def test_runtime_default_dispatch_without_table_or_winner():
    # no calibration attached → default variant, nothing logged
    session = _variant_session(None)
    try:
        x = np.arange(1024, dtype=np.float64)
        session.submit("double", [x]).result(timeout=60)
        session.barrier()
        assert session.runtime.variant_log == []
    finally:
        session.close()
    # table attached but winner at a DIFFERENT bucket → default path
    table = CalibrationTable()
    table.set_winner("double", "cpu", 1 << 20, "alt", speedup=2.0,
                     median_s=1e-4)
    session = _variant_session(table)
    try:
        x = np.arange(1024, dtype=np.float64)
        out = session.submit("double", [x]).result(timeout=60)
        session.barrier()
        # the winner lives at a different bucket: default path, no log
        assert session.runtime.variant_log == []
        np.testing.assert_array_equal(np.asarray(out), x * 2.0)
    finally:
        session.close()


def test_registry_select_consults_table():
    reg = OpRegistry()
    reg.register("double", "cpu", _double)
    reg.register("double", "cpu", _double_alt, variant="alt")
    assert reg.select("double", "cpu", 8192).fn is _double
    table = CalibrationTable()
    table.set_winner("double", "cpu", 8192, "alt", speedup=2.0,
                     median_s=1e-4)
    assert reg.select("double", "cpu", 8192, table=table).fn is _double_alt
    # winner naming an unregistered variant falls back to the default
    table2 = CalibrationTable()
    table2.set_winner("double", "cpu", 8192, "gone", speedup=2.0,
                      median_s=1e-4)
    assert reg.select("double", "cpu", 8192, table=table2).fn is _double


# ---------------------------------------------------------------------------
# session calibration lifecycle
# ---------------------------------------------------------------------------


def test_session_calibrate_then_save_embeds_divergence(tmp_path):
    reg = OpRegistry()
    reg.register("double", "cpu", _double, calib=_make_f64)
    reg.register("double", "cpu", _double_alt, variant="alt")
    session = Session.emulated(n_cpu=1, accelerators=(), registry=reg)
    try:
        table = session.calibrate(ops=["double"], nbytes=[8192], k=2,
                                  warmup=1)
        assert session.calibration is table
        assert session.runtime.calibration is table
        # both variants measured, non-default verified bit-identical
        assert table.cell("double", "cpu", 8192)["count"] == 1
        alt = table.cell("double", "cpu", 8192, variant="alt")
        assert alt["identical"] is True
        assert table.winner("double", "cpu", 8192)["speedup"] >= 1.0
        # run something so the divergence monitor has cells to embed
        session.submit("double", [np.arange(64, dtype=np.float64)]
                       ).result(timeout=60)
        session.barrier()
        path = tmp_path / "calib.json"
        session.save_calibration(str(path))
    finally:
        session.close()
    back = CalibrationTable.load(str(path))
    assert back.divergence is not None
    # a new session picks the snapshot up into its live monitor
    s2 = Session.emulated(n_cpu=1, accelerators=(), registry=reg,
                          calibration=str(path))
    try:
        assert s2.runtime.divergence.table() != {}
    finally:
        s2.close()


def test_calibrate_skips_ops_without_input_factory():
    reg = OpRegistry()
    reg.register("double", "cpu", _double)  # no calib= factory
    session = Session.emulated(n_cpu=1, accelerators=(), registry=reg)
    try:
        table = calibrate(session, nbytes=[4096], k=1, warmup=1)
    finally:
        session.close()
    assert len(table) == 0
    assert "double" in table.meta["skipped_ops"]


# ---------------------------------------------------------------------------
# tuned Pallas variants: bit-identity of every candidate vs the default
# ---------------------------------------------------------------------------


def test_tuned_variant_candidates_bit_identical_to_default():
    from repro.core.autotune import tunables

    rng = np.random.default_rng(7)
    nb = 32 << 10
    for tun in tunables():
        if not tun.bit_identical:
            continue
        ins = [np.asarray(a) for a in tun.make_inputs(rng, nb)]
        ref = tun.fn(ins, **{tun.param: tun.default})
        for value in tun.candidates:
            outs = tun.fn(ins, **{tun.param: value})
            assert len(outs) == len(ref), tun.op
            for a, b in zip(outs, ref):
                assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), (
                    f"{tun.op}: {tun.param}={value} is not bit-identical "
                    f"to the default {tun.default}"
                )


def test_autotune_registers_variants_and_attaches_table():
    from repro.core.autotune import autotune, register_tunables

    reg = OpRegistry()
    ops = register_tunables(reg)
    assert set(ops) == {"fft_pallas", "zip_pallas", "flash_attention",
                        "mlstm", "rg_lru"}
    assert len(reg.variants("fft_pallas", "cpu")) == 3
    assert reg.variants("fft_pallas", "cpu")[0] == DEFAULT_VARIANT
    # double registration is idempotent only with replace
    with pytest.raises(ValueError, match="already registered"):
        reg.register("fft_pallas", "cpu", _double)
    register_tunables(reg)  # same fns → no-op, no raise

    session = Session.emulated(n_cpu=1, accelerators=(), registry=reg)
    try:
        table = autotune(session, nbytes=[16 << 10], k=1, warmup=1)
        assert session.runtime.calibration is table
        # every tuned op measured on the cpu kind
        measured = {key.split("/")[0] for key, _ in table.cells()}
        assert set(ops) <= measured
        # mlstm's chunk candidates change accumulation order: they must
        # be recorded as NOT identical, so the default always wins
        alts = [c for key, c in table.cells()
                if key.startswith("mlstm/chunk32/cpu/")]
        assert alts and all(c["identical"] is False for c in alts)
        win = [w for key, w in table.winners()
               if key.startswith("mlstm/cpu/")]
        assert win and all(w["variant"] == DEFAULT_VARIANT for w in win)
    finally:
        session.close()


# ---------------------------------------------------------------------------
# process backend: worker-side measurement + cross-process metric drain
# ---------------------------------------------------------------------------


def test_calibrate_process_backend_roundtrip_and_metric_drain(tmp_path):
    reg = OpRegistry()
    reg.register("scale", "gpu", elemwise.scale, calib=_make_f64)
    # same module-level fn, same params → bit-identical by construction
    reg.register("scale", "gpu", elemwise.scale, variant="alt",
                 params={"factor": 2.0})
    session = Session.emulated(n_cpu=0, accelerators=("gpu0",),
                               registry=reg, backend="process")
    try:
        table = session.calibrate(ops=["scale"], nbytes=[8192], k=2,
                                  warmup=1)
        assert table.meta["backend"] == "process"
        cell = table.cell("scale", "gpu", 8192)
        assert cell is not None and cell["median_s"] > 0
        alt = table.cell("scale", "gpu", 8192, variant="alt")
        assert alt["identical"] is True
        assert table.winner("scale", "gpu", 8192)["speedup"] >= 1.0
        path = tmp_path / "proc.json"
        session.save_calibration(str(path))
    finally:
        session.close()
        session.runtime.close()
    # the calibration runs executed in the PE's subprocess worker; its
    # locally accumulated metrics must drain into the session registry
    tasks = session.metrics.counter("worker/gpu0/tasks").value
    assert tasks > 0
    back = CalibrationTable.load(str(path))
    assert back.state()["cells"] == table.state()["cells"]
